"""Benchmark suite — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is host time
where meaningful (0 for analytic models); ``derived`` carries the quantity
the paper reports.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,table2,...]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import baselines, bitstream, codec, entropy, fixed, huffman
from . import common
from .common import emit, timeit

PAPER_MODELS = ("jamba-tiny-dev", "zamba2-1.2b", "qwen1.5-1.8b")
DATASETS = {"wikitext2": 1024, "c4": 2048}   # paper: 1K / 2K input tokens


def fig1_entropy() -> None:
    """Fig 1a/b: exponent entropy, distinct values, volume reduction."""
    for arch in PAPER_MODELS:
        w = common.weight_stream(arch)
        t0 = time.perf_counter()
        st = entropy.profile_exponents(w)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig1.entropy.weights.{arch}", us,
             f"exp_H={st.exp_entropy_bits:.2f}b distinct="
             f"{st.distinct_exponents} man_H={st.man_entropy_bits:.2f}b "
             f"overall_cr={st.overall_cr:.2f}x")
        acts = common.activation_streams(arch)
        for kind, a in acts.items():
            st = entropy.profile_exponents(a)
            emit(f"fig1.entropy.{kind}.{arch}", 0.0,
                 f"exp_H={st.exp_entropy_bits:.2f}b distinct="
                 f"{st.distinct_exponents} overall_cr={st.overall_cr:.2f}x")


def table2_compression_ratio() -> None:
    """Table 2: exponent CR of RLE / BDI / LEXI on model weights."""
    for arch in PAPER_MODELS:
        w = common.weight_stream(arch)
        t0 = time.perf_counter()
        crs = codec.measure_crs(w)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"table2.cr.{arch}", us,
             f"rle={crs['rle']:.2f}x bdi={crs['bdi']:.2f}x "
             f"lexi={crs['lexi']:.2f}x (paper: 0.62-0.65/2.36-2.43/"
             f"3.07-3.14)")


def table3_comm_latency() -> None:
    """Table 3: communication latency per model x dataset x method."""
    from repro.configs import get_config
    from repro.hw import noc
    for arch in PAPER_MODELS:
        w = common.weight_stream(arch)
        acts = common.activation_streams(arch)
        cr_w = codec.overall_bf16_ratio(codec.measure_crs(w)["lexi"])
        cr_a = codec.overall_bf16_ratio(
            codec.measure_crs(acts["activations"])["lexi"])
        cr_c = codec.overall_bf16_ratio(
            codec.measure_crs(acts.get("cache", acts["activations"]))["lexi"])
        crs = {"weights": cr_w, "activations": cr_a, "cache": cr_c}
        for ds, in_tok in DATASETS.items():
            res = noc.simulate(get_config(arch), in_tokens=in_tok,
                               out_tokens=512, crs=crs)
            u, wo, l = (res["uncompressed"], res["weights_only"],
                        res["lexi"])
            emit(f"table3.comm.{arch}.{ds}", 0.0,
                 f"uncompressed={u.comm_ms:.1f}ms weights={wo.comm_ms:.1f}ms "
                 f"lexi={l.comm_ms:.1f}ms red="
                 f"{(1 - l.comm_ms / u.comm_ms) * 100:.1f}% "
                 f"(paper: 33-45%)")


def fig7_e2e_latency() -> None:
    """Fig 7: normalized end-to-end latency."""
    from repro.configs import get_config
    from repro.hw import noc
    for arch in PAPER_MODELS:
        w = common.weight_stream(arch)
        cr = codec.overall_bf16_ratio(codec.measure_crs(w)["lexi"])
        crs = {"weights": cr, "activations": cr, "cache": cr}
        for ds, in_tok in DATASETS.items():
            res = noc.simulate(get_config(arch), in_tokens=in_tok,
                               out_tokens=512, crs=crs)
            u, l = res["uncompressed"], res["lexi"]
            emit(f"fig7.e2e.{arch}.{ds}", 0.0,
                 f"uncompressed={u.e2e_ms:.1f}ms lexi={l.e2e_ms:.1f}ms "
                 f"red={(1 - l.e2e_ms / u.e2e_ms) * 100:.1f}% "
                 f"comm_frac={u.comm_ms / u.e2e_ms * 100:.0f}% "
                 f"(paper: 30-35% red, 68-95% comm)")


def fig4_cache_hit_rate() -> None:
    """Fig 4: local cache hit rate vs depth, per model."""
    from repro.hw import lanecache
    for arch in PAPER_MODELS:
        acts = common.activation_streams(arch)
        u16 = entropy.to_bf16_u16(acts["activations"][:40_000])
        exp = entropy.split_fields(u16)[1]
        rates = []
        t0 = time.perf_counter()
        for depth in (1, 2, 4, 8, 16):
            st = lanecache.simulate_lanes(exp, lanes=10, depth=depth)
            rates.append(f"d{depth}={st.hit_rate * 100:.1f}%")
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig4.hitrate.{arch}", us,
             " ".join(rates) + " (paper: >90% at depth 8)")


def fig5_codebook_latency() -> None:
    """Fig 5: codebook generation latency vs cache configuration."""
    from repro.hw import lanecache
    w = common.weight_stream(PAPER_MODELS[0])
    exp = entropy.split_fields(entropy.to_bf16_u16(w))[1]
    rows = []
    t0 = time.perf_counter()
    for lanes, depth in ((1, 4), (2, 4), (4, 8), (10, 8), (16, 8), (32, 16)):
        ns = lanecache.codebook_latency_cycles(exp, lanes, depth)
        rows.append(f"{lanes}x{depth}={ns}ns/"
                    f"{lanecache.cache_size_bytes(lanes, depth)}B")
    us = (time.perf_counter() - t0) * 1e6
    emit("fig5.codebook_latency", us,
         " ".join(rows) + " (paper: 788ns@1x4, ~55ns@10x8, ~17ns@32x16)")


def fig6_decoder_dse() -> None:
    """Fig 6: staged-LUT decoder latency/area design points."""
    from repro.hw import lut_decoder
    w = common.weight_stream(PAPER_MODELS[0], max_elems=6000)
    exp = entropy.split_fields(entropy.to_bf16_u16(w))[1]
    t0 = time.perf_counter()
    pts = lut_decoder.dse_points(exp)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig6.decoder_dse", us,
         " ".join(f"[{n}]={lat:.1f}ns/{a:.1f}um2" for n, lat, a in pts)
         + " (paper: 4-stage 11.6ns/98.5um2 vs flat 10ns/157.6um2)")


def table4_area_power() -> None:
    """Table 4: GF22 area/power breakdown + 16nm scaling."""
    from repro.hw import area
    la = area.LexiArea()
    br = la.breakdown_um2()
    emit("table4.area", 0.0,
         " ".join(f"{k}={v:.1f}um2" for k, v in br.items())
         + f" total={la.total_um2:.1f}um2 power={la.total_mw:.2f}mW "
           f"16nm={la.total_um2_16nm:.1f}um2 "
           f"overhead={la.chiplet_overhead * 100:.3f}% (paper: 0.09%)")


def bench_kernels() -> None:
    """Kernel wrappers vs pure-jnp refs (CPU interpret — correctness-scale
    timings only; see EXPERIMENTS §Perf for the TPU roofline story)."""
    import jax.numpy as jnp
    from repro.kernels import ops
    x = jnp.asarray(common.RNG.normal(0, 0.05, (64, 4096)), jnp.bfloat16)
    us = timeit(lambda v: ops.histogram(v), x, iters=3)
    emit("kernel.exp_histogram.256k", us, "vs ref: bit-exact (tests)")
    us = timeit(lambda v: fixed.compress(v), x, iters=3)
    emit("kernel.fw_compress.256k", us,
         f"wire_ratio={float(fixed.compress(x).ratio()):.3f}x")
    w = jnp.asarray(common.RNG.normal(0, 0.02, (512, 512)), jnp.bfloat16)
    from repro.kernels import ops as kops
    sm, pl, d, _ = kops.compress_weight(w)
    xa = jnp.asarray(common.RNG.normal(0, 1, (128, 512)), jnp.bfloat16)
    us = timeit(lambda a: kops.matmul_compressed(a, sm, pl, d), xa, iters=3)
    emit("kernel.decompress_matmul.128x512x512", us, "fused JIT decode")


def _repo_root():
    import pathlib
    return pathlib.Path(__file__).resolve().parent.parent


SMOKE = False   # set by --smoke: tiny single-scenario pass, no JSON writes
SOCKET = False  # set by --socket: run the disagg scenario a second time
                # with the decode replica in a separate OS process behind
                # SocketTransport (spawns repro.launch.disagg_host)
STORE_PAGES = 4096  # set by --store-pages: LRU cap for the content-
                    # addressed stores (transport digest store + PageCache
                    # warm tier) on every engine the serving bench builds
TRACE_OUT = None    # set by --trace-out: write the disagg serving
                    # scenario's Chrome trace-event JSON here (each disagg
                    # scenario overwrites it, so the file ends up holding
                    # the LAST one — the two-process socket run under
                    # --socket; validate with scripts/trace_summary.py)


def bench_serving() -> None:
    """Serving throughput: continuous batching over the paged LEXI cache.

    Runs a SHARED-PREFIX request stream (more requests than decode slots,
    mixed prompt lengths, duplicated/extended prompts) through
    ``repro.serve.ServeEngine`` for the cache codec on/off x decode backend
    (pure-JAX scan vs the fused Pallas kernels in interpret mode).  Each
    scenario runs twice on the same engine: a COLD pass (includes every
    jit compile) and a WARM pass (steady state, ``includes_compile:
    false``) — plus a prefix-sharing-off comparison run per codec so the
    page-memory win of sharing is recorded.  Reports requests/s, tokens/s,
    latency percentiles, admission dispatch/compile counts, shared-page
    hits and the peak paged-cache footprint (stored vs raw bytes) — the
    serving analogue of Table 3's wire-byte accounting.  tp=1 so it runs
    on a single host device.

    A ``disagg`` scenario then runs the same stream through prefill ->
    decode replicas over compressed page transfer (``repro.serve.disagg``),
    asserting stream identity with the monolithic engine and recording the
    link-byte accounting (wire vs bf16-dense bytes, codec-only vs
    prefix-dedup, modeled LinkModel latency) — the serving analogue of the
    paper's Table 3 wire-byte reduction.

    Writes machine-readable ``BENCH_serving.json`` at the repo root so
    future PRs have a recorded perf baseline to regress against (skipped
    under --smoke).  (On CPU the interpret backend measures the Pallas
    *interpreter* — the cross-backend comparison is a correctness/
    trajectory record, not a TPU roofline.)
    """
    import dataclasses
    import json
    from repro.configs.base import RunConfig
    from repro.core.collectives import CodecConfig
    from repro.launch.disagg_host import tiny_bench_config
    from repro.serve import Request, ServeEngine

    # the same config the two-process socket scenario's decode host builds
    # from its CLI flags (--model tiny-bench) — one definition, one
    # fingerprint
    cfg = tiny_bench_config()
    rng = np.random.default_rng(0)
    base_a = rng.integers(0, 512, (24,)).astype(np.int32)   # 3 page columns
    base_b = rng.integers(0, 512, (16,)).astype(np.int32)
    forked = np.concatenate([base_a[:16],
                             rng.integers(0, 512, (8,)).astype(np.int32)])
    n_req = 3 if SMOKE else 6

    def make_reqs():
        # duplicates + a prefix fork; budgets are STAGGERED so base_a's
        # slot outlives its neighbours — the duplicate/fork admissions
        # overlap base_a's residency and hit its live prefix pages
        # (refcount-zero frees mean sharing needs concurrent residency)
        prompts = [base_a, base_b, base_a, forked, base_b, base_a]
        budgets = [12, 4, 10, 8, 4, 6]
        return [Request(uid=i, prompt=prompts[i],
                        max_new_tokens=budgets[i]) for i in range(n_req)]

    def row(st, includes_compile: bool):
        return {
            "includes_compile": includes_compile,
            "n_requests": st.n_requests, "n_tokens": st.n_tokens,
            "decode_steps": st.decode_steps,
            "n_dispatches": st.n_dispatches,
            "n_admit_dispatches": st.n_admit_dispatches,
            "n_replay_dispatches": st.n_replay_dispatches,
            "n_admit_compiles": st.n_admit_compiles,
            "shared_page_hits": st.shared_page_hits,
            "wall_s": st.wall_s,
            "requests_per_s": st.requests_per_s,
            "tokens_per_s": st.tokens_per_s,
            "latency_mean_ms": st.mean_latency_s * 1e3,
            "latency_p50_ms": st.latency_p50_s * 1e3,
            "latency_p95_ms": st.latency_p95_s * 1e3,
            "peak_pages": st.peak_pages,
            "peak_cache_bytes": st.peak_cache_bytes,
            "peak_cache_raw_bytes": st.peak_cache_raw_bytes,
            "cache_hot_hits": st.cache_hot_hits,
            "cache_spilled_pages": st.cache_spilled_pages,
            "cache_spilled_bytes": st.cache_spilled_bytes,
            "cache_fetched_pages": st.cache_fetched_pages,
            "cache_fetched_bytes": st.cache_fetched_bytes,
            "cache_reprefill_cols": st.cache_reprefill_cols,
            "cache_evicted_cols": st.cache_evicted_cols,
            "weights_compressed": st.weights_compressed,
            "weight_backend": st.weight_backend,
            "weight_bytes_per_step": st.weight_bytes_per_step,
            "weight_raw_bytes_per_step": st.weight_raw_bytes_per_step,
            "ttft_mean_ms": st.ttft_mean_s * 1e3,
            "ttft_p50_ms": st.ttft_p50_s * 1e3,
            "ttft_p95_ms": st.ttft_p95_s * 1e3,
            "admit_window_mean_ms": st.admit_window_mean_s * 1e3,
            "decode_window_mean_ms": st.decode_window_mean_s * 1e3,
            "inter_token_mean_ms": st.inter_token_mean_s * 1e3,
        }

    scenarios = []
    codecs = (("on", CodecConfig(cache_block=8)),
              ("off", dataclasses.replace(CodecConfig.off(), cache_block=8)))
    if SMOKE:
        codecs = codecs[:1]
    backends = ("jax",) if SMOKE else ("jax", "interpret")
    for label, codec in codecs:
        for backend in backends:
            run = RunConfig(codec=dataclasses.replace(
                codec, decode_backend=backend))
            eng = ServeEngine(cfg, run, tp=1, n_slots=2, max_len=96, seed=1,
                              store_pages=STORE_PAGES)
            reqs = make_reqs()
            results, st = eng.run(reqs)
            assert all(len(r.tokens) == q.max_new_tokens
                       for r, q in zip(results, reqs))
            assert st.shared_page_hits > 0
            assert st.n_admit_dispatches < st.n_requests
            # warm pass: same engine, identical fresh requests -> steady
            # state (no new compiles; admission fns are bucket-keyed).
            # Retention means the cold pass's prefix columns SURVIVED the
            # full release — the warm pass must re-acquire them from the
            # hot tier instead of re-prefilling
            results_w, st_w = eng.run(make_reqs())
            assert st_w.n_admit_compiles == st.n_admit_compiles
            assert st_w.cache_hot_hits > st.cache_hot_hits
            assert [r.tokens for r in results_w] == \
                   [r.tokens for r in results]
            for tag, s in (("cold", st), ("warm", st_w)):
                emit(f"serving.continuous.codec_{label}.{backend}.{tag}",
                     s.wall_s * 1e6,
                     f"req_s={s.requests_per_s:.2f} "
                     f"tok_s={s.tokens_per_s:.1f} steps={s.decode_steps} "
                     f"dispatches={s.n_dispatches} "
                     f"admit={s.n_admit_dispatches}+{s.n_replay_dispatches}r "
                     f"hits={s.shared_page_hits} "
                     f"p50_ms={s.latency_p50_s * 1e3:.0f} "
                     f"p95_ms={s.latency_p95_s * 1e3:.0f} "
                     f"peak_pages={s.peak_pages} "
                     f"cache_kB={s.peak_cache_bytes / 1e3:.1f} "
                     f"raw_kB={s.peak_cache_raw_bytes / 1e3:.1f} "
                     f"ratio={s.cache_ratio:.2f}x")
            scenarios.append({
                "codec": label, "decode_backend": st.decode_backend,
                "cold": row(st, True), "warm": row(st_w, False)})

        # prefix-sharing-off comparison (jax backend): same stream, no
        # page sharing -> more admit prefills + higher page peak
        run = RunConfig(codec=dataclasses.replace(codec,
                                                  decode_backend="jax"))
        eng_off = ServeEngine(cfg, run, tp=1, n_slots=2, max_len=96, seed=1,
                              prefix_sharing=False)
        results_o, st_o = eng_off.run(make_reqs())
        assert [r.tokens for r in results_o] == [r.tokens for r in results]
        assert st_o.shared_page_hits == 0
        assert st.n_admit_dispatches < st_o.n_admit_dispatches
        emit(f"serving.continuous.codec_{label}.no_sharing",
             st_o.wall_s * 1e6,
             f"admit={st_o.n_admit_dispatches} hits=0 "
             f"peak_pages={st_o.peak_pages} "
             f"cache_kB={st_o.peak_cache_bytes / 1e3:.1f}")
        scenarios.append({
            "codec": label, "decode_backend": "jax",
            "prefix_sharing": False, "cold": row(st_o, True)})
    # --- disagg: prefill replicas -> decode replicas over compressed page
    # transfer, with STREAMING prefill export (full pages cross the link as
    # admission fills them; the closing blob references them by digest).
    # The link-byte accounting is the serving measurement of the paper's
    # headline claim (Table 3's wire bytes): every handoff ships LEXI-FW
    # pages byte-identical to the pool + content-dedups repeated prefixes
    # in the RECEIVER's digest store, metered against the bf16-dense
    # baseline through hw.noc.LinkModel.  Token streams must match the
    # monolithic engine.  With --socket, the same scenario then runs AGAIN
    # with the decode replica in a separate OS process behind
    # SocketTransport (spawned via repro.launch.disagg_host).
    from repro.serve.disagg import DisaggEngine

    def disagg_row(tag, st_d, ratio):
        return {
            "scenario": tag, "codec": label,
            "decode_backend": st_d.decode_backend,
            "n_prefill": st_d.n_prefill_replicas,
            "n_decode": st_d.n_decode_replicas,
            "n_transfers": st_d.n_transfers,
            "wire_bytes": st_d.wire_bytes,
            "wire_bytes_nodedup": st_d.wire_bytes_nodedup,
            "wire_raw_bytes": st_d.wire_raw_bytes,
            "wire_ratio": ratio,
            "link_reduction": st_d.link_reduction,
            "dedup_page_refs": st_d.dedup_page_refs,
            "pages_streamed": st_d.pages_streamed,
            "stream_chunk_bytes": st_d.stream_chunk_bytes,
            "decode_prefix_hits": st_d.decode_prefix_hits,
            "cache_hot_hits": st_d.cache_hot_hits,
            "cache_spilled_pages": st_d.cache_spilled_pages,
            "cache_spilled_bytes": st_d.cache_spilled_bytes,
            "cache_fetched_pages": st_d.cache_fetched_pages,
            "cache_fetched_bytes": st_d.cache_fetched_bytes,
            "cache_reprefill_cols": st_d.cache_reprefill_cols,
            "pages_resent": st_d.pages_resent,
            "store_evicted": st_d.store_evicted,
            "link_model_ms": st_d.link_model_ms,
            "link_model_ms_raw": st_d.link_model_ms_raw,
            "tokens_per_s": st_d.tokens_per_s,
            "n_tokens": st_d.n_tokens,
            "decode_steps": st_d.decode_steps,
            "n_dispatches": st_d.n_dispatches,
            "wall_s": st_d.wall_s,
            "ttft_mean_ms": st_d.ttft_mean_s * 1e3,
            "ttft_p50_ms": st_d.ttft_p50_s * 1e3,
            "ttft_p95_ms": st_d.ttft_p95_s * 1e3,
            "transfer_mean_ms": st_d.transfer_mean_s * 1e3,
        }

    def emit_disagg(tag, st_d, ratio):
        emit(f"serving.{tag}.codec_{label}", st_d.wall_s * 1e6,
             f"tok_s={st_d.tokens_per_s:.1f} "
             f"transfers={st_d.n_transfers} "
             f"wire_kB={st_d.wire_bytes / 1e3:.1f} "
             f"raw_kB={st_d.wire_raw_bytes / 1e3:.1f} "
             f"ratio={ratio:.3f} "
             f"red={st_d.link_reduction * 100:.1f}% "
             f"nodedup_kB={st_d.wire_bytes_nodedup / 1e3:.1f} "
             f"deduped={st_d.dedup_page_refs} "
             f"streamed={st_d.pages_streamed} "
             f"chunk_kB={st_d.stream_chunk_bytes / 1e3:.1f} "
             f"import_hits={st_d.decode_prefix_hits} "
             f"link_ms={st_d.link_model_ms:.4f}/"
             f"{st_d.link_model_ms_raw:.4f}")

    from repro.serve.telemetry import Tracer

    def write_trace(tracer):
        if TRACE_OUT:
            tracer.write(TRACE_OUT)
            emit("serving.trace", 0.0,
                 f"wrote {TRACE_OUT} ({len(tracer.events)} spans)")

    mono_tokens = {}
    for label, codec in codecs:
        run = RunConfig(codec=dataclasses.replace(codec,
                                                  decode_backend="jax"))
        eng_m = ServeEngine(cfg, run, tp=1, n_slots=2, max_len=96, seed=1)
        res_m, _ = eng_m.run(make_reqs())
        mono_tokens[label] = [r.tokens for r in res_m]
        tr_d = Tracer(enabled=TRACE_OUT is not None)
        dis = DisaggEngine(cfg, run, tp=1, n_prefill=1, n_decode=1,
                           n_slots=2, max_len=96, seed=1, streaming=True,
                           store_pages=STORE_PAGES, tracer=tr_d)
        res_d, st_d = dis.run(make_reqs())
        assert [r.tokens for r in res_d] == mono_tokens[label]
        assert st_d.n_transfers > 0
        assert st_d.pages_streamed > 0           # streaming export is live
        ratio = st_d.wire_bytes / max(st_d.wire_raw_bytes, 1)
        if not SMOKE:
            # imported duplicates reuse resident prefix pages
            assert st_d.decode_prefix_hits > 0, st_d
        if label == "on" and not SMOKE:
            # acceptance bar: compressed link bytes <= 0.6x raw for the
            # bf16 cache mix (codec pages + receiver-side dedup, streaming
            # export enabled)
            assert ratio <= 0.6, ratio
        emit_disagg("disagg", st_d, ratio)
        scenarios.append(disagg_row("disagg", st_d, ratio))
        write_trace(tr_d)
        if SOCKET:
            # same stream, decode replica in ANOTHER OS PROCESS: spawn a
            # decode host, route the handoffs over TCP, assert identity
            from repro.launch.disagg_host import spawn_decode_host
            from repro.serve import SocketTransport
            proc, port = spawn_decode_host(
                ["--model", "tiny-bench", "--codec", label,
                 "--cache-block", "8", "--tp", "1", "--slots", "2",
                 "--max-len", "96", "--seed", "1",
                 "--decode-backend", "jax",
                 "--store-pages", str(STORE_PAGES)])
            tr = SocketTransport()
            try:
                tr_s = Tracer(enabled=TRACE_OUT is not None)
                dis_s = DisaggEngine(
                    cfg, run, tp=1, n_prefill=1, n_slots=2, max_len=96,
                    seed=1, transport=tr, streaming=True,
                    decode_addrs=[f"127.0.0.1:{port}"], tracer=tr_s)
                res_s, st_s = dis_s.run(make_reqs())
                assert [r.tokens for r in res_s] == mono_tokens[label]
                ratio_s = st_s.wire_bytes / max(st_s.wire_raw_bytes, 1)
                emit_disagg("disagg_socket", st_s, ratio_s)
                scenarios.append(disagg_row("disagg_socket", st_s, ratio_s))
                write_trace(tr_s)
            finally:
                tr.close()
                proc.terminate()
                try:
                    proc.wait(timeout=10)
                except Exception:
                    proc.kill()
    _cache_pressure_scenarios(scenarios)
    _weights_scenarios(scenarios)
    if SMOKE:
        emit("serving.smoke", 0.0,
             "smoke pass ok incl. disagg + cache pressure + packed weights"
             + (" + two-process socket" if SOCKET else "")
             + " (no JSON written)")
        return
    out = {"bench": "serving", "model": cfg.name,
           "jax_backend": __import__("jax").default_backend(),
           "scenarios": scenarios}
    path = _repo_root() / "BENCH_serving.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    emit("serving.json", 0.0, f"wrote {path.name} "
         f"({len(scenarios)} scenarios)")


def _cache_pressure_scenarios(scenarios: list) -> None:
    """Cache-pressure scenario for the tiered PageCache: a tiny pool forces
    retained columns out of the hot tier (evict -> the payloads spilled to
    host RAM at release), and a re-admission restores the prefix by digest
    fetch WITHOUT re-prefill — token streams must stay identical to the
    first pass.  A second run with a tiny digest store loses the spilled
    bytes and must take the counted re-prefill fallback instead, still
    stream-identical.  Runs under --smoke (it is the CI cache-pressure
    check); rows land in BENCH_serving.json."""
    import dataclasses
    from repro.configs.base import RunConfig
    from repro.core.collectives import CodecConfig
    from repro.launch.disagg_host import tiny_bench_config
    from repro.serve import Request, ServeEngine

    cfg = tiny_bench_config()
    run = RunConfig(codec=dataclasses.replace(CodecConfig(cache_block=4),
                                              decode_backend="jax"))
    rng = np.random.default_rng(3)
    shorts = [rng.integers(0, 512, (16,)).astype(np.int32)
              for _ in range(4)]                       # 4 columns each
    longs = [rng.integers(0, 512, (24,)).astype(np.int32)
             for _ in range(2)]                        # 6 columns each

    for store_pages, tag in ((4096, "pressure"), (2, "tiny_store")):
        # pool: 2 slots x 40 tokens / 4-token blocks = 20 page columns
        eng = ServeEngine(cfg, run, tp=1, n_slots=2, max_len=40, seed=1,
                          store_pages=store_pages)
        # phase 1: fill the pool with retained prefixes (16 columns)
        res1, _ = eng.run([Request(uid=i, prompt=p, max_new_tokens=2)
                           for i, p in enumerate(shorts)])
        assert eng.cache.retained() > 0
        # phase 2: longer admissions need 12 free columns -> the LRU tail
        # (the oldest retained columns, spilled at release) is evicted
        eng.run([Request(uid=10 + i, prompt=p, max_new_tokens=2)
                 for i, p in enumerate(longs)])
        assert eng.cache.evicted_cols > 0
        # phase 3: re-admit the FIRST prompt — its hot columns are gone;
        # the warm store restores them (or the tiny store forces the
        # re-prefill fallback), either way the stream is unchanged
        (r3,), st3 = eng.run([Request(uid=20, prompt=shorts[0].copy(),
                                      max_new_tokens=2)])
        assert r3.tokens == res1[0].tokens, tag
        assert st3.cache_spilled_pages > 0
        if store_pages >= 4096:
            assert st3.cache_fetched_pages > 0
            assert st3.cache_reprefill_cols == 0
        else:
            assert st3.cache_reprefill_cols > 0
        eng.drop_cache()
        assert eng._pages_in_use() == 0
        emit(f"serving.cache_{tag}", 0.0,
             f"store={store_pages} hot={st3.cache_hot_hits} "
             f"spilled={st3.cache_spilled_pages}p/"
             f"{st3.cache_spilled_bytes}B "
             f"fetched={st3.cache_fetched_pages}p/"
             f"{st3.cache_fetched_bytes}B "
             f"evicted={st3.cache_evicted_cols} "
             f"reprefill={st3.cache_reprefill_cols}")
        scenarios.append({
            "scenario": f"cache_{tag}", "store_pages": store_pages,
            "cache_hot_hits": st3.cache_hot_hits,
            "cache_spilled_pages": st3.cache_spilled_pages,
            "cache_spilled_bytes": st3.cache_spilled_bytes,
            "cache_fetched_pages": st3.cache_fetched_pages,
            "cache_fetched_bytes": st3.cache_fetched_bytes,
            "cache_evicted_cols": st3.cache_evicted_cols,
            "cache_reprefill_cols": st3.cache_reprefill_cols})


def _weights_scenarios(scenarios: list) -> None:
    """Weight-plane scenario: serve the same request stream from raw bf16
    weights and from the LEXI-packed at-rest store (``--compress-weights``),
    on both the exact unpack-then-einsum backend and the fused
    decompress_matmul kernel.  Token streams must be bit-identical and the
    packed store must hold <= 0.85x the raw bf16 HBM bytes per decode step.
    Runs under --smoke (it is the CI weight-plane check); rows land in
    BENCH_serving.json."""
    import dataclasses
    from repro.configs.base import RunConfig
    from repro.core.collectives import CodecConfig
    from repro.launch.disagg_host import tiny_bench_config
    from repro.serve import Request, ServeEngine

    cfg = tiny_bench_config()
    rng = np.random.default_rng(5)
    base = [rng.integers(0, 512, (16,)).astype(np.int32) for _ in range(3)]
    mk = lambda: [Request(uid=i, prompt=p.copy(), max_new_tokens=8)
                  for i, p in enumerate(base)]

    run_raw = RunConfig(codec=dataclasses.replace(
        CodecConfig(cache_block=8), decode_backend="jax"))
    eng_r = ServeEngine(cfg, run_raw, tp=1, n_slots=2, max_len=48, seed=1)
    t0 = time.perf_counter()
    res_r, st_r = eng_r.run(mk())
    dt_r = time.perf_counter() - t0
    raw_tokens = [r.tokens for r in res_r]

    for wb in ("jax", "interpret"):
        run_pk = RunConfig(codec=dataclasses.replace(
            CodecConfig(cache_block=8), decode_backend="jax",
            weight_backend=wb))
        eng_p = ServeEngine(cfg, run_pk, tp=1, n_slots=2, max_len=48,
                            seed=1, compress_weights=True)
        t0 = time.perf_counter()
        res_p, st_p = eng_p.run(mk())
        dt_p = time.perf_counter() - t0
        # serving from the packed store must not change a single token
        assert [r.tokens for r in res_p] == raw_tokens, wb
        # acceptance bar: packed weight HBM bytes <= 0.85x raw bf16
        assert st_p.weight_ratio <= 0.85, (wb, st_p.weight_ratio)
        assert st_p.weights_compressed and not st_r.weights_compressed
        tok_s = st_p.n_tokens / max(dt_p, 1e-9)
        emit(f"serving.weights.{wb}", 0.0,
             f"packed={st_p.weight_bytes_per_step / 1e3:.1f}kB/step "
             f"raw={st_p.weight_raw_bytes_per_step / 1e3:.1f}kB "
             f"ratio={st_p.weight_ratio:.3f} "
             f"tok/s={tok_s:.1f} (raw engine "
             f"{st_r.n_tokens / max(dt_r, 1e-9):.1f}) "
             f"streams identical")
        scenarios.append({
            "scenario": f"weights_{wb}", "weight_backend": wb,
            "weights_compressed": True,
            "weight_bytes_per_step": st_p.weight_bytes_per_step,
            "weight_raw_bytes_per_step": st_p.weight_raw_bytes_per_step,
            "weight_ratio": st_p.weight_ratio,
            "tokens_per_s": tok_s,
            "raw_tokens_per_s": st_r.n_tokens / max(dt_r, 1e-9),
            "streams_identical": True})


def bench_decode_kernel() -> None:
    """Microbench: the fused paged decompress+attend kernel vs the pure-JAX
    page-scan reference on a serving-shaped problem (per-slot lengths,
    page-table indirection).  On CPU the kernel runs under the Pallas
    interpreter, so treat these as trajectory numbers; writes
    ``BENCH_decode_kernel.json`` next to the serving baseline."""
    import json
    import jax
    import jax.numpy as jnp
    from repro.core import fixed
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    rng = np.random.default_rng(0)
    n_s, maxp, blk, hkv, hd, h = 4, 6, 16, 4, 32, 8
    w = 2 * hkv * hd
    n_pages = n_s * maxp
    kv_idx = tuple(min(i // (h // hkv), hkv - 1) for i in range(h))
    pages = jnp.asarray(rng.normal(0, 0.5, (n_pages, blk, w)), jnp.bfloat16)
    ring = jnp.asarray(rng.normal(0, 0.5, (n_s, blk, w)), jnp.bfloat16)
    pt = jnp.asarray(rng.integers(0, n_pages, (n_s, maxp)), jnp.int32)
    lengths = jnp.asarray(rng.integers(blk, maxp * blk, (n_s,)), jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (n_s, h, hd)), jnp.bfloat16)
    cts = jax.vmap(lambda v: fixed.compress(v, k=5))(pages)
    scale = hd ** -0.5

    fused = jax.jit(lambda q_: kops.decode_attend_paged(
        q_, cts.signman, cts.planes, cts.dict_syms, cts.esc_raw, None, ring,
        pt, lengths, 0, kops.WINDOW_NONE, k=5, hkv=hkv, hd=hd, kv_idx=kv_idx,
        scale=scale, tp=1, interpret=not kops.on_tpu())[0])
    pure = jax.jit(lambda q_: kref.paged_decode_attend_ref(
        q_, jax.vmap(fixed.decompress)(cts), pt, lengths, ring,
        kv_idx=kv_idx, scale=scale, tp=1, ti=0))
    rows = {}
    for name, fn in (("fused_kernel", fused), ("pure_jax", pure)):
        us = timeit(fn, q, iters=3)
        rows[name] = us
        emit(f"decode_kernel.paged.{name}", us,
             f"S={n_s} maxp={maxp} blk={blk} Hq={h} Hkv={hkv} hd={hd}")

    # weight-plane microbench: fused decompress_matmul on a packed (K, N)
    # weight vs the pure-JAX unpack-then-matmul reference, decode-shaped
    # activations (M = slot count)
    from repro.kernels import decompress_matmul as dm
    M, K, N, wk = n_s, 128, 256, 5
    wmat = jnp.asarray(rng.normal(0, 0.05, (K, N)), jnp.bfloat16)
    signman, planes, dict_syms, nesc = kref.compress_weight_2d(wmat, k=wk)
    assert nesc == 0
    x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.bfloat16)
    fused_w = jax.jit(lambda x_: dm.decompress_matmul(
        x_, signman, planes, dict_syms, k=wk,
        interpret=not kops.on_tpu()))
    pure_w = jax.jit(lambda x_: kref.decompress_matmul_ref(
        x_, signman, planes, dict_syms, k=wk))
    for name, fn in (("decompress_matmul_fused", fused_w),
                     ("decompress_matmul_ref", pure_w)):
        us = timeit(fn, x, iters=3)
        rows[name] = us
        emit(f"decode_kernel.weights.{name}", us,
             f"M={M} K={K} N={N} k={wk}")
    out = {"bench": "decode_kernel",
           "backend": "interpret" if not kops.on_tpu() else "pallas",
           "jax_backend": jax.default_backend(),
           "shape": {"slots": n_s, "maxp": maxp, "block": blk, "heads": h,
                     "kv_heads": hkv, "head_dim": hd,
                     "weight_matmul": {"M": M, "K": K, "N": N, "k": wk}},
           "us_per_call": rows}
    path = _repo_root() / "BENCH_decode_kernel.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    emit("decode_kernel.json", 0.0, f"wrote {path.name}")


def bench_codec_throughput() -> None:
    """Host codec throughput (numpy oracle; context for checkpoint costs)."""
    w = common.weight_stream(PAPER_MODELS[0], max_elems=1_000_000)
    u16 = entropy.to_bf16_u16(w)
    t0 = time.perf_counter()
    blob = bitstream.compress_bf16(u16)
    enc_s = time.perf_counter() - t0
    emit("codec.lexih.encode.1M", enc_s * 1e6,
         f"{u16.nbytes / enc_s / 1e6:.0f} MB/s ratio="
         f"{u16.nbytes / len(blob):.2f}x")


ALL = {
    "fig1": fig1_entropy,
    "table2": table2_compression_ratio,
    "table3": table3_comm_latency,
    "fig7": fig7_e2e_latency,
    "fig4": fig4_cache_hit_rate,
    "fig5": fig5_codebook_latency,
    "fig6": fig6_decoder_dse,
    "table4": table4_area_power,
    "kernels": bench_kernels,
    "serving": bench_serving,
    "decode_kernel": bench_decode_kernel,
    "codec": bench_codec_throughput,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast pass (CI wiring check): shrinks the "
                         "serving scenario and skips BENCH_*.json writes")
    ap.add_argument("--socket", action="store_true",
                    help="serving bench: also run the disagg scenario over "
                         "SocketTransport against a decode host spawned in "
                         "a second OS process (localhost TCP)")
    ap.add_argument("--store-pages", type=int, default=4096,
                    help="serving bench: LRU cap (pages) for the content-"
                         "addressed stores (transport digest store + "
                         "PageCache warm tier)")
    ap.add_argument("--trace-out", default=None,
                    help="serving bench: write the disagg scenario's "
                         "Chrome trace-event JSON here (the last disagg "
                         "scenario wins — under --socket that is the "
                         "two-process run); check with "
                         "scripts/trace_summary.py")
    args = ap.parse_args()
    global SMOKE, SOCKET, STORE_PAGES, TRACE_OUT
    SMOKE = args.smoke
    SOCKET = args.socket
    STORE_PAGES = args.store_pages
    TRACE_OUT = args.trace_out
    names = args.only.split(",") if args.only else list(ALL)
    print("name,us_per_call,derived")
    for n in names:
        ALL[n]()


if __name__ == "__main__":
    main()
