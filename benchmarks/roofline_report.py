"""Render dry-run JSON results as the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m benchmarks.roofline_report results/*.json
"""

from __future__ import annotations

import json
import sys


def fmt(v, unit=""):
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        for s, n in (("T", 1e12), ("G", 1e9), ("M", 1e6), ("k", 1e3)):
            if abs(v) >= n:
                return f"{v / n:.2f}{s}{unit}"
        return f"{v:.3g}{unit}"
    return str(v)


def render(paths):
    rows = []
    for p in paths:
        rows.extend(json.load(open(p)))
    hdr = ("| arch | shape | mesh | dom | compute_s | memory_s | coll_s | "
           "ideal_s | roofline | useful | note |")
    sep = "|" + "---|" * 11
    print(hdr)
    print(sep)
    for r in rows:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip | - | "
                  f"- | - | - | - | - | {r['reason'][:40]}... |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | - "
                  f"| - | - | - | - | - | {r.get('error', '')[:40]} |")
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
              f"{r['dominant'][:4]} | {r['compute_s']:.2e} | "
              f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
              f"{r['ideal_s']:.2e} | {r['roofline_fraction']:.3f} | "
              f"{r['useful_flops_ratio']:.2f} | "
              f"compile {r['compile_s']:.0f}s |")


if __name__ == "__main__":
    render(sys.argv[1:])
