"""Shared benchmark utilities: realistic tensor sources + CSV emission.

Weights are synthesized per-layer from the arch configs (random init — the
exponent statistics match trained checkpoints, see DESIGN §1 calibration);
activations/caches come from actually RUNNING the reduced models on the
synthetic pipeline, so the profiled streams are real model intermediates.
"""

from __future__ import annotations

import time
from typing import Dict, Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, make_reduced
from repro.configs.base import MeshConfig, RunConfig
from repro.models import lm, params as PM

RNG = np.random.default_rng(0)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def timeit(fn, *args, iters: int = 5) -> float:
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / iters * 1e6


def weight_stream(arch: str, max_elems: int = 2_000_000) -> np.ndarray:
    """Concatenated sample of the arch's (reduced) weight tensors, bf16-f32."""
    cfg = make_reduced(get_config(arch))
    table = lm.lm_table(cfg, MeshConfig(1, 1, 1), RunConfig())
    params = PM.init_params(table, jax.random.key(1))
    parts: List[np.ndarray] = []
    tot = 0
    for leaf in jax.tree_util.tree_leaves(params):
        if leaf.dtype == jnp.bfloat16 and leaf.size > 256:
            a = np.asarray(leaf.astype(jnp.float32)).reshape(-1)
            parts.append(a)
            tot += a.size
            if tot >= max_elems:
                break
    return np.concatenate(parts)[:max_elems]


def activation_streams(arch: str, batch: int = 2, seq: int = 64
                       ) -> Dict[str, np.ndarray]:
    """Run the reduced model and capture real hidden-state/cache streams."""
    from jax.sharding import PartitionSpec as P
    from repro.core import collectives as cl
    cfg = make_reduced(get_config(arch))
    mesh_cfg = MeshConfig(1, 1, 1)
    run = RunConfig()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    params = PM.init_params(table, jax.random.key(1))
    pspecs = PM.param_pspecs(table)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (batch, seq)),
                       jnp.int32)
    kwargs = {}
    if cfg.frontend == "vision_stub":
        kwargs["front_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (batch, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.encdec:
        kwargs["enc_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (batch, seq, cfg.d_model)), jnp.bfloat16)

    def fwd(pp, t, kw):
        x, caches, _ = lm.lm_forward(cfg, run, pp, t, 1, dims=dims,
                                     want_cache=True, **kw)
        return x, caches

    kspecs = {k: P(None) for k in kwargs}
    f = jax.jit(cl.shmap(fwd, mesh, (pspecs, P(None), kspecs),
                         (P(None), P(None))))
    x, caches = f(params, toks, kwargs)
    out = {"activations": np.asarray(x.astype(jnp.float32)).reshape(-1)}
    if caches:
        flat = [np.asarray(l.astype(jnp.float32)).reshape(-1)
                for l in jax.tree_util.tree_leaves(caches)
                if hasattr(l, "dtype") and l.dtype in (jnp.bfloat16,)]
        if flat:
            out["cache"] = np.concatenate(flat)
    return out
