"""Full language models (decoder-only, encoder-decoder, multimodal stubs).

All forward functions run INSIDE shard_map with mesh axes ("data", "model")
and optionally "pod".  Boundary activations are (B_loc, S_loc, D):
batch over ("pod","data"), sequence over "model".

The vocabulary is padded to a multiple of tp*128 and column-sharded; the
cross-entropy is computed vocab-sharded in sequence chunks (never
materializing full logits), with padded columns masked to -inf.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MeshConfig, ModelConfig, RunConfig
from repro.core import collectives as cl
from . import attention, blocks, layers
from .params import (PDef, apply_fsdp, fsdp_dims, param_pspecs, stack, tmap)

AUX_LOSS_COEF = 0.01


# ---------------------------------------------------------------------------
# tables
# ---------------------------------------------------------------------------

def lm_table(cfg: ModelConfig, mesh: MeshConfig, run: RunConfig) -> Dict:
    tp = mesh.model
    vp = cfg.padded_vocab(tp)
    d = cfg.d_model
    # fsdp strategy: blocks are built UNSHARDED over model (tp_eff=1) and
    # then sharded over ("data","model") as pure parameter storage;
    # embeddings stay vocab-sharded over model (the sharded embed/xent
    # machinery is layout-compatible with both strategies).
    tp_blocks = 1 if run.tp_strategy == "fsdp" else tp
    t: Dict[str, Any] = {
        "embed": PDef((vp, d), ("model", None), "normal:0.02"),
        "final_norm": PDef((d,), (None,), "ones"),
    }
    if cfg.encdec:
        t["enc_blocks"] = stack(blocks.block_table(cfg, tp_blocks),
                                cfg.n_layers)
        t["enc_norm"] = PDef((d,), (None,), "ones")
        t["blocks"] = stack(blocks.block_table(cfg, tp_blocks, cross=True),
                            cfg.n_layers)
    else:
        t["blocks"] = stack(blocks.block_table(cfg, tp_blocks), cfg.n_layers)
    if not cfg.tie_embeddings:
        t["lm_head"] = PDef((d, vp), (None, "model"), "normal:0.02")
    if run.tp_strategy == "fsdp":
        # block tables were built at tp_eff=1 but still carry "model" specs;
        # strip them (storage sharding comes from the FSDP pass instead).
        for key in ("blocks", "enc_blocks"):
            if key in t:
                t[key] = _strip_model_specs(t[key])
        t = apply_fsdp_tree(t, mesh, run,
                            axes=("data", "model") if mesh.data > 1
                            else ("model",))
    elif run.fsdp and mesh.data > 1:
        t = apply_fsdp_tree(t, mesh, run)
    return t


def lm_fsdp_dims(table: Dict) -> Dict:
    """Static pytree of FSDP gather dims, passed alongside params at runtime
    (params are plain arrays inside shard_map, so the dims travel as a
    parallel static structure)."""
    out: Dict[str, Any] = {}
    for key in ("blocks", "enc_blocks"):
        if key in table:
            out[key] = fsdp_dims(table[key])
    for key in ("embed", "lm_head"):
        out[key] = table[key].fsdp_dim if key in table else None
    return out


def _strip_model_specs(table):
    import dataclasses

    def one(d: PDef) -> PDef:
        spec = tuple(None if sp == "model" else sp for sp in d.spec)
        return dataclasses.replace(d, spec=spec)

    return tmap(one, table)


def apply_fsdp_tree(t, mesh: MeshConfig, run: RunConfig, axes=("data",)):
    sizes = {"data": mesh.data, "model": mesh.model, "pod": mesh.pod}
    n = 1
    for a in axes:
        n *= sizes[a]
    out = dict(t)
    for key in ("blocks", "enc_blocks"):
        if key in out:
            out[key] = _fsdp_skip_scan_dim(out[key], n, axes, run)
    for key in ("embed", "lm_head"):
        if key in out and run.tp_strategy != "fsdp":
            out[key] = apply_fsdp({"x": out[key]}, ("data",), mesh.data,
                                  run.fsdp_min_size)["x"]
    return out


def _fsdp_skip_scan_dim(table, n: int, axes, run: RunConfig):
    """apply_fsdp over ``axes``, but never on the scan (leading) dim."""
    import dataclasses

    def one(d: PDef) -> PDef:
        size = int(np.prod(d.shape))
        if size < run.fsdp_min_size:
            return d
        cands = [(dim, s) for dim, (s, sp) in
                 enumerate(zip(d.shape, d.spec))
                 if dim > 0 and sp is None and s % n == 0 and s > 1]
        if not cands:
            return d
        dim = max(cands, key=lambda c: c[1])[0]
        entry = axes[0] if len(axes) == 1 else tuple(axes)
        spec = tuple(entry if i == dim else sp
                     for i, sp in enumerate(d.spec))
        return dataclasses.replace(d, spec=spec, fsdp_dim=dim)

    return tmap(one, table)


# ---------------------------------------------------------------------------
# embedding / unembedding (vocab-sharded)
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, run: RunConfig, table: jax.Array,
                 tokens: jax.Array, tp: int, scatter: bool = False) -> jax.Array:
    """Vocab-sharded embedding lookup.

    Each shard holds v_loc table rows and contributes *partial* embeddings
    (zero for tokens outside its vocab range); the partials are combined
    over "model".  IMPORTANT: ``tokens`` must be identical on every model
    shard (full sequence) — the combine sums vocab shards, so per-shard
    token slices would mix positions.  With ``scatter=True`` the combine is
    a psum_scatter along the sequence dim, returning the (B, S/tp, D)
    sequence-sharded layout directly (train/prefill); with ``scatter=False``
    a plain psum returns (B, S, D) replicated (decode: S=1).
    """
    v_loc = table.shape[0]
    off = jax.lax.axis_index("model") * v_loc
    idx = tokens.astype(jnp.int32) - off
    ok = (idx >= 0) & (idx < v_loc)
    emb = jnp.take(table, jnp.clip(idx, 0, v_loc - 1), axis=0)
    # vocab shards are disjoint (exactly one nonzero contributor per token),
    # so a bf16 combine is exact and halves the wire bytes.
    emb = jnp.where(ok[..., None], emb, 0).astype(jnp.bfloat16)
    if scatter:
        out = jax.lax.psum_scatter(emb, "model", scatter_dimension=1,
                                   tiled=True)
    else:
        out = jax.lax.psum(emb, "model")
    out = out.astype(jnp.float32)
    if cfg.scale_embeddings:                      # gemma2 scales embeddings
        out = out * jnp.sqrt(float(cfg.d_model))
    return out.astype(jnp.bfloat16)


def chunked_xent(cfg: ModelConfig, run: RunConfig, x: jax.Array,
                 head: jax.Array, labels: jax.Array, tp: int) -> jax.Array:
    """Vocab-sharded cross entropy, seq-chunked.

    x (B,S_loc,D) bf16; head (D, V_loc); labels (B,S_loc).  Returns the
    local *sum* of token losses (caller psums and normalizes).
    """
    b, s_loc, d = x.shape
    v_loc = head.shape[1]
    off = jax.lax.axis_index("model") * v_loc
    col = jnp.arange(v_loc)
    col_ok = (off + col) < cfg.vocab_size
    c = min(run.loss_chunk, s_loc)
    nc = s_loc // c
    assert s_loc % c == 0

    def step(acc, inp):
        xc, lc = inp                                   # (B,c,D), (B,c)
        logits = layers.matmul_f32(xc, head)
        if cfg.final_softcap is not None:
            logits = layers.softcap(logits, cfg.final_softcap)
        logits = jnp.where(col_ok[None, None, :], logits, layers.NEG_INF)
        # pmax has no AD rule; the max shift is gradient-free anyway, so cut
        # the tangent *before* the collective (symbolic-zero skips the rule).
        mx = jax.lax.pmax(jax.lax.stop_gradient(logits.max(-1)), "model")
        se = jax.lax.psum(jnp.exp(logits - mx[..., None]).sum(-1), "model")
        idx = lc.astype(jnp.int32) - off
        ok = (idx >= 0) & (idx < v_loc)
        tgt = jnp.take_along_axis(
            logits, jnp.clip(idx, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
        tgt = jax.lax.psum(jnp.where(ok, tgt, 0.0), "model")
        loss = jnp.log(se) + mx - tgt
        return acc + loss.sum(), None

    xc = x.reshape(b, nc, c, d).swapaxes(0, 1)
    lc = labels.reshape(b, nc, c).swapaxes(0, 1)
    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (xc, lc))
    return total


def logits_for(cfg: ModelConfig, run: RunConfig, params, dims,
               x: jax.Array) -> jax.Array:
    """Final-position logits (decode): x (B,1,D) -> (B,1,V_loc) local.

    Padded vocab columns are masked to -inf (they hold random-init weights;
    without the mask greedy decode can emit out-of-vocab ids).
    """
    head = gathered_head(cfg, params, dims, run)
    logits = layers.matmul_f32(x, head)
    if cfg.final_softcap is not None:
        logits = layers.softcap(logits, cfg.final_softcap)
    v_loc = head.shape[1]
    col_ok = (jax.lax.axis_index("model") * v_loc
              + jnp.arange(v_loc)) < cfg.vocab_size
    return jnp.where(col_ok[None, None, :], logits, layers.NEG_INF)


# ---------------------------------------------------------------------------
# forward (train / prefill trunk)
# ---------------------------------------------------------------------------

def _scan_blocks(cfg: ModelConfig, run: RunConfig, stacked, dims, x,
                 positions_full, spec, tp, windows=None, memory=None,
                 mem_positions=None, want_cache: bool = False,
                 local: bool = False, cache_stores=None, cache_xform=None):
    """Scan the (stacked) blocks; returns (x, stacked caches, aux sum).

    ``cache_stores``/``cache_xform``: when building a decode cache, the raw
    per-layer KV is transformed (resharded + LEXI-block-compressed) INSIDE
    the scan body — materializing all layers' raw KV first would need
    L x seq x heads bf16 of HBM (tens of GB/chip at 32k prefill).
    """

    def body(carry, xs):
        xb, aux = carry
        p_layer, win, store = xs
        p_layer = blocks.gather_fsdp(p_layer, dims, run)
        xb, cache, a = blocks.block_forward(
            cfg, run, p_layer, xb, positions_full, spec, tp, window=win,
            memory=memory, mem_positions=mem_positions,
            want_cache=want_cache, local=local)
        if cache_xform is not None:
            cache = cache_xform(cache, store)
        return (xb, aux + a), cache

    body_fn = jax.checkpoint(body) if run.remat else body
    wins = (windows if windows is not None
            else jnp.zeros((cfg.n_layers,), jnp.int32))
    (x, aux), caches = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                    (stacked, wins, cache_stores))
    return x, caches, aux


def gathered_embed(params, dims, run: RunConfig) -> jax.Array:
    """Embedding table with its FSDP shard gathered (compressed) if needed."""
    e = params["embed"]
    if dims and dims.get("embed") is not None:
        e = blocks.gather_fsdp(e, dims["embed"], run, in_scan=False)
    return e


def gathered_head(cfg: ModelConfig, params, dims, run: RunConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return gathered_embed(params, dims, run).T
    h = params["lm_head"]
    if dims and dims.get("lm_head") is not None:
        h = blocks.gather_fsdp(h, dims["lm_head"], run, in_scan=False)
    return h


def lm_forward(cfg: ModelConfig, run: RunConfig, params, tokens: jax.Array,
               tp: int, dims: Optional[Dict] = None,
               front_embeds: Optional[jax.Array] = None,
               enc_embeds: Optional[jax.Array] = None,
               want_cache: bool = False, cache_stores=None,
               cache_xform=None):
    """Trunk forward.  tokens (B_loc, S) full-seq (each shard slices its part).

    Returns (hidden (B,S_loc,D), caches or None, aux).
    ``dims`` is the static FSDP-dims pytree from ``lm_fsdp_dims``.
    """
    b, s = tokens.shape
    s_loc = s // tp
    ti = jax.lax.axis_index("model")
    positions_full = jnp.arange(s, dtype=jnp.int32)
    spec = attention.base_attn_spec(cfg)
    wins = attention.layer_windows(cfg)
    wins = None if wins is None else jnp.asarray(wins)

    # full-sequence tokens in, sequence-sharded embeddings out (see note in
    # embed_tokens: the vocab-shard combine must see identical tokens).
    x = embed_tokens(cfg, run, gathered_embed(params, dims, run), tokens, tp,
                     scatter=True)

    # fsdp strategy: reshard seq-sharded -> batch-sharded over "model"
    # (one a2a); blocks then run with zero model-axis collectives, weights
    # arriving via compressed ZeRO-3 gathers instead.
    fsdp_mode = run.tp_strategy == "fsdp" and tp > 1
    if fsdp_mode:
        assert b % tp == 0, (
            f"tp_strategy=fsdp needs per-data-shard batch {b} divisible by "
            f"model={tp}")
        x = jax.lax.all_to_all(x, "model", split_axis=0, concat_axis=1,
                               tiled=True)            # (B/tp, S, D)

    if cfg.frontend == "vision_stub" and front_embeds is not None:
        pos = ti * s_loc + jnp.arange(s_loc)
        nf = cfg.n_frontend_tokens
        fe = jnp.take(front_embeds, jnp.clip(pos, 0, nf - 1), axis=1)
        x = jnp.where((pos < nf)[None, :, None], fe.astype(x.dtype), x)

    memory = mem_pos = None
    if cfg.encdec:
        # encoder trunk on frame embeddings (audio stub) or token embeds
        assert enc_embeds is not None, "encdec needs encoder inputs"
        sm = enc_embeds.shape[1]
        sm_loc = sm // tp
        ex = jax.lax.dynamic_slice_in_dim(enc_embeds, ti * sm_loc, sm_loc,
                                          axis=1).astype(jnp.bfloat16)
        espec = layers.AttnSpec(causal=False, softcap=cfg.attn_softcap)
        edims = dims.get("enc_blocks") if dims else None
        ex, _, _ = _scan_blocks(cfg, run, params["enc_blocks"], edims, ex,
                                jnp.arange(sm, dtype=jnp.int32), espec, tp)
        ex = layers.rms_norm(ex, params["enc_norm"], cfg.norm_eps)
        memory = cl.lexi_all_gather(ex, "model", run.codec, gather_axis=1)
        mem_pos = jnp.arange(sm, dtype=jnp.int32)

    bdims = dims.get("blocks") if dims else None
    tp_eff = 1 if fsdp_mode else tp
    x, caches, aux = _scan_blocks(cfg, run, params["blocks"], bdims, x,
                                  positions_full, spec, tp_eff, windows=wins,
                                  memory=memory, mem_positions=mem_pos,
                                  want_cache=want_cache, local=fsdp_mode,
                                  cache_stores=cache_stores,
                                  cache_xform=cache_xform)
    if fsdp_mode:   # back to seq-sharded for the vocab-sharded loss
        x = jax.lax.all_to_all(x, "model", split_axis=1, concat_axis=0,
                               tiled=True)            # (B, S/tp, D)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, caches, aux


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def train_loss(cfg: ModelConfig, run: RunConfig, params, batch: Dict,
               tp: int, batch_axes: Tuple[str, ...],
               dims: Optional[Dict] = None) -> jax.Array:
    """LOCAL shard contribution to the global mean next-token loss.

    Deliberately contains NO loss-reduction collectives: under shard_map,
    ``transpose(psum) = psum`` re-sums unit cotangents across shards and
    scales gradients by the shard count.  Each shard therefore returns its
    own (batch-slice × seq-slice) token-loss sum normalized by the *global*
    token count; summing the returned value over every mesh axis gives the
    true global mean (``train.train_step`` does that, outside AD), and the
    per-leaf gradient psums live in ``train.optimizer.sync_grads``.
    """
    tokens, labels = batch["tokens"], batch["labels"]
    x, _, aux = lm_forward(cfg, run, params, tokens, tp, dims=dims,
                           front_embeds=batch.get("front_embeds"),
                           enc_embeds=batch.get("enc_embeds"))
    b, s = tokens.shape
    s_loc = s // tp
    ti = jax.lax.axis_index("model")
    lab_loc = jax.lax.dynamic_slice_in_dim(labels, ti * s_loc, s_loc, axis=1)
    head = gathered_head(cfg, params, dims, run)
    local_sum = chunked_xent(cfg, run, x, head, lab_loc, tp)
    n_tokens = b * s
    n_shards = tp
    for a in batch_axes:                      # static mesh sizes
        size = jax.lax.psum(1, a)
        n_tokens = n_tokens * size
        n_shards = n_shards * size
    loss = local_sum / n_tokens
    # aux is a per-shard statistic; normalize so the all-axes sum is the
    # shard-mean per layer.
    return loss + AUX_LOSS_COEF * aux / (n_shards * max(cfg.n_layers, 1))
