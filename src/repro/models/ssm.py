"""Mamba2 (SSD — state-space duality) blocks, manual-SPMD.

TP: heads (d_inner) column-sharded over "model"; the shared B/C projections
(ngroups=1) are row-parallel + psum like the GQA row mode; out_proj is
row-sharded so the caller psum_scatters the block output.

Train/prefill uses the chunked SSD algorithm (quadratic-within-chunk +
linear-across-chunks); decode is the O(1) recurrent update on the fixed-size
state — the "state cache" half of the paper's hybrid caches, which LEXI
compresses between steps.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from . import layers
from .params import PDef


def ssm_dims(cfg: ModelConfig, tp: int):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    assert nh % tp == 0, (nh, tp)
    return di, nh, s.headdim, s.d_state


def ssm_table(cfg: ModelConfig, tp: int) -> Dict[str, PDef]:
    d = cfg.d_model
    s = cfg.ssm
    di, nh, _, n = ssm_dims(cfg, tp)
    return {
        "w_zx": PDef((d, 2 * di), (None, "model")),
        "w_bc": PDef((d, 2 * n), ("model", None)),
        "w_dt": PDef((d, nh), (None, "model")),
        "dt_bias": PDef((nh,), ("model",), "zeros"),
        "a_log": PDef((nh,), ("model",), "zeros"),       # A = -exp(a_log)
        "d_skip": PDef((nh,), ("model",), "ones"),
        "conv_x": PDef((s.d_conv, di), (None, "model"), "normal:0.1"),
        "conv_bc": PDef((s.d_conv, 2 * n), (None, None), "normal:0.1"),
        "gate_norm": PDef((di,), ("model",), "ones"),
        "w_out": PDef((di, d), ("model", None)),
    }


class SSMState(NamedTuple):
    """Decode-phase recurrent state (the paper's SSM "state cache")."""
    h: jax.Array          # (B, H_loc, P, N) f32
    conv_x: jax.Array     # (B, d_conv-1, di_loc) bf16 ring
    conv_bc: jax.Array    # (B, d_conv-1, 2N) bf16


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq: x (B,S,C), w (K,C) -> (B,S,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):                                 # unrolled small K
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return jax.nn.silu(out).astype(jnp.bfloat16)


def _proj_bc(cfg: ModelConfig, p, xg: jax.Array, tp: int) -> jax.Array:
    """Row-parallel shared B/C projection (B,S,2N) + psum (local at tp=1)."""
    if tp == 1:
        return layers.matmul_f32(xg, p["w_bc"]).astype(jnp.bfloat16)
    dsh = cfg.d_model // tp
    i = jax.lax.axis_index("model") * dsh
    xs = jax.lax.dynamic_slice_in_dim(xg, i, dsh, axis=-1)
    return jax.lax.psum(
        layers.matmul_f32(xs, p["w_bc"]), "model").astype(jnp.bfloat16)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x (B,S,H,P); dt (B,S,H) post-softplus; a (H,) negative; b/c (B,S,N)
    shared across heads (ngroups=1).  Returns (y (B,S,H,P), final state
    (B,H,P,N) f32).
    """
    bs, s, h, p_ = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:  # zero-pad the tail: dt=0 ⇒ decay 1, contribution 0 (exact)
        zc = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                               [(0, 0)] * (a.ndim - 2))
        x, dt, b, c = zc(x), zc(dt), zc(b), zc(c)
    s_p = s + pad
    nc = s_p // q

    xf = x.astype(jnp.float32).reshape(bs, nc, q, h, p_)
    dtf = dt.astype(jnp.float32).reshape(bs, nc, q, h)
    bf = b.astype(jnp.float32).reshape(bs, nc, q, n)
    cf = c.astype(jnp.float32).reshape(bs, nc, q, n)
    da = dtf * a                                        # (B,nc,Q,H) <= 0
    lcum = jnp.cumsum(da, axis=2)                       # within-chunk logdecay

    # intra-chunk: Y[t] = sum_{s<=t} (C_t.B_s) exp(l_t-l_s) dt_s x_s
    g = jnp.einsum("bcqn,bckn->bcqk", cf, bf)           # (B,nc,Q,Q)
    ldiff = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(ldiff), 0.0)
    y_intra = jnp.einsum("bcqk,bcqkh,bckh,bckhp->bcqhp",
                         g, decay, dtf, xf)

    # chunk states: S_c = sum_t B_t (dt_t x_t) exp(l_Q - l_t)
    tail = jnp.exp(lcum[:, :, -1:, :] - lcum)           # (B,nc,Q,H)
    st = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", bf, dtf * tail, xf)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(lcum[:, :, -1, :])            # (B,nc,H)

    def step(hprev, inp):
        dec, s_c = inp                                  # (B,H), (B,H,N,P)
        hnew = hprev * dec[..., None, None] + s_c
        return hnew, hprev

    h0 = jnp.zeros((bs, h, n, p_), jnp.float32)
    hlast, hprevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(st, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)                 # (B,nc,H,N,P)

    # inter-chunk contribution: C_t . h_{c-1} * exp(l_t)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         cf, jnp.exp(lcum), hprevs)
    y = (y_intra + y_inter).reshape(bs, s_p, h, p_)[:, :s]
    return y.astype(jnp.bfloat16), jnp.moveaxis(hlast, -1, -2)  # (B,H,P,N)


def ssm_forward(cfg: ModelConfig, run: RunConfig, p, xg: jax.Array,
                tp: int, want_state: bool = False):
    """Full-sequence SSD block.  xg (B,S,D) gathered; returns partial-sum
    output (B,S,D) f32 (caller psum_scatters) and optionally the final
    recurrent state for the prefill→decode transition."""
    di, nh, hd, n = ssm_dims(cfg, tp)
    nh_loc = nh // tp
    di_loc = di // tp
    bs, s, _ = xg.shape

    zx = layers.pdot(xg, p["w_zx"])                     # (B,S,2*di_loc)
    z, xin = zx[..., :di_loc], zx[..., di_loc:]
    dt = layers.matmul_f32(xg, p["w_dt"])               # (B,S,nh_loc)
    bc = _proj_bc(cfg, p, xg, tp)                       # (B,S,2N)

    # depthwise causal conv (+silu) on x and shared B/C; keep the raw tails
    # (pre-conv) for the decode-phase conv ring buffers.  conv weights are
    # consumed by slice/broadcast, not matmul -> raw_weight (decoded
    # in-graph if the store packed them)
    ti = jax.lax.axis_index("model") if tp > 1 else 0
    convx_w = jax.lax.dynamic_slice_in_dim(
        layers.raw_weight(p["conv_x"]), ti * di_loc, di_loc, axis=1)
    xin_raw, bc_raw = xin, bc
    xin = _causal_conv(xin, convx_w)
    bc = _causal_conv(bc, layers.raw_weight(p["conv_bc"]))
    b_, c_ = bc[..., :n], bc[..., n:]

    a = -jnp.exp(p["a_log"].astype(jnp.float32))        # (nh_loc,)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))
    xh = xin.reshape(bs, s, nh_loc, hd)
    y, state = ssd_chunked(xh, dt, a, b_, c_, cfg.ssm.chunk)
    y = y + xh.astype(jnp.bfloat16) * p["d_skip"].astype(jnp.bfloat16)[
        None, None, :, None]
    y = y.reshape(bs, s, di_loc)
    y = layers.rms_norm(
        y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)),
        p["gate_norm"], cfg.norm_eps)
    out = layers.matmul_f32(y, p["w_out"])              # partial over model

    if not want_state:
        return out, None
    k = cfg.ssm.d_conv - 1
    st = SSMState(h=state,
                  conv_x=xin_raw[:, s - k:, :],
                  conv_bc=bc_raw[:, s - k:, :])
    return out, st


def ssm_decode_step(cfg: ModelConfig, p, x: jax.Array, state: SSMState,
                    tp: int) -> Tuple[jax.Array, SSMState]:
    """One-token recurrent update.  x (B,1,D) full; returns partial-sum
    output (B,1,D) f32 and the new state.

    Note: conv ring buffers store *pre-activation* inputs; the prefill
    transition stores the raw tail (see engine), so semantics match.
    """
    di, nh, hd, n = ssm_dims(cfg, tp)
    nh_loc, di_loc = nh // tp, di // tp
    bs = x.shape[0]

    zx = layers.pdot(x, p["w_zx"])
    z, xin = zx[..., :di_loc], zx[..., di_loc:]         # (B,1,di_loc)
    dt = layers.matmul_f32(x, p["w_dt"])[:, 0]          # (B,nh_loc)
    if tp == 1:
        bc = layers.matmul_f32(x, p["w_bc"]).astype(jnp.bfloat16)
    else:
        dsh = cfg.d_model // tp
        i = jax.lax.axis_index("model") * dsh
        xs = jax.lax.dynamic_slice_in_dim(x, i, dsh, axis=-1)
        bc = jax.lax.psum(layers.matmul_f32(xs, p["w_bc"]),
                          "model").astype(jnp.bfloat16)     # (B,1,2N)

    # conv ring update (pre-activation inputs in the ring)
    ti = jax.lax.axis_index("model") if tp > 1 else 0
    convx_w = jax.lax.dynamic_slice_in_dim(
        layers.raw_weight(p["conv_x"]), ti * di_loc, di_loc, axis=1)
    ring_x = jnp.concatenate([state.conv_x, xin], axis=1)   # (B,K,di_loc)
    ring_bc = jnp.concatenate([state.conv_bc, bc], axis=1)
    xin_c = jax.nn.silu(jnp.einsum(
        "bkc,kc->bc", ring_x.astype(jnp.float32),
        convx_w.astype(jnp.float32)))[:, None]              # (B,1,di_loc)
    bc_c = jax.nn.silu(jnp.einsum(
        "bkc,kc->bc", ring_bc.astype(jnp.float32),
        layers.raw_weight(p["conv_bc"]).astype(jnp.float32)))[:, None]
    b_, c_ = bc_c[..., :n], bc_c[..., n:]

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))  # (B,nh_loc)
    xh = xin_c.reshape(bs, nh_loc, hd).astype(jnp.float32)
    decay = jnp.exp(dt * a)                              # (B,nh_loc)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, b_[:, 0].astype(jnp.float32))
    h = state.h * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, c_[:, 0].astype(jnp.float32))
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bs, 1, di_loc)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)),
                        p["gate_norm"], cfg.norm_eps)
    out = layers.matmul_f32(y, p["w_out"])
    new = SSMState(h=h, conv_x=ring_x[:, 1:], conv_bc=ring_bc[:, 1:])
    return out, new
