"""Transformer / SSM / hybrid blocks and the layer-scan driver.

A "block" is one residual layer.  Block families:

  dense   : attn + (SwiGLU) MLP
  moe     : attn + MoE FFN (EP dispatch)
  ssm     : Mamba2 mixer only (mamba2-370m)
  hybrid  : attn ∥ Mamba2 in parallel on the same input (hymba) + MLP

All blocks keep boundary activations sequence-sharded (B, S_loc, D) and use

  lexi_all_gather  (compressed)  at entry to full-sequence mixers,
  psum_scatter     (raw — it sums) back to the boundary layout,

which is precisely the paper's egress-compress / ingress-decompress placement
mapped onto Megatron-SP transition points.

Layers are scanned (one compiled block regardless of depth); per-layer
heterogeneity (gemma2 local/global windows, hymba global layers) travels as
scan *data* (traced window sizes), not structure.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core import collectives as cl
from . import attention, layers, moe as moe_mod, ssm as ssm_mod
from .params import PDef, fsdp_dims, is_pdef


# ---------------------------------------------------------------------------
# tables
# ---------------------------------------------------------------------------

def mlp_table(cfg: ModelConfig, tp: int) -> Dict[str, PDef]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": PDef((d, f), (None, "model")),
        "w_up": PDef((d, f), (None, "model")),
        "w_down": PDef((f, d), ("model", None)),
    }


def block_table(cfg: ModelConfig, tp: int, cross: bool = False
                ) -> Dict[str, PDef]:
    """Parameter table for ONE layer (unstacked)."""
    d = cfg.d_model
    t: Dict[str, PDef] = {"ln1": PDef((d,), (None,), "ones")}
    has_attn = cfg.n_heads > 0
    has_ssm = cfg.ssm is not None
    if has_attn:
        t["attn"] = attention.attn_table(cfg, tp)
    if has_ssm:
        t["ssm"] = ssm_mod.ssm_table(cfg, tp)
    if cfg.post_norm:
        t["ln1b"] = PDef((d,), (None,), "ones")
    if cross:
        t["ln_x"] = PDef((d,), (None,), "ones")
        t["xattn"] = attention.attn_table(cfg, tp)
    # FFN (dense archs + hymba; pure-ssm has none; moe has its own)
    if cfg.moe is not None:
        t["ln2"] = PDef((d,), (None,), "ones")
        t["moe"] = moe_mod.moe_table(cfg, tp)
    elif cfg.d_ff and (has_attn or not has_ssm):
        t["ln2"] = PDef((d,), (None,), "ones")
        t["mlp"] = mlp_table(cfg, tp)
        if cfg.post_norm:
            t["ln2b"] = PDef((d,), (None,), "ones")
    return t


# ---------------------------------------------------------------------------
# FSDP gather inside the scan body
# ---------------------------------------------------------------------------

def fsdp_axes(run: RunConfig):
    """Mesh axes parameter shards live on (and are gathered over)."""
    return ("data", "model") if run.tp_strategy == "fsdp" else ("data",)


def gather_fsdp(params, dims, run: RunConfig, in_scan: bool = True):
    """All-gather (LEXI-compressed when codec.weights) the leaves that were
    FSDP-sharded.  ``dims`` indexes the *stacked* table, so a leaf sliced by
    scan shifts down by one.  With tp_strategy="fsdp" the gather spans
    ("data","model") — this is the paper's "transmit weights in compact
    lossless form" applied to ZeRO-3 traffic."""
    axes = fsdp_axes(run)

    def one(w, d):
        if d is None:
            return w
        ax = d - 1 if in_scan else d
        if run.codec.weights and w.dtype == jnp.bfloat16:
            return cl.lexi_all_gather(w, axes, run.codec, gather_axis=ax)
        return jax.lax.all_gather(w, axes, axis=ax, tiled=True)

    # PackedWeight leaves are never FSDP-sharded (serving meshes are
    # data=1): is_leaf stops the map from descending into their children,
    # which would misalign against dims' None.
    return jax.tree_util.tree_map(
        one, params, dims,
        is_leaf=lambda w: isinstance(w, layers.PackedWeight))


# ---------------------------------------------------------------------------
# single block forward (train/prefill)
# ---------------------------------------------------------------------------

def block_forward(cfg: ModelConfig, run: RunConfig, p, x: jax.Array,
                  positions_full: jax.Array, spec: layers.AttnSpec,
                  tp: int, window=None, memory: Optional[jax.Array] = None,
                  mem_positions: Optional[jax.Array] = None,
                  want_cache: bool = False, local: bool = False):
    """x (B,S_loc,D) seq-sharded -> (x', cache_bits, aux_loss).

    ``memory`` (B,Sm,D full, gathered once by the caller) enables the
    cross-attention path for encoder-decoder configs.  ``local=True``
    (tp_strategy="fsdp") means x is already the full sequence of this
    shard's batch slice and ALL model-axis collectives are skipped — the
    caller passed tp=1 and gathered the weights instead.
    """
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    has_attn = cfg.n_heads > 0
    has_ssm = cfg.ssm is not None

    # ---- mixer(s) --------------------------------------------------------
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    if has_attn or has_ssm:
        hg = h if local else cl.lexi_all_gather(h, "model", run.codec,
                                                gather_axis=1)
        partial = jnp.zeros(hg.shape, jnp.float32)
        if has_attn:
            o, kv = attention.attn_forward(cfg, run, p["attn"], hg,
                                           positions_full, spec, tp,
                                           window=window,
                                           want_cache=want_cache)
            partial = partial + o
            if want_cache:
                cache["kv"] = kv
        if has_ssm:
            o, st = ssm_mod.ssm_forward(cfg, run, p["ssm"], hg, tp,
                                        want_state=want_cache)
            partial = partial + o
            if want_cache:
                cache["ssm"] = st
        # reduce in bf16: halves RS wire bytes (industry-standard TP sum)
        out = (partial.astype(jnp.bfloat16) if local else
               jax.lax.psum_scatter(partial.astype(jnp.bfloat16), "model",
                                    scatter_dimension=1, tiled=True))
        if cfg.post_norm:
            out = layers.rms_norm(out, p["ln1b"], cfg.norm_eps)
        x = x + out

    # ---- cross attention (enc-dec decoder) -------------------------------
    if memory is not None:
        h = layers.rms_norm(x, p["ln_x"], cfg.norm_eps)
        hg = h if local else cl.lexi_all_gather(h, "model", run.codec,
                                                gather_axis=1)
        xspec = layers.AttnSpec(causal=False, softcap=None)
        o, xkv = cross_attn_forward(cfg, run, p["xattn"], hg, memory,
                                    positions_full, mem_positions, xspec,
                                    tp, want_cache=want_cache)
        out = (o.astype(jnp.bfloat16) if local else
               jax.lax.psum_scatter(o.astype(jnp.bfloat16), "model",
                                    scatter_dimension=1, tiled=True))
        x = x + out
        if want_cache:
            cache["xkv"] = xkv

    # ---- FFN --------------------------------------------------------------
    if "moe" in p:
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        y, aux = moe_mod.moe_forward(cfg, run, p["moe"], h, tp)
        x = x + y
    elif "mlp" in p:
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        hg = h if local else cl.lexi_all_gather(h, "model", run.codec,
                                                gather_axis=1)
        m = p["mlp"]
        act = layers.swiglu(layers.pdot(hg, m["w_gate"]),
                            layers.pdot(hg, m["w_up"]))
        y = layers.matmul_f32(act, m["w_down"])
        y = (y.astype(jnp.bfloat16) if local else
             jax.lax.psum_scatter(y.astype(jnp.bfloat16), "model",
                                  scatter_dimension=1, tiled=True))
        if cfg.post_norm:
            y = layers.rms_norm(y, p["ln2b"], cfg.norm_eps)
        x = x + y
    return x, cache, aux


def cross_attn_forward(cfg: ModelConfig, run: RunConfig, p, xg: jax.Array,
                       memory: jax.Array, q_pos, kv_pos,
                       spec: layers.AttnSpec, tp: int,
                       want_cache: bool = False):
    """Cross-attention: queries from xg, K/V from encoder memory."""
    hd = cfg.head_dim
    hq = cfg.padded_heads(tp)
    hq_loc = hq // tp
    nkv = cfg.n_kv_heads
    mode = attention.kv_mode(cfg, tp)
    q = layers.pdot(xg, p["wq"], p.get("bq"))
    b, s, _ = q.shape
    q = q.reshape(b, s, hq_loc, hd).transpose(0, 2, 1, 3)
    if mode == "col":
        k = layers.pdot(memory, p["wk"]).reshape(
            b, memory.shape[1], nkv // tp, hd).transpose(0, 2, 1, 3)
        v = layers.pdot(memory, p["wv"]).reshape(
            b, memory.shape[1], nkv // tp, hd).transpose(0, 2, 1, 3)
    else:
        dsh = cfg.d_model // tp
        i = jax.lax.axis_index("model") * dsh
        ms = jax.lax.dynamic_slice_in_dim(memory, i, dsh, axis=-1)
        k = jax.lax.psum(layers.matmul_f32(ms, p["wk"]),
                         "model").astype(jnp.bfloat16)
        v = jax.lax.psum(layers.matmul_f32(ms, p["wv"]),
                         "model").astype(jnp.bfloat16)
        k = k.reshape(b, -1, nkv, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, -1, nkv, hd).transpose(0, 2, 1, 3)
        ti = jax.lax.axis_index("model")
        g_real = max(cfg.n_heads // max(nkv, 1), 1)
        qidx = ti * hq_loc + jnp.arange(hq_loc)
        kv_idx = jnp.clip(qidx // g_real, 0, nkv - 1)
        k = jnp.take(k, kv_idx, axis=1)
        v = jnp.take(v, kv_idx, axis=1)
    out = layers.flash_attention(q, k, v, q_pos, kv_pos, spec,
                                 chunk_q=run.attn_chunk_q,
                                 chunk_kv=run.attn_chunk_kv)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq_loc * hd)
    o = layers.matmul_f32(out, p["wo"])
    return o, ((k, v) if want_cache else None)
