"""Hybrid caches (attention KV + SSM state), LEXI-block-compressed.

This is the paper's "hybrid cache" path: caches are compressed block-by-block
when written back to memory and decompressed just before use (§4.1).  The TPU
layout:

* the KV cache is **sequence-sharded over "model", interleaved**: shard t
  owns global positions {p : p % tp == t}.  Writes round-robin across shards
  (balanced), every shard holds ~len/tp live slots, and decode attention is
  a partial attention per shard merged with one tiny psum
  (``layers.merge_partials``) — no head-divisibility constraints ever.
* each full block of ``block`` owned slots is stored as a LEXI-FW
  ``Compressed`` (K and V of the block packed together); a bf16 ring buffer
  holds the in-flight block.  HBM-side cache traffic is the packed size.
* the decode step streams compressed blocks through a scan, decompressing
  one block at a time (the VMEM-sized working set of a fused kernel) with
  online-softmax accumulation.
* MLA caches the *latent* (c_kv ‖ k_rope) instead of K/V — LEXI compresses
  the latent stream (already 4-8x smaller than full KV: double win).
* the SSM state cache is the fixed-size recurrent state (f32 master for
  recurrence stability — see note at bottom).

With ``CodecConfig.cache=False`` blocks are stored raw bf16 with identical
structure, giving the A/B for the roofline.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core import fixed, packing
from repro.core.collectives import CodecConfig
from . import layers
from .ssm import SSMState


class KVBlocks(NamedTuple):
    """Per-layer, per-shard compressed KV block store.

    Payload width W = kv_width(cfg): 2*Hkv*hd for plain attention (K‖V),
    kv_lora+rope for MLA.  Block value shape: (B, block, W).
    """
    signman: Optional[jax.Array]    # (nblk, N) u8, N = B*block*W
    planes: Optional[jax.Array]     # (nblk, k, Npad/32) u32
    dict_syms: Optional[jax.Array]  # (nblk, 2^k) u8
    esc_pos: Optional[jax.Array]    # (nblk, C) i32
    esc_raw: Optional[jax.Array]    # (nblk, C) u8
    raw_blocks: Optional[jax.Array] # (nblk, B, block, W) bf16 when codec off
    ring: jax.Array                 # (B, block, W) bf16 in-flight block
    length: jax.Array               # () i32 global tokens written (all shards)


def kv_width(cfg: ModelConfig) -> int:
    if cfg.mla is not None:
        return cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
    return 2 * cfg.n_kv_heads * cfg.head_dim


def n_blocks(cfg: ModelConfig, run: RunConfig, max_len: int, tp: int) -> int:
    """Capacity in blocks per shard (prefill length + decode growth room)."""
    slots = max_len // tp
    return slots // run.codec.cache_block + 2


def empty_kv(cfg: ModelConfig, run: RunConfig, batch_loc: int, max_len: int,
             tp: int) -> KVBlocks:
    w = kv_width(cfg)
    blk = run.codec.cache_block
    nblk = n_blocks(cfg, run, max_len, tp)
    n = batch_loc * blk * w
    npad = packing.pad_to_lanes(n)
    c = run.codec.esc_capacity(n)
    k = run.codec.k
    if run.codec.cache:
        return KVBlocks(
            signman=jnp.zeros((nblk, n), jnp.uint8),
            planes=jnp.zeros((nblk, k, npad // 32), jnp.uint32),
            dict_syms=jnp.zeros((nblk, 1 << k), jnp.uint8),
            esc_pos=jnp.full((nblk, c), npad, jnp.int32),
            esc_raw=jnp.zeros((nblk, c), jnp.uint8),
            raw_blocks=None,
            ring=jnp.zeros((batch_loc, blk, w), jnp.bfloat16),
            length=jnp.zeros((), jnp.int32))
    return KVBlocks(signman=None, planes=None, dict_syms=None, esc_pos=None,
                    esc_raw=None,
                    raw_blocks=jnp.zeros((nblk, batch_loc, blk, w),
                                         jnp.bfloat16),
                    ring=jnp.zeros((batch_loc, blk, w), jnp.bfloat16),
                    length=jnp.zeros((), jnp.int32))


def store_block(kv: KVBlocks, idx, vals: jax.Array,
                codec: CodecConfig) -> KVBlocks:
    """Write one full block (B, blk, W) into slot ``idx``."""
    if codec.cache:
        ct = fixed.compress(vals, k=codec.k,
                            esc_capacity=codec.esc_capacity(vals.size))
        upd = jax.lax.dynamic_update_index_in_dim
        return kv._replace(
            signman=upd(kv.signman, ct.signman, idx, 0),
            planes=upd(kv.planes, ct.planes, idx, 0),
            dict_syms=upd(kv.dict_syms, ct.dict_syms, idx, 0),
            esc_pos=upd(kv.esc_pos, ct.esc_pos, idx, 0),
            esc_raw=upd(kv.esc_raw, ct.esc_raw, idx, 0))
    return kv._replace(raw_blocks=jax.lax.dynamic_update_index_in_dim(
        kv.raw_blocks, vals, idx, 0))


def load_block(kv: KVBlocks, idx, batch_loc: int, blk: int, w: int,
               codec: CodecConfig) -> jax.Array:
    if codec.cache:
        ct = fixed.Compressed(
            signman=kv.signman[idx], planes=kv.planes[idx],
            dict_syms=kv.dict_syms[idx], esc_pos=kv.esc_pos[idx],
            esc_raw=kv.esc_raw[idx], n_escapes=jnp.zeros((), jnp.int32),
            shape=(batch_loc, blk, w), k=codec.k)
        return fixed.decompress(ct)
    return kv.raw_blocks[idx]


# ---------------------------------------------------------------------------
# prefill -> decode transition
# ---------------------------------------------------------------------------

def fill_from_prefill(cfg: ModelConfig, run: RunConfig, kv: KVBlocks,
                      vals_loc: jax.Array, seq_len: int, tp: int) -> KVBlocks:
    """Load this shard's interleaved slots (B, S/tp, W) into the block store.

    ``vals_loc`` must already be this shard's interleaved sequence slice with
    full head width (the engine's all_to_all produces it).
    """
    b, slots, w = vals_loc.shape
    blk = run.codec.cache_block
    nfull = slots // blk
    rem = slots - nfull * blk

    if nfull:
        def body(kv_c, i):
            vals = jax.lax.dynamic_slice_in_dim(vals_loc, i * blk, blk, axis=1)
            return store_block(kv_c, i, vals, run.codec), None

        kv, _ = jax.lax.scan(body, kv, jnp.arange(nfull))
    if rem:  # partial tail lives in the raw ring (slots nfull*blk + i)
        ring = jax.lax.dynamic_update_slice_in_dim(
            kv.ring, vals_loc[:, nfull * blk:].astype(jnp.bfloat16), 0, 1)
        kv = kv._replace(ring=ring)
    return kv._replace(length=jnp.asarray(seq_len, jnp.int32))


# ---------------------------------------------------------------------------
# decode: append + attend
# ---------------------------------------------------------------------------

def append_token(cfg: ModelConfig, run: RunConfig, kv: KVBlocks,
                 new_vals: jax.Array, tp: int) -> KVBlocks:
    """Append one token's KV/latent (B, W) at global position kv.length.

    Only the owner shard (length % tp) actually mutates its ring; when the
    ring fills, it is compressed into the next block slot (paper: caches are
    compressed block-by-block when written back).
    """
    blk = run.codec.cache_block
    ti = jax.lax.axis_index("model")
    pos = kv.length
    owner = (pos % tp) == ti
    loc = pos // tp                              # owner's local slot index
    ring_idx = loc % blk
    ring_new = jax.lax.dynamic_update_index_in_dim(
        kv.ring, new_vals.astype(jnp.bfloat16)[:, None], ring_idx, 1)
    ring_out = jnp.where(owner, ring_new, kv.ring)
    kv = kv._replace(ring=ring_out, length=pos + 1)

    # flush when the owner's ring just filled (global condition per shard;
    # non-owners keep their store untouched via the same `owner` predicate)
    flush = owner & (ring_idx == blk - 1)
    blk_idx = loc // blk

    def do_flush(kv_c):
        return store_block(kv_c, blk_idx, kv_c.ring, run.codec)

    return jax.lax.cond(flush, do_flush, lambda c: c, kv)


def attend_cache(cfg: ModelConfig, run: RunConfig, kv: KVBlocks,
                 q: jax.Array, spec: layers.AttnSpec, tp: int,
                 window=None, mla_ctx=None) -> jax.Array:
    """Decode attention: q (B,Hq,1,hd) FULL heads on every shard; streams
    this shard's compressed blocks + ring; merges across shards.

    For MLA pass ``mla_ctx = (w_uk_full, w_uv_full ... )``?  No — MLA decode
    uses the *absorbed* form and calls this with q already projected into
    latent space (hd = lora+rope) and hd_v = lora; the caller then applies
    the value up-projection.  ``kv_width`` matches in both cases.

    Returns (B,Hq,1,hd_v) bf16, fully normalized across shards.
    """
    b, hq, _, _ = q.shape
    blk = run.codec.cache_block
    w = kv_width(cfg)
    ti = jax.lax.axis_index("model")
    length = kv.length
    loc_len = jnp.maximum((length - 1 - ti) // tp + 1, 0)
    nfull = loc_len // blk

    mla = cfg.mla is not None
    # static per-query-head kv index: correct for any (padded) head count —
    # q heads keep the model's native order with pad heads appended at the
    # end (clipped onto the last kv head; their wo rows are extra params).
    if not mla:
        import numpy as _np
        g_real = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
        kv_idx = jnp.asarray(_np.clip(_np.arange(hq) // g_real, 0,
                                      cfg.n_kv_heads - 1))

    def split_kv(vals):
        """(B, blk, W) -> (k, v) (B,Hq,blk,·) per-query-head gathered."""
        if mla:
            lora = cfg.mla.kv_lora_rank
            lat = vals[..., :]                   # (B, blk, lora+rope)
            k = lat[:, None]                     # (B,1,blk,lora+rope)
            v = lat[:, None, :, :lora]           # (B,1,blk,lora)
            return k, v
        hkv, hd = cfg.n_kv_heads, cfg.head_dim
        kvv = vals.reshape(b, blk, hkv, 2, hd)
        k = kvv[:, :, :, 0].transpose(0, 2, 1, 3)
        v = kvv[:, :, :, 1].transpose(0, 2, 1, 3)
        return jnp.take(k, kv_idx, axis=1), jnp.take(v, kv_idx, axis=1)

    def valid_for(i0):
        sl = i0 + jnp.arange(blk)
        pos = sl * tp + ti
        ok = pos < length
        if spec.windowed and window is not None:
            ok &= pos > (length - 1 - window)
        return ok

    nblk = (kv.signman.shape[0] if run.codec.cache
            else kv.raw_blocks.shape[0])
    hd_v = (cfg.mla.kv_lora_rank if mla else cfg.head_dim)

    def merge(carry, po, pm, pl):
        out, m, l = carry
        m_new = jnp.maximum(m, pm)
        a_old, a_new = jnp.exp(m - m_new), jnp.exp(pm - m_new)
        return (out * a_old[..., None] + po * a_new[..., None],
                m_new, l * a_old + pl * a_new)

    def scan_blk(carry, i):
        vals = load_block(kv, i, b, blk, w, run.codec)
        ok = valid_for(i * blk) & (i < nfull)
        k, v = split_kv(vals)
        po, pm, pl = layers.attention_partial(
            q, k, v, jnp.broadcast_to(ok[None], (b, blk)), spec)
        return merge(carry, po, pm, pl), None

    init = (jnp.zeros((b, hq, 1, hd_v), jnp.float32),
            jnp.full((b, hq, 1), layers.NEG_INF, jnp.float32),
            jnp.zeros((b, hq, 1), jnp.float32))
    (out, m, l), _ = jax.lax.scan(scan_blk, init, jnp.arange(nblk))

    # ring (raw, partially filled): local slots [nfull*blk, loc_len)
    sl_r = nfull * blk + jnp.arange(blk)
    pos_r = sl_r * tp + ti
    ok_r = (sl_r < loc_len) & (pos_r < length)
    if spec.windowed and window is not None:
        ok_r &= pos_r > (length - 1 - window)
    kr, vr = split_kv(kv.ring)
    po, pm, pl = layers.attention_partial(
        q, kr, vr, jnp.broadcast_to(ok_r[None], (b, blk)), spec)
    out, m, l = merge((out, m, l), po, pm, pl)

    return layers.merge_partials(out, m, l, "model")
