"""Hybrid caches (attention KV + SSM state), LEXI-block-compressed.

This is the paper's "hybrid cache" path: caches are compressed block-by-block
when written back to memory and decompressed just before use (§4.1).  The TPU
layout:

* the KV cache is **sequence-sharded over "model", interleaved**: shard t
  owns global positions {p : p % tp == t}.  Writes round-robin across shards
  (balanced), every shard holds ~len/tp live slots, and decode attention is
  a partial attention per shard merged with one tiny psum
  (``layers.merge_partials``) — no head-divisibility constraints ever.
* each full block of ``block`` owned slots is stored as a LEXI-FW
  ``Compressed`` (K and V of the block packed together); a bf16 ring buffer
  holds the in-flight block.  HBM-side cache traffic is the packed size.
* the decode step streams compressed blocks through a scan, decompressing
  one block at a time (the VMEM-sized working set of a fused kernel) with
  online-softmax accumulation.
* MLA caches the *latent* (c_kv ‖ k_rope) instead of K/V — LEXI compresses
  the latent stream (already 4-8x smaller than full KV: double win).
* the SSM state cache is the fixed-size recurrent state (f32 master for
  recurrence stability — see note at bottom).

With ``CodecConfig.cache=False`` blocks are stored raw bf16 with identical
structure, giving the A/B for the roofline.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core import fixed, packing
from repro.core.collectives import CodecConfig
from repro.kernels import ops as kops
from . import layers
from .ssm import SSMState

WINDOW_NONE = kops.WINDOW_NONE     # "no window" sentinel (huge i32)


class KVBlocks(NamedTuple):
    """Per-layer, per-shard compressed KV block store.

    Payload width W = kv_width(cfg): 2*Hkv*hd for plain attention (K‖V),
    kv_lora+rope for MLA.  Block value shape: (B, block, W).
    """
    signman: Optional[jax.Array]    # (nblk, N) u8, N = B*block*W
    planes: Optional[jax.Array]     # (nblk, k, Npad/32) u32
    dict_syms: Optional[jax.Array]  # (nblk, 2^k) u8
    esc_pos: Optional[jax.Array]    # (nblk, C) i32
    esc_raw: Optional[jax.Array]    # (nblk, C) u8
    raw_blocks: Optional[jax.Array] # (nblk, B, block, W) bf16 when codec off
    ring: jax.Array                 # (B, block, W) bf16 in-flight block
    length: jax.Array               # () i32 global tokens written (all shards)


def kv_width(cfg: ModelConfig) -> int:
    if cfg.mla is not None:
        return cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
    return 2 * cfg.n_kv_heads * cfg.head_dim


def n_blocks(cfg: ModelConfig, run: RunConfig, max_len: int, tp: int) -> int:
    """Capacity in blocks per shard (prefill length + decode growth room)."""
    slots = max_len // tp
    return slots // run.codec.cache_block + 2


def empty_kv(cfg: ModelConfig, run: RunConfig, batch_loc: int, max_len: int,
             tp: int) -> KVBlocks:
    w = kv_width(cfg)
    blk = run.codec.cache_block
    nblk = n_blocks(cfg, run, max_len, tp)
    n = batch_loc * blk * w
    npad = packing.pad_to_lanes(n)
    c = run.codec.esc_capacity(n)
    k = run.codec.k
    if run.codec.cache:
        return KVBlocks(
            signman=jnp.zeros((nblk, n), jnp.uint8),
            planes=jnp.zeros((nblk, k, npad // 32), jnp.uint32),
            dict_syms=jnp.zeros((nblk, 1 << k), jnp.uint8),
            esc_pos=jnp.full((nblk, c), npad, jnp.int32),
            esc_raw=jnp.zeros((nblk, c), jnp.uint8),
            raw_blocks=None,
            ring=jnp.zeros((batch_loc, blk, w), jnp.bfloat16),
            length=jnp.zeros((), jnp.int32))
    return KVBlocks(signman=None, planes=None, dict_syms=None, esc_pos=None,
                    esc_raw=None,
                    raw_blocks=jnp.zeros((nblk, batch_loc, blk, w),
                                         jnp.bfloat16),
                    ring=jnp.zeros((batch_loc, blk, w), jnp.bfloat16),
                    length=jnp.zeros((), jnp.int32))


def store_block(kv: KVBlocks, idx, vals: jax.Array,
                codec: CodecConfig) -> KVBlocks:
    """Write one full block (B, blk, W) into slot ``idx``."""
    if codec.cache:
        ct = fixed.compress(vals, k=codec.k,
                            esc_capacity=codec.esc_capacity(vals.size))
        upd = jax.lax.dynamic_update_index_in_dim
        return kv._replace(
            signman=upd(kv.signman, ct.signman, idx, 0),
            planes=upd(kv.planes, ct.planes, idx, 0),
            dict_syms=upd(kv.dict_syms, ct.dict_syms, idx, 0),
            esc_pos=upd(kv.esc_pos, ct.esc_pos, idx, 0),
            esc_raw=upd(kv.esc_raw, ct.esc_raw, idx, 0))
    return kv._replace(raw_blocks=jax.lax.dynamic_update_index_in_dim(
        kv.raw_blocks, vals, idx, 0))


def load_block(kv: KVBlocks, idx, batch_loc: int, blk: int, w: int,
               codec: CodecConfig) -> jax.Array:
    if codec.cache:
        ct = fixed.Compressed(
            signman=kv.signman[idx], planes=kv.planes[idx],
            dict_syms=kv.dict_syms[idx], esc_pos=kv.esc_pos[idx],
            esc_raw=kv.esc_raw[idx], n_escapes=jnp.zeros((), jnp.int32),
            shape=(batch_loc, blk, w), k=codec.k)
        return fixed.decompress(ct)
    return kv.raw_blocks[idx]


# ---------------------------------------------------------------------------
# prefill -> decode transition
# ---------------------------------------------------------------------------

def fill_from_prefill(cfg: ModelConfig, run: RunConfig, kv: KVBlocks,
                      vals_loc: jax.Array, seq_len: int, tp: int) -> KVBlocks:
    """Load this shard's interleaved slots (B, S/tp, W) into the block store.

    ``vals_loc`` must already be this shard's interleaved sequence slice with
    full head width (the engine's all_to_all produces it).
    """
    b, slots, w = vals_loc.shape
    blk = run.codec.cache_block
    nfull = slots // blk
    rem = slots - nfull * blk

    if nfull:
        def body(kv_c, i):
            vals = jax.lax.dynamic_slice_in_dim(vals_loc, i * blk, blk, axis=1)
            return store_block(kv_c, i, vals, run.codec), None

        kv, _ = jax.lax.scan(body, kv, jnp.arange(nfull))
    if rem:  # partial tail lives in the raw ring (slots nfull*blk + i)
        ring = jax.lax.dynamic_update_slice_in_dim(
            kv.ring, vals_loc[:, nfull * blk:].astype(jnp.bfloat16), 0, 1)
        kv = kv._replace(ring=ring)
    return kv._replace(length=jnp.asarray(seq_len, jnp.int32))


# ---------------------------------------------------------------------------
# decode: append + attend
# ---------------------------------------------------------------------------

def split_kv_payload(cfg: ModelConfig, vals: jax.Array, hq: int):
    """Cache payload (B, L, W) -> (k, v) per-query-head views.

    Plain attention: (B,Hq,L,hd) with the static GQA head map (pad query
    heads clip onto the last kv head).  MLA: the latent travels whole,
    (B,1,L,lora+rope) / (B,1,L,lora).  Shared by the fixed-batch block
    store and the paged store so the two decode paths cannot diverge.
    """
    b, L, _ = vals.shape
    if cfg.mla is not None:
        lora = cfg.mla.kv_lora_rank
        return vals[:, None], vals[:, None, :, :lora]
    import numpy as _np
    g_real = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    kv_idx = jnp.asarray(_np.clip(_np.arange(hq) // g_real, 0,
                                  cfg.n_kv_heads - 1))
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    kvv = vals.reshape(b, L, hkv, 2, hd)
    k = kvv[:, :, :, 0].transpose(0, 2, 1, 3)
    v = kvv[:, :, :, 1].transpose(0, 2, 1, 3)
    return jnp.take(k, kv_idx, axis=1), jnp.take(v, kv_idx, axis=1)


def merge_partial(carry, po, pm, pl):
    """Online-softmax accumulation of one attention partial into (out,m,l)."""
    out, m, l = carry
    m_new = jnp.maximum(m, pm)
    a_old, a_new = jnp.exp(m - m_new), jnp.exp(pm - m_new)
    return (out * a_old[..., None] + po * a_new[..., None],
            m_new, l * a_old + pl * a_new)


# ---------------------------------------------------------------------------
# decode attention: shared masking + streaming helpers and backend dispatch
#
# Both cache stores (fixed-batch blocks, paged pool) stream [compressed
# blocks ‖ raw ring] with the same live-slot arithmetic; the per-block scan
# body exists ONCE here (the "jax" backend), and the fused Pallas kernels
# (``kernels.decode_attend``) implement identical semantics for the
# pallas/interpret backends — selected via ``run.codec.decode_backend``
# (see ``kernels.ops.resolve_decode_backend``).
# ---------------------------------------------------------------------------


def effective_window(spec: layers.AttnSpec, window):
    """Traced window size with the huge-sentinel convention: masking is
    always ``pos > L - 1 - window``, so non-windowed layers pass a value
    no live position can fail."""
    if spec.windowed and window is not None:
        return jnp.asarray(window, jnp.int32)
    return jnp.asarray(WINDOW_NONE, jnp.int32)


def stream_mask(lengths, i, blk: int, tp: int, ti, window, ring: bool):
    """Live mask (..., blk) for block ``i`` (or the ring) of the slot
    stream.  ``lengths`` is () for the fixed store or (S,) for the paged
    store; shard ``ti`` owns interleaved global positions p % tp == ti."""
    lengths = jnp.asarray(lengths, jnp.int32)
    loc_len = jnp.maximum((lengths - 1 - ti) // tp + 1, 0)
    nfull = loc_len // blk
    if ring:
        sl = nfull[..., None] * blk + jnp.arange(blk)
        live = sl < loc_len[..., None]
    else:
        sl = jnp.broadcast_to(i * blk + jnp.arange(blk),
                              lengths.shape + (blk,))
        live = jnp.broadcast_to((i < nfull)[..., None], sl.shape)
    pos = sl * tp + ti
    ok = (pos < lengths[..., None]) & (pos > lengths[..., None] - 1 - window)
    return ok & live


def gqa_head_table(cfg: ModelConfig, hq: int) -> tuple:
    """Static per-query-head kv index table (pad heads clip onto the last
    kv head) — must match ``split_kv_payload``'s dynamic take."""
    import numpy as _np
    g_real = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    return tuple(int(x) for x in
                 _np.clip(_np.arange(hq) // g_real, 0, cfg.n_kv_heads - 1))


def _attend_scan_jax(cfg, q, spec, hq, load_fn, n_steps, valid_fn,
                     ring_kv, ring_ok):
    """The ONE pure-JAX streaming-attention body: scan compressed blocks,
    then the raw ring, with online-softmax partial merging."""
    b = q.shape[0]
    hd_v = (cfg.mla.kv_lora_rank if cfg.mla is not None else cfg.head_dim)

    def scan_blk(carry, i):
        k, v = split_kv_payload(cfg, load_fn(i), hq)
        po, pm, pl = layers.attention_partial(q, k, v, valid_fn(i), spec)
        return merge_partial(carry, po, pm, pl), None

    init = (jnp.zeros((b, hq, 1, hd_v), jnp.float32),
            jnp.full((b, hq, 1), layers.NEG_INF, jnp.float32),
            jnp.zeros((b, hq, 1), jnp.float32))
    (out, m, l), _ = jax.lax.scan(scan_blk, init, jnp.arange(n_steps))

    kr, vr = split_kv_payload(cfg, ring_kv, hq)
    po, pm, pl = layers.attention_partial(q, kr, vr, ring_ok, spec)
    return merge_partial((out, m, l), po, pm, pl)


def _kernel_statics(cfg: ModelConfig, run: RunConfig, q: jax.Array,
                    spec: layers.AttnSpec):
    """Static kwargs shared by both fused-kernel entry points."""
    hq = q.shape[1]
    hd = q.shape[-1]
    return dict(
        k=run.codec.k,
        hkv=cfg.n_kv_heads,
        hd=cfg.head_dim,
        kv_idx=(() if cfg.mla is not None else gqa_head_table(cfg, hq)),
        scale=(spec.scale if spec.scale is not None else hd ** -0.5),
        softcap=spec.softcap,
        mla_lora=(cfg.mla.kv_lora_rank if cfg.mla is not None else None))


def append_token(cfg: ModelConfig, run: RunConfig, kv: KVBlocks,
                 new_vals: jax.Array, tp: int) -> KVBlocks:
    """Append one token's KV/latent (B, W) at global position kv.length.

    Only the owner shard (length % tp) actually mutates its ring; when the
    ring fills, it is compressed into the next block slot (paper: caches are
    compressed block-by-block when written back).
    """
    blk = run.codec.cache_block
    ti = jax.lax.axis_index("model")
    pos = kv.length
    owner = (pos % tp) == ti
    loc = pos // tp                              # owner's local slot index
    ring_idx = loc % blk
    ring_new = jax.lax.dynamic_update_index_in_dim(
        kv.ring, new_vals.astype(jnp.bfloat16)[:, None], ring_idx, 1)
    ring_out = jnp.where(owner, ring_new, kv.ring)
    kv = kv._replace(ring=ring_out, length=pos + 1)

    # flush when the owner's ring just filled (global condition per shard;
    # non-owners keep their store untouched via the same `owner` predicate)
    flush = owner & (ring_idx == blk - 1)
    blk_idx = loc // blk

    def do_flush(kv_c):
        return store_block(kv_c, blk_idx, kv_c.ring, run.codec)

    return jax.lax.cond(flush, do_flush, lambda c: c, kv)


def attend_cache(cfg: ModelConfig, run: RunConfig, kv: KVBlocks,
                 q: jax.Array, spec: layers.AttnSpec, tp: int,
                 window=None) -> jax.Array:
    """Decode attention: q (B,Hq,1,hd) FULL heads on every shard; streams
    this shard's compressed blocks + ring; merges across shards.

    MLA decode uses the *absorbed* form and calls this with q already
    projected into latent space (hd = lora+rope) and hd_v = lora; the
    caller then applies the value up-projection.

    The backend (fused Pallas kernel vs pure-JAX scan) comes from
    ``run.codec.decode_backend``.  Returns (B,Hq,1,hd_v) bf16, fully
    normalized across shards.
    """
    b, hq, _, _ = q.shape
    blk = run.codec.cache_block
    w = kv_width(cfg)
    ti = jax.lax.axis_index("model")
    length = kv.length
    win = effective_window(spec, window)
    backend = kops.resolve_decode_backend(run.codec)

    if backend != "jax":
        out, m, l = kops.decode_attend(
            q[:, :, 0], kv.signman, kv.planes, kv.dict_syms, kv.esc_raw,
            kv.raw_blocks, kv.ring, length, ti, win, tp=tp,
            interpret=(backend == "interpret"),
            **_kernel_statics(cfg, run, q, spec))
        return layers.merge_partials(out[:, :, None, :], m[..., None],
                                     l[..., None], "model")

    nblk = (kv.signman.shape[0] if run.codec.cache
            else kv.raw_blocks.shape[0])
    load = lambda i: load_block(kv, i, b, blk, w, run.codec)
    valid = lambda i: jnp.broadcast_to(
        stream_mask(length, i, blk, tp, ti, win, ring=False)[None], (b, blk))
    ring_ok = jnp.broadcast_to(
        stream_mask(length, 0, blk, tp, ti, win, ring=True)[None], (b, blk))
    out, m, l = _attend_scan_jax(cfg, q, spec, hq, load, nblk, valid,
                                 kv.ring, ring_ok)
    return layers.merge_partials(out, m, l, "model")


# ---------------------------------------------------------------------------
# Paged KV cache (continuous batching)
#
# The block store above is fixed-batch: all B sequences advance in lockstep
# and share one global length.  The paged store below decouples them so a
# scheduler can admit/evict sequences mid-flight (vLLM-style paging, with
# LEXI block compression as the page representation):
#
# * a pool of fixed-size *pages*, each holding ``block`` interleaved-owned
#   slots of ONE sequence, LEXI-FW-compressed on fill (codec on) or raw bf16
#   (codec off) — the compressed layout of a page is byte-identical to a
#   B=1 block of the fixed-batch store, so a prefilled sequence's blocks
#   copy straight into pages with no decompress/recompress round trip;
# * a per-slot page table mapping block index -> page id (-1 = unmapped)
#   plus a page_used bitmap for functional in-graph allocation;
# * per-slot bf16 rings for the in-flight partial block and per-slot
#   lengths, so every sequence appends/attends at its own position.
#
# All of it remains per-shard state inside shard_map: shard t owns global
# positions {p : p % tp == t} of every sequence, exactly like the fixed
# store, so decode attention stays a partial-per-shard + one tiny psum.
# ---------------------------------------------------------------------------


class PagedKV(NamedTuple):
    """Per-layer, per-shard paged KV store (one sequence per slot).

    Page payload shape: (block, W); compressed fields have leading n_pages.

    **Page lifecycle (refcount / copy-on-write convention).**  A page is
    immutable once full: it is written exactly once (trunk insert via
    ``paged_insert_many`` or a ring flush in ``append_token_paged``) and
    never rewritten while ``page_used`` is set.  That immutability is what
    makes prefix sharing safe: several slots' page-table rows may point at
    the SAME page id (mapped by ``map_prefix_pages``), and the only mutable
    per-sequence state — the partially filled tail block — lives in each
    slot's private ``ring`` row, so "copy-on-write" is simply "the tail is
    never shared" (a slot that outgrows a shared prefix flushes its ring
    into a freshly allocated page, never into a shared one).  Reference
    counts are HOST-side state (the serving scheduler owns them, keyed by
    prefix content with per-shard page-id vectors, because page ids may
    diverge across shards after unaligned releases); the device-side
    contract is only: ``release_pages(..., free_mask)`` clears exactly the
    pages the host decided hit refcount zero, while shared pages stay
    ``page_used`` until their last referencing slot releases.
    """
    signman: Optional[jax.Array]    # (P, N) u8, N = block*W
    planes: Optional[jax.Array]     # (P, k, Npad/32) u32
    dict_syms: Optional[jax.Array]  # (P, 2^k) u8
    esc_pos: Optional[jax.Array]    # (P, C) i32
    esc_raw: Optional[jax.Array]    # (P, C) u8
    raw_pages: Optional[jax.Array]  # (P, block, W) bf16 when codec off
    page_table: jax.Array           # (S, maxp) i32, -1 = unmapped
    page_used: jax.Array            # (P,) bool
    ring: jax.Array                 # (S, block, W) bf16 in-flight blocks


def max_pages_per_slot(run: RunConfig, max_len: int, tp: int) -> int:
    return (max_len // tp) // run.codec.cache_block + 2


def page_bytes(cfg: ModelConfig, run: RunConfig) -> Tuple[int, int]:
    """(stored_bytes, raw_bytes) per page per shard — the serving metric.

    Derived from the abstract shapes of the actual store (one source of
    truth: whatever ``empty_paged_kv`` allocates per page is what HBM pays).
    """
    if cfg.n_heads == 0:            # attention-free: no KV pages at all
        return 0, 0
    import numpy as _np
    pkv = jax.eval_shape(lambda: empty_paged_kv(cfg, run, 1,
                                                run.codec.cache_block, 1))
    per_page = lambda f: int(_np.prod(f.shape[1:])) * f.dtype.itemsize
    raw = per_page(pkv.ring)                       # ring row == one raw page
    if not run.codec.cache:
        return raw, raw
    stored = sum(per_page(f) for f in (pkv.signman, pkv.planes,
                                       pkv.dict_syms, pkv.esc_pos,
                                       pkv.esc_raw))
    return stored, raw


def empty_paged_kv(cfg: ModelConfig, run: RunConfig, n_slots: int,
                   max_len: int, tp: int,
                   n_pages: Optional[int] = None) -> PagedKV:
    w = kv_width(cfg)
    blk = run.codec.cache_block
    maxp = max_pages_per_slot(run, max_len, tp)
    # In-graph allocation (append_token_paged) has no way to fail loudly on
    # pool exhaustion — it would hand out a live page.  Oversubscription is
    # therefore rejected here, at construction, where it CAN fail loudly.
    if n_pages is not None and n_pages < n_slots * maxp:
        raise ValueError(
            f"page pool oversubscription unsupported: n_pages={n_pages} < "
            f"n_slots*max_pages={n_slots * maxp}")
    P_ = n_pages if n_pages is not None else n_slots * maxp
    n = blk * w
    npad = packing.pad_to_lanes(n)
    c = run.codec.esc_capacity(n)
    k = run.codec.k
    pt = jnp.full((n_slots, maxp), -1, jnp.int32)
    used = jnp.zeros((P_,), jnp.bool_)
    ring = jnp.zeros((n_slots, blk, w), jnp.bfloat16)
    if run.codec.cache:
        return PagedKV(
            signman=jnp.zeros((P_, n), jnp.uint8),
            planes=jnp.zeros((P_, k, npad // 32), jnp.uint32),
            dict_syms=jnp.zeros((P_, 1 << k), jnp.uint8),
            esc_pos=jnp.full((P_, c), npad, jnp.int32),
            esc_raw=jnp.zeros((P_, c), jnp.uint8),
            raw_pages=None, page_table=pt, page_used=used, ring=ring)
    return PagedKV(signman=None, planes=None, dict_syms=None, esc_pos=None,
                   esc_raw=None,
                   raw_pages=jnp.zeros((P_, blk, w), jnp.bfloat16),
                   page_table=pt, page_used=used, ring=ring)


def load_pages(pkv: PagedKV, page_ids: jax.Array, blk: int, w: int,
               codec: CodecConfig) -> jax.Array:
    """Gather + decompress one page per slot.  page_ids (S,) -> (S, blk, W).

    Unmapped ids (-1) load page 0; callers mask those positions invalid.
    """
    pid = jnp.clip(page_ids, 0, None)
    if codec.cache:
        ct = fixed.Compressed(
            signman=pkv.signman[pid], planes=pkv.planes[pid],
            dict_syms=pkv.dict_syms[pid], esc_pos=pkv.esc_pos[pid],
            esc_raw=pkv.esc_raw[pid],
            n_escapes=jnp.zeros(pid.shape, jnp.int32),
            shape=(blk, w), k=codec.k)
        return jax.vmap(fixed.decompress)(ct)
    return pkv.raw_pages[pid]


def append_token_paged(cfg: ModelConfig, run: RunConfig, pkv: PagedKV,
                       new_vals: jax.Array, lengths: jax.Array,
                       active: jax.Array, tp: int) -> PagedKV:
    """Append one token's KV/latent (S, W) at each slot's own position.

    Only the owner shard of each slot's next position writes its ring;
    inactive slots are untouched.  Rings that just filled are compressed
    into freshly allocated pages (free-list allocation stays in-graph:
    argsort of the used bitmap yields free page ids deterministically).
    """
    blk = run.codec.cache_block
    ti = jax.lax.axis_index("model")
    pos = lengths                                    # (S,)
    owner = (pos % tp) == ti
    write = owner & active
    loc = pos // tp
    ring_idx = loc % blk
    oh = (ring_idx[:, None] == jnp.arange(blk)[None]) & write[:, None]
    ring = jnp.where(oh[..., None], new_vals.astype(jnp.bfloat16)[:, None],
                     pkv.ring)
    pkv = pkv._replace(ring=ring)

    flush = write & (ring_idx == blk - 1)
    blk_idx = loc // blk                             # page-table column
    maxp = pkv.page_table.shape[1]
    n_pages = pkv.page_used.shape[0]

    def do_flush(pkv_c: PagedKV) -> PagedKV:
        free_order = jnp.argsort(pkv_c.page_used)    # free pages first
        rank = jnp.cumsum(flush.astype(jnp.int32)) - 1
        page = free_order[jnp.clip(rank, 0, n_pages - 1)]
        tgt = jnp.where(flush, page, n_pages)        # sentinel drops
        if run.codec.cache:
            ct = jax.vmap(lambda r: fixed.compress(
                r, k=run.codec.k,
                esc_capacity=run.codec.esc_capacity(r.size)))(pkv_c.ring)
            pkv_c = pkv_c._replace(
                signman=pkv_c.signman.at[tgt].set(ct.signman, mode="drop"),
                planes=pkv_c.planes.at[tgt].set(ct.planes, mode="drop"),
                dict_syms=pkv_c.dict_syms.at[tgt].set(ct.dict_syms,
                                                      mode="drop"),
                esc_pos=pkv_c.esc_pos.at[tgt].set(ct.esc_pos, mode="drop"),
                esc_raw=pkv_c.esc_raw.at[tgt].set(ct.esc_raw, mode="drop"))
        else:
            pkv_c = pkv_c._replace(
                raw_pages=pkv_c.raw_pages.at[tgt].set(pkv_c.ring,
                                                      mode="drop"))
        ohp = (blk_idx[:, None] == jnp.arange(maxp)[None]) & flush[:, None]
        pt = jnp.where(ohp, page[:, None], pkv_c.page_table)
        used = pkv_c.page_used.at[tgt].set(True, mode="drop")
        return pkv_c._replace(page_table=pt, page_used=used)

    return jax.lax.cond(jnp.any(flush), do_flush, lambda c: c, pkv)


def attend_paged(cfg: ModelConfig, run: RunConfig, pkv: PagedKV,
                 q: jax.Array, lengths: jax.Array, spec: layers.AttnSpec,
                 tp: int, window=None) -> jax.Array:
    """Per-slot paged decode attention: q (S,Hq,1,hd) FULL heads on every
    shard; streams each slot's pages via its page table, then the rings;
    merges across shards.  ``lengths`` (S,) are post-append token counts.

    The backend (fused page-table Pallas kernel vs pure-JAX scan) comes
    from ``run.codec.decode_backend``.  Returns (S,Hq,1,hd_v) bf16, fully
    normalized across shards.
    """
    b, hq, _, _ = q.shape
    blk = run.codec.cache_block
    w = kv_width(cfg)
    ti = jax.lax.axis_index("model")
    maxp = pkv.page_table.shape[1]
    win = effective_window(spec, window)
    backend = kops.resolve_decode_backend(run.codec)

    if backend != "jax":
        out, m, l = kops.decode_attend_paged(
            q[:, :, 0], pkv.signman, pkv.planes, pkv.dict_syms, pkv.esc_raw,
            pkv.raw_pages, pkv.ring, jnp.clip(pkv.page_table, 0, None),
            lengths, ti, win, tp=tp, interpret=(backend == "interpret"),
            **_kernel_statics(cfg, run, q, spec))
        return layers.merge_partials(out[:, :, None, :], m[..., None],
                                     l[..., None], "model")

    load = lambda i: load_pages(pkv, pkv.page_table[:, i], blk, w, run.codec)
    valid = lambda i: stream_mask(lengths, i, blk, tp, ti, win, ring=False)
    ring_ok = stream_mask(lengths, 0, blk, tp, ti, win, ring=True)
    out, m, l = _attend_scan_jax(cfg, q, spec, hq, load, maxp, valid,
                                 pkv.ring, ring_ok)
    return layers.merge_partials(out, m, l, "model")


def paged_insert_many(cfg: ModelConfig, run: RunConfig, pkv: PagedKV,
                      kvb: KVBlocks, slots: jax.Array, seq_len: int,
                      tp: int) -> PagedKV:
    """Scatter ``B`` prefilled B=1 block stores into paged slots ``slots``.

    ``kvb`` is a stack of B independent B=1 fixed stores (leading batch
    axis, as produced by a vmapped prefill): the compressed layout of a
    (1, blk, W) block equals a (blk, W) page byte-for-byte (same element
    count, same dictionary build), so full blocks transfer by one batched
    array scatter; each partial tail transfers as that slot's ring row.

    ``seq_len`` is a static int and MUST be a multiple of tp (the admission
    trunk is bucket-aligned; unaligned leftovers replay through
    ``append_token_paged`` afterwards), so every shard owns the same static
    number of full blocks — which also keeps page-id allocation in lockstep
    across shards for freshly admitted trunks.
    """
    assert seq_len % tp == 0, (seq_len, tp)
    blk = run.codec.cache_block
    nb = kvb.ring.shape[0]
    nfull = (seq_len // tp) // blk                   # static, same per shard
    maxp = pkv.page_table.shape[1]
    assert nfull <= maxp, (nfull, maxp)

    used = pkv.page_used
    if nfull:
        free_order = jnp.argsort(used)               # free pages first
        pages = free_order[:nb * nfull].reshape(nb, nfull)
        tgt = pages.reshape(-1)                      # distinct ids
        if run.codec.cache:
            pkv = pkv._replace(
                signman=pkv.signman.at[tgt].set(
                    kvb.signman[:, :nfull].reshape((nb * nfull,) +
                                                   pkv.signman.shape[1:])),
                planes=pkv.planes.at[tgt].set(
                    kvb.planes[:, :nfull].reshape((nb * nfull,) +
                                                  pkv.planes.shape[1:])),
                dict_syms=pkv.dict_syms.at[tgt].set(
                    kvb.dict_syms[:, :nfull].reshape((nb * nfull,) +
                                                     pkv.dict_syms.shape[1:])),
                esc_pos=pkv.esc_pos.at[tgt].set(
                    kvb.esc_pos[:, :nfull].reshape((nb * nfull,) +
                                                   pkv.esc_pos.shape[1:])),
                esc_raw=pkv.esc_raw.at[tgt].set(
                    kvb.esc_raw[:, :nfull].reshape((nb * nfull,) +
                                                   pkv.esc_raw.shape[1:])))
        else:
            pkv = pkv._replace(
                raw_pages=pkv.raw_pages.at[tgt].set(
                    kvb.raw_blocks[:, :nfull, 0].reshape(
                        (nb * nfull,) + pkv.raw_pages.shape[1:])))
        used = used.at[tgt].set(True)
        rows = jnp.concatenate(
            [pages, jnp.full((nb, maxp - nfull), -1, jnp.int32)], axis=1)
    else:
        rows = jnp.full((nb, maxp), -1, jnp.int32)
    slots = jnp.asarray(slots, jnp.int32)
    pt = pkv.page_table.at[slots].set(rows)
    ring = pkv.ring.at[slots].set(kvb.ring[:, 0])
    return pkv._replace(page_table=pt, page_used=used, ring=ring)


def map_prefix_pages(pkv: PagedKV, slot, page_ids: jax.Array,
                     n_cols) -> PagedKV:
    """Map already-filled shared pages into slot ``slot``'s table row.

    ``page_ids`` (maxp,) holds this shard's page ids for the matched full
    prefix columns (entries beyond ``n_cols`` are ignored); the slot's ring
    starts empty (the shared prefix is block-aligned; the tail is private —
    see the PagedKV lifecycle note).  Zero data moves: sharing is pure
    page-table indirection, the caller (host scheduler) owns the refcounts.
    """
    maxp = pkv.page_table.shape[1]
    n_pages = pkv.page_used.shape[0]
    cols = jnp.arange(maxp)
    n_cols = jnp.asarray(n_cols, jnp.int32)
    row = jnp.where(cols < n_cols, page_ids, -1)
    slot = jnp.asarray(slot, jnp.int32)
    pt = jax.lax.dynamic_update_index_in_dim(pkv.page_table, row, slot, 0)
    # shared pages are live already; the masked set is a no-op re-assert
    tgt = jnp.where(cols < n_cols, page_ids, n_pages)
    used = pkv.page_used.at[tgt].set(True, mode="drop")
    ring = jax.lax.dynamic_update_index_in_dim(
        pkv.ring, jnp.zeros_like(pkv.ring[0]), slot, 0)
    return pkv._replace(page_table=pt, page_used=used, ring=ring)


class PageWire(NamedTuple):
    """One slot's cache payload in transfer layout (per layer, per shard).

    The dense, slot-ordered view of a sequence's pages that crosses a
    replica boundary: ``export_sequence`` gathers it out of a pool,
    ``import_sequence`` scatters it into another pool.  Compressed fields
    are BYTE-IDENTICAL to the pool pages they came from (no decompress /
    recompress round trip); page-id indirection never crosses the wire —
    column order IS the sequence order.

    Leaves are ``None`` exactly as in ``PagedKV`` (codec on: compressed
    fields; codec off: ``raw_pages``).  Shapes (n_cols = exported full-page
    columns, the max over shards; trailing invalid columns are zeroed):

      signman   (n_cols, N) u8          N = block*W
      planes    (n_cols, k, Npad/32) u32
      dict_syms (n_cols, 2^k) u8
      esc_pos   (n_cols, C) i32
      esc_raw   (n_cols, C) u8
      raw_pages (n_cols, block, W) bf16
      ring      (block, W) bf16         the in-flight partial tail block
    """
    signman: Optional[jax.Array]
    planes: Optional[jax.Array]
    dict_syms: Optional[jax.Array]
    esc_pos: Optional[jax.Array]
    esc_raw: Optional[jax.Array]
    raw_pages: Optional[jax.Array]
    ring: jax.Array


def local_full_pages(length, ti, blk: int, tp: int):
    """Full pages shard ``ti`` holds for a sequence of ``length`` tokens
    (interleaved ownership: shard t owns positions p % tp == t)."""
    length = jnp.asarray(length, jnp.int32)
    loc_len = jnp.maximum((length - 1 - ti) // tp + 1, 0)
    return loc_len // blk


def export_n_cols(length: int, blk: int, tp: int) -> int:
    """Static page-column count of a wire payload: the max over shards of
    ``local_full_pages`` — host-side mirror of the device arithmetic."""
    return max(max((int(length) - 1 - t) // tp + 1, 0) // blk
               for t in range(tp)) if length > 0 else 0


def export_sequence(pkv: PagedKV, slot, n_cols: int, length,
                    tp: int, col0=0) -> PageWire:
    """Gather slot ``slot``'s cache payload into transfer layout.

    The disaggregated-prefill seam: a prefill replica exports each admitted
    sequence as a :class:`PageWire` whose compressed planes are byte-copied
    from its pool pages (pages are immutable once full, so the gather IS
    the serialization — no decompress/recompress round trip), and a decode
    replica scatters it into its own pool via :func:`import_sequence`.

    ``n_cols`` is static (``export_n_cols``); shards holding fewer full
    pages (``length % (block*tp) != 0``) zero their trailing columns so the
    payload is deterministic.  ``slot``/``length`` may be traced.

    **Chunked mode.**  ``col0`` (traced, default 0) windows the gather to
    page columns ``[col0, col0 + n_cols)`` — the streaming-prefill export:
    as admission fills pages, the prefill replica gathers just the freshly
    completed columns and ships them ahead of the closing blob as
    ``repro.serve.transport.pack_chunk`` frames (columns at or past a
    shard's ``local_full_pages`` are zeroed exactly as in whole-sequence
    mode, and the window is re-keyed on ``n_cols`` only, so the jit cache
    stays small).

    **WIRE FORMAT (version 1).**  The byte framing a transport ships (see
    ``repro.serve.transport.SequenceBlob.to_wire``) — everything little-
    endian, arrays serialized as raw C-order bytes in exactly this order:

      header:
        magic      4B  b"LXSQ"
        version    u8  = 1        (bump on ANY layout change)
        flags      u8  bit0 codec-on, bit1 KV present, bit2 SSM present
        tp         u16            per-shard layout: every array below
        n_layers   u16            carries a leading (tp, n_layers) pair of
        n_cols     u16            axes, shard-major then layer
        block      u16            tokens per page per shard
        w          u32            payload width W (kv_width)
        k          u16            dictionary index bits
        esc_cap    u32            C, escape side-channel slots per page
        npad       u32            N padded to lanes (planes row = npad/32 u32)
        length     u32            tokens held by the sequence (all shards)
        cur_token  i32            next decode input (last emitted token)
        n_emitted  u16            tokens generated so far (normally 1)
        emitted    n_emitted x i32
      ssm section (iff flag bit2; dims header then arrays, per shard/layer):
        nh_loc u16, headdim u16, d_state u16, d_conv-1 u16, di_loc u32
        h       (tp, L, nh_loc, headdim, d_state) f32
        conv_x  (tp, L, d_conv-1, di_loc) bf16
        conv_bc (tp, L, d_conv-1, 2*d_state) bf16
      ring section (iff flag bit1):
        ring    (tp, L, block, w) bf16
      page section (iff flag bit1) — one entry per VALID column, iterated
      shard-major, then layer, then column (shard t has
      ``local_full_pages(length, t)`` valid columns):
        tag     u8   0 = inline payload, 1 = content reference
        digest  12B  sha256(payload)[:12]
        payload      iff tag 0: the page's fields back to back —
                     codec on : signman (N u8) ‖ planes (k*npad/32 u32) ‖
                                dict_syms (2^k u8) ‖ esc_pos (C i32) ‖
                                esc_raw (C u8)
                     codec off: raw page (block*w bf16)

    Tag-1 entries let a transport replace pages the receiver already holds
    (content-addressed dedup); a receiver resolves them from its digest
    store and must fail loudly on an unknown digest.
    """
    blk, w = pkv.ring.shape[1], pkv.ring.shape[2]
    maxp = pkv.page_table.shape[1]
    ti = jax.lax.axis_index("model")
    nfull = local_full_pages(length, ti, blk, tp)
    row = pkv.page_table[jnp.asarray(slot, jnp.int32)]       # (maxp,)
    cols = jnp.asarray(col0, jnp.int32) + jnp.arange(n_cols)
    valid = (cols < nfull) & (cols < maxp)
    pid = jnp.where(valid,
                    jnp.clip(row[jnp.clip(cols, 0, maxp - 1)], 0, None), 0)

    def take(field, zero_dtype):
        if field is None:
            return None
        out = field[pid]
        mask = valid.reshape((n_cols,) + (1,) * (out.ndim - 1))
        return jnp.where(mask, out, jnp.zeros((), zero_dtype))

    return PageWire(
        signman=take(pkv.signman, jnp.uint8),
        planes=take(pkv.planes, jnp.uint32),
        dict_syms=take(pkv.dict_syms, jnp.uint8),
        esc_pos=take(pkv.esc_pos, jnp.int32),
        esc_raw=take(pkv.esc_raw, jnp.uint8),
        raw_pages=take(pkv.raw_pages, jnp.bfloat16),
        ring=pkv.ring[jnp.asarray(slot, jnp.int32)])


def import_sequence(pkv: PagedKV, slot, wire: PageWire, length,
                    tp: int, col0=0) -> PagedKV:
    """Scatter a :class:`PageWire` into slot ``slot`` of this pool.

    Exact inverse of :func:`export_sequence` up to page ids: fresh pages
    come from THIS pool's free list (argsort of ``page_used`` — works for
    any permutation of the free list, ids need not match the exporting
    pool's), the compressed fields are byte-copied into them, and the
    slot's page-table row maps them in sequence order.  Columns beyond this
    shard's ``local_full_pages`` are dropped via the sentinel-scatter
    convention.  The re-export of an imported slot is bit-identical to the
    original wire payload (round-trip proof in ``tests/test_disagg.py``).

    ``col0`` (traced, default 0) makes the import PARTIAL: the wire columns
    represent global page columns ``[col0, col0 + n_cols)`` and the table
    row's entries below ``col0`` are left as they are — the decode-replica
    prefix-reuse path maps already-resident shared pages into columns
    ``[0, col0)`` first (``map_prefix_pages``) and imports only the
    unmatched suffix columns from the wire.

    In-graph allocation cannot fail loudly, so the HOST must check pool
    capacity before dispatching an import (``col0 + n_cols <= max pages per
    slot`` and enough free pages on every shard/layer) — see
    ``repro.serve.disagg.DecodeReplica.import_handoff``, which rejects
    oversubscription before any device state mutates.

    See the export docstring for the WIRE FORMAT this pair defines.
    """
    lead = wire.signman if pkv.signman is not None else wire.raw_pages
    n_cols = lead.shape[0]
    blk = pkv.ring.shape[1]
    maxp = pkv.page_table.shape[1]
    n_pages = pkv.page_used.shape[0]
    assert n_cols <= maxp, (n_cols, maxp)
    ti = jax.lax.axis_index("model")
    nfull = local_full_pages(length, ti, blk, tp)
    slot = jnp.asarray(slot, jnp.int32)
    col0 = jnp.asarray(col0, jnp.int32)

    free_order = jnp.argsort(pkv.page_used)          # free pages first
    pages = free_order[:n_cols] if n_cols else jnp.zeros((0,), jnp.int32)
    valid = col0 + jnp.arange(n_cols) < nfull
    tgt = jnp.where(valid, pages, n_pages)           # sentinel drops
    if pkv.signman is not None:
        pkv = pkv._replace(
            signman=pkv.signman.at[tgt].set(wire.signman, mode="drop"),
            planes=pkv.planes.at[tgt].set(wire.planes, mode="drop"),
            dict_syms=pkv.dict_syms.at[tgt].set(wire.dict_syms, mode="drop"),
            esc_pos=pkv.esc_pos.at[tgt].set(wire.esc_pos, mode="drop"),
            esc_raw=pkv.esc_raw.at[tgt].set(wire.esc_raw, mode="drop"))
    else:
        pkv = pkv._replace(
            raw_pages=pkv.raw_pages.at[tgt].set(wire.raw_pages, mode="drop"))
    used = pkv.page_used.at[tgt].set(True, mode="drop")
    cols = jnp.arange(maxp)
    padded = jnp.zeros((maxp,), jnp.int32).at[col0 + jnp.arange(n_cols)].set(
        pages.astype(jnp.int32), mode="drop")
    prev = pkv.page_table[slot]                      # kept below col0
    row = jnp.where(cols < col0, prev,
                    jnp.where(cols < nfull, padded, -1))
    pt = jax.lax.dynamic_update_index_in_dim(pkv.page_table, row, slot, 0)
    ring = jax.lax.dynamic_update_index_in_dim(pkv.ring, wire.ring, slot, 0)
    return pkv._replace(page_table=pt, page_used=used, ring=ring)


def release_pages(pkv: PagedKV, slots_mask: jax.Array,
                  free_mask: Optional[jax.Array] = None) -> PagedKV:
    """Unmap masked slots' table rows and free their pages.

    ``free_mask`` None (no sharing): every page referenced by a masked row
    is freed.  With prefix sharing the host passes ``free_mask`` (n_pages,)
    bool — exactly the pages whose refcount hit zero — so pages still
    referenced by other slots' rows stay ``page_used``.
    """
    pt = pkv.page_table
    if free_mask is None:
        n_pages = pkv.page_used.shape[0]
        owned = slots_mask[:, None] & (pt >= 0)
        tgt = jnp.where(owned, pt, n_pages).reshape(-1)  # sentinel drops
        used = pkv.page_used.at[tgt].set(False, mode="drop")
    else:
        used = pkv.page_used & ~free_mask
    pt2 = jnp.where(slots_mask[:, None], -1, pt)
    return pkv._replace(page_table=pt2, page_used=used)
