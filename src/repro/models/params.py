"""Declarative parameter tables: one source of truth for shapes, shardings
and initializers.

Modules declare ``{name: PDef(shape, spec, init)}``; the table is then used
to (1) initialize real arrays for smoke/e2e tests, (2) produce
ShapeDtypeStruct + NamedSharding for the dry-run, (3) drive FSDP placement
(an extra "data" axis on the largest eligible dim, gathered explicitly —
and LEXI-compressed — inside the scan body).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PDef:
    shape: Tuple[int, ...]
    spec: Tuple[Optional[str], ...]   # mesh axis per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | normal:<std>
    dtype: Any = jnp.bfloat16
    fsdp_dim: Optional[int] = None    # filled by apply_fsdp

    def partition_spec(self) -> P:
        return P(*self.spec)


Table = Dict[str, Any]   # nested dict with PDef leaves


def is_pdef(x) -> bool:
    return isinstance(x, PDef)


def tmap(fn: Callable[[PDef], Any], table: Table) -> Any:
    return jax.tree_util.tree_map(fn, table, is_leaf=is_pdef)


def stack(table: Table, n: int) -> Table:
    """Prepend a scan (layer) dimension to every leaf."""
    return tmap(lambda d: dataclasses.replace(
        d, shape=(n,) + d.shape, spec=(None,) + d.spec,
        fsdp_dim=None if d.fsdp_dim is None else d.fsdp_dim + 1), table)


def apply_fsdp(table: Table, data_axes: Tuple[str, ...], data_size: int,
               min_size: int) -> Table:
    """Shard the largest eligible replicated dim over the data axes.

    Skips leaves that are small or have no divisible free dim.  The chosen
    dim is recorded so the forward pass knows to all-gather (compressed)
    before use.
    """

    def one(d: PDef) -> PDef:
        size = int(np.prod(d.shape))
        if size < min_size:
            return d
        cands = [(dim, s) for dim, (s, sp) in enumerate(zip(d.shape, d.spec))
                 if sp is None and s % data_size == 0 and s > 1]
        if not cands:
            return d
        dim = max(cands, key=lambda c: c[1])[0]
        entry = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)
        spec = tuple(entry if i == dim else sp
                     for i, sp in enumerate(d.spec))
        return dataclasses.replace(d, spec=spec, fsdp_dim=dim)

    return tmap(one, table)


def init_params(table: Table, key: jax.Array) -> Any:
    """Materialize real arrays (host/small-scale use: smoke tests, examples)."""
    leaves, treedef = jax.tree_util.tree_flatten(table, is_leaf=is_pdef)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            a = jnp.zeros(d.shape, d.dtype)
        elif d.init == "ones":
            a = jnp.ones(d.shape, d.dtype)
        else:
            std = float(d.init.split(":")[1]) if ":" in d.init else 0.02
            a = (jax.random.normal(k, d.shape, jnp.float32) * std
                 ).astype(d.dtype)
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(table: Table) -> Any:
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    return tmap(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), table)


def param_pspecs(table: Table) -> Any:
    """PartitionSpec pytree for shard_map in_specs / NamedSharding."""
    return tmap(lambda d: d.partition_spec(), table)


def fsdp_dims(table: Table) -> Any:
    """Pytree of Optional[int]: which dim to all-gather over data."""
    return tmap(lambda d: d.fsdp_dim, table)


def local_view(table: Table, mesh_shape: Dict[str, int]) -> Any:
    """Per-shard shapes (what shard_map sees) — for memory estimates."""

    def one(d: PDef):
        shape = []
        for s, sp in zip(d.shape, d.spec):
            axes = sp if isinstance(sp, tuple) else (sp,) if sp else ()
            div = int(np.prod([mesh_shape[a] for a in axes])) if axes else 1
            shape.append(s // div)
        return tuple(shape)

    return tmap(one, table)
