"""Attention blocks (manual-SPMD): GQA, MLA, local/global, decode partials.

Head-parallel TP with three projection regimes (chosen statically per arch):

  (a) ``n_kv_heads % tp == 0`` — classic: kv heads column-sharded, query
      heads grouped per kv head (query heads padded to a multiple of both
      tp and n_kv; pad heads have zero-init weights).
  (b) ``n_kv_heads % tp != 0`` (e.g. hymba kv=5, qwen2.5 kv=8 < tp=16) —
      kv projections are ROW-parallel (input dim sharded) + one psum so every
      shard holds all kv heads with no duplicated parameters or FLOPs; each
      shard's query heads then dynamically select their kv head.
  MLA — the latent c_kv (kv_lora + rope) is row-parallel like (b); per-head
      up-projections and queries are column-sharded.

Decode uses the sequence-sharded cache: every shard holds S/tp cache slots
for ALL heads and computes a partial attention merged with one tiny psum
(``layers.merge_partials``) — no head-divisibility constraint, balanced
memory, and the cache itself is LEXI-block-compressed (models/cache.py).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core import collectives as cl
from repro.kernels.decode_attend import WINDOW_NONE
from . import layers
from .layers import (AttnSpec, apply_rope, matmul_f32, pdot, raw_weight,
                     rope_tables)
from .params import PDef


# "no window" sentinel for traced per-layer windows — shared with the
# decode kernels/cache masking so every window comparison uses one value.
GLOBAL_WINDOW = WINDOW_NONE


def kv_mode(cfg: ModelConfig, tp: int) -> str:
    return "col" if cfg.n_kv_heads % tp == 0 else "row"


def layer_windows(cfg: ModelConfig):
    """Per-layer window sizes as data (int32 (L,)) so heterogeneous layers
    share a single scan.  None if the arch has no local-attention layers."""
    import numpy as np
    if cfg.attn_layout == "full" or cfg.window is None:
        return None
    w = np.full((cfg.n_layers,), GLOBAL_WINDOW, np.int32)
    if cfg.attn_layout == "alternating_local":
        w[0::2] = cfg.window
    elif cfg.attn_layout == "hymba_3global":
        w[:] = cfg.window
        for i in (0, cfg.n_layers // 2, cfg.n_layers - 1):
            w[i] = GLOBAL_WINDOW
    return w


def base_attn_spec(cfg: ModelConfig) -> AttnSpec:
    return AttnSpec(causal=True, softcap=cfg.attn_softcap,
                    windowed=layer_windows(cfg) is not None)


# ---------------------------------------------------------------------------
# parameter tables
# ---------------------------------------------------------------------------

def attn_table(cfg: ModelConfig, tp: int) -> Dict[str, PDef]:
    d, hd = cfg.d_model, cfg.head_dim
    hq = cfg.padded_heads(tp)
    t: Dict[str, PDef] = {}
    if cfg.mla is not None:
        m = cfg.mla
        t["wq"] = PDef((d, hq * (m.qk_nope_dim + m.qk_rope_dim)),
                       (None, "model"))
        t["w_dkv"] = PDef((d, m.kv_lora_rank + m.qk_rope_dim), ("model", None))
        t["kv_norm"] = PDef((m.kv_lora_rank,), (None,), "ones")
        t["w_uk"] = PDef((m.kv_lora_rank, hq * m.qk_nope_dim), (None, "model"))
        t["w_uv"] = PDef((m.kv_lora_rank, hq * m.v_dim), (None, "model"))
        t["wo"] = PDef((hq * m.v_dim, d), ("model", None))
        return t
    mode = kv_mode(cfg, tp)
    nkv = cfg.n_kv_heads
    t["wq"] = PDef((d, hq * hd), (None, "model"))
    if mode == "col":
        t["wk"] = PDef((d, nkv * hd), (None, "model"))
        t["wv"] = PDef((d, nkv * hd), (None, "model"))
    else:
        t["wk"] = PDef((d, nkv * hd), ("model", None))
        t["wv"] = PDef((d, nkv * hd), ("model", None))
    t["wo"] = PDef((hq * hd, d), ("model", None))
    if cfg.qkv_bias:
        t["bq"] = PDef((hq * hd,), ("model",), "zeros")
        t["bk"] = PDef((nkv * hd,), ("model",) if mode == "col" else (None,),
                       "zeros")
        t["bv"] = PDef((nkv * hd,), ("model",) if mode == "col" else (None,),
                       "zeros")
    if cfg.qk_norm:
        t["q_norm"] = PDef((hd,), (None,), "ones")
        t["k_norm"] = PDef((hd,), (None,), "ones")
    return t


# ---------------------------------------------------------------------------
# shared projection helpers
# ---------------------------------------------------------------------------

class QKV(NamedTuple):
    q: jax.Array          # (B, Hq_loc, S, hd)  local query heads
    k: jax.Array          # (B, Hkv_eff, S, hd) kv heads used by this shard's q
    v: jax.Array
    g: int                # query heads per kv head in the flash call
    k_cache: jax.Array | None = None   # raw kv heads for the decode cache
    v_cache: jax.Array | None = None   # (col: local shard; row: full)


def _heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)


def project_qkv(cfg: ModelConfig, p, xg: jax.Array, positions: jax.Array,
                tp: int) -> QKV:
    """xg (B,S,D) full-seq; returns rope'd local q and shard-visible k/v."""
    hd = cfg.head_dim
    hq = cfg.padded_heads(tp)
    hq_loc = hq // tp
    nkv = cfg.n_kv_heads
    mode = kv_mode(cfg, tp)

    q = pdot(xg, p["wq"], p.get("bq"))
    q = _heads(q, hq_loc, hd)
    if mode == "col":
        k = _heads(pdot(xg, p["wk"], p.get("bk")), nkv // tp, hd)
        v = _heads(pdot(xg, p["wv"], p.get("bv")), nkv // tp, hd)
    else:
        # row-parallel: xg column slice x sharded weight rows, then psum.
        dsh = cfg.d_model // tp
        i = jax.lax.axis_index("model") * dsh
        xs = jax.lax.dynamic_slice_in_dim(xg, i, dsh, axis=-1)
        k = jax.lax.psum(matmul_f32(xs, p["wk"]), "model")
        v = jax.lax.psum(matmul_f32(xs, p["wv"]), "model")
        if cfg.qkv_bias:
            k, v = k + p["bk"].astype(jnp.float32), v + p["bv"].astype(jnp.float32)
        k = _heads(k.astype(jnp.bfloat16), nkv, hd)
        v = _heads(v.astype(jnp.bfloat16), nkv, hd)

    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if mode == "col":
        g = hq_loc // (nkv // tp)
        return QKV(q, k, v, g, k_cache=k, v_cache=v)
    # select this shard's kv head per local query head (g=1 flash)
    g_real = max(cfg.n_heads // max(nkv, 1), 1)
    ti = jax.lax.axis_index("model")
    qidx = ti * hq_loc + jnp.arange(hq_loc)
    kv_idx = jnp.clip(qidx // g_real, 0, nkv - 1)
    k_sel = jnp.take(k, kv_idx, axis=1)
    v_sel = jnp.take(v, kv_idx, axis=1)
    return QKV(q, k_sel, v_sel, 1, k_cache=k, v_cache=v)


def project_qkv_mla(cfg: ModelConfig, p, xg: jax.Array,
                    positions: jax.Array, tp: int
                    ) -> Tuple[QKV, jax.Array]:
    """MLA projections.  Returns (QKV with g=1, latent (B,S,lora+rope)).

    The latent (c_kv + rope key) is what the decode cache stores — LEXI
    compresses the *latent* stream (double compression synergy, DESIGN §4).
    """
    m = cfg.mla
    hq = cfg.padded_heads(tp)
    hq_loc = hq // tp
    dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_dim

    q = pdot(xg, p["wq"])
    b, s, _ = q.shape
    q = q.reshape(b, s, hq_loc, dn + dr).transpose(0, 2, 1, 3)

    # latent: row-parallel + psum (shared across heads); local at tp=1
    if tp == 1:
        lat = matmul_f32(xg, p["w_dkv"]).astype(jnp.bfloat16)
    else:
        dsh = cfg.d_model // tp
        i = jax.lax.axis_index("model") * dsh
        xs = jax.lax.dynamic_slice_in_dim(xg, i, dsh, axis=-1)
        lat = jax.lax.psum(matmul_f32(xs, p["w_dkv"]),
                           "model").astype(jnp.bfloat16)
    c_kv = layers.rms_norm(lat[..., :m.kv_lora_rank], p["kv_norm"],
                           cfg.norm_eps)
    k_rope = lat[..., m.kv_lora_rank:]                 # (B,S,dr)
    latent = jnp.concatenate([c_kv, k_rope], axis=-1)

    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, None], cos, sin)     # (B,1,S,dr)

    k_nope = _heads(pdot(c_kv, p["w_uk"]), hq_loc, dn)
    v = _heads(pdot(c_kv, p["w_uv"]), hq_loc, dv)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope[..., :dr].shape)], axis=-1)
    return QKV(q_full, k_full, v, 1), latent


# ---------------------------------------------------------------------------
# train / prefill forward
# ---------------------------------------------------------------------------

def attn_forward(cfg: ModelConfig, run: RunConfig, p, xg: jax.Array,
                 positions: jax.Array, spec: AttnSpec, tp: int,
                 window=None, want_cache: bool = False):
    """Full-sequence attention.  Input xg (B,S,D) gathered; output is the
    *partial* o-projection (caller psum_scatters back to seq-sharded).

    ``window`` is an optional traced per-layer window size (see
    ``layer_windows``).  ``want_cache`` additionally returns this shard's
    head-visible KV (or MLA latent) for the prefill→decode transition.
    """
    hd_v = cfg.mla.v_dim if cfg.mla is not None else cfg.head_dim
    if cfg.mla is not None:
        qkv, latent = project_qkv_mla(cfg, p, xg, positions, tp)
        aspec = spec._replace(
            scale=(cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim) ** -0.5)
        cache = latent if want_cache else None
    else:
        qkv = project_qkv(cfg, p, xg, positions, tp)
        aspec = spec
        cache = (qkv.k_cache, qkv.v_cache) if want_cache else None

    b, hq_loc, s, _ = qkv.q.shape
    out = layers.flash_attention(
        qkv.q, qkv.k, qkv.v, positions, positions, aspec, window=window,
        chunk_q=run.attn_chunk_q, chunk_kv=run.attn_chunk_kv)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq_loc * hd_v)
    o = matmul_f32(out, p["wo"])                         # partial over model
    return o, cache


# ---------------------------------------------------------------------------
# decode-phase projections (sequence-sharded cache; q gathered to full heads)
# ---------------------------------------------------------------------------

def decode_qkv(cfg: ModelConfig, p, h: jax.Array, pos, tp: int):
    """h (B,1,D) replicated -> (q_full (B,Hq,1,hd), new_vals (B,W)).

    q is all-gathered to FULL heads (tiny at S=1) because decode attention is
    context-parallel over the cache; the new token's K/V (or MLA latent) is
    returned full-width for the cache append.

    ``pos`` is the rope position — a scalar (whole-batch decode, every
    sequence at the same length) or a (B,) vector (continuous batching,
    per-slot lengths).  Both lower to per-batch rope tables.
    """
    hd = cfg.head_dim
    hq = cfg.padded_heads(tp)
    hq_loc = hq // tp
    b = h.shape[0]
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1),
                            (b,))[:, None]           # (B,1)

    if cfg.mla is not None:
        m = cfg.mla
        dn, dr = m.qk_nope_dim, m.qk_rope_dim
        q = pdot(h, p["wq"]).reshape(b, 1, hq_loc, dn + dr) \
            .transpose(0, 2, 1, 3)                       # (B,hq_loc,1,dn+dr)
        # latent for the new token (row-parallel psum, like prefill)
        dsh = cfg.d_model // tp
        i = jax.lax.axis_index("model") * dsh
        hs = jax.lax.dynamic_slice_in_dim(h, i, dsh, axis=-1)
        lat = jax.lax.psum(matmul_f32(hs, p["w_dkv"]),
                           "model").astype(jnp.bfloat16)[:, 0]      # (B, lora+dr)
        c_kv = layers.rms_norm(lat[..., :m.kv_lora_rank], p["kv_norm"],
                               cfg.norm_eps)
        cos, sin = rope_tables(posv, dr, cfg.rope_theta)
        cos, sin = cos[:, None], sin[:, None]            # (B,1,1,dr/2)
        k_rope = apply_rope(lat[:, None, None, m.kv_lora_rank:], cos, sin
                            )[:, 0, 0]                   # (B, dr)
        new_vals = jnp.concatenate([c_kv, k_rope], axis=-1)
        # absorbed query: q_lat = [q_nope @ W_uk(head), q_rope]
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q_rope = apply_rope(q_rope, cos, sin)
        w_uk = raw_weight(p["w_uk"]).reshape(m.kv_lora_rank, hq_loc, dn)
        q_lat = jnp.einsum("bhsd,lhd->bhsl", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32)).astype(jnp.bfloat16)
        q_full = jnp.concatenate([q_lat, q_rope], axis=-1)  # (B,hq_loc,1,lora+dr)
        q_full = jax.lax.all_gather(q_full, "model", axis=1, tiled=True)
        return q_full, new_vals

    nkv = cfg.n_kv_heads
    mode = kv_mode(cfg, tp)
    q = pdot(h, p["wq"], p.get("bq")).reshape(b, 1, hq_loc, hd) \
        .transpose(0, 2, 1, 3)
    if mode == "col":
        k = pdot(h, p["wk"], p.get("bk")).reshape(b, 1, nkv // tp, hd) \
            .transpose(0, 2, 1, 3)
        v = pdot(h, p["wv"], p.get("bv")).reshape(b, 1, nkv // tp, hd) \
            .transpose(0, 2, 1, 3)
    else:
        dsh = cfg.d_model // tp
        i = jax.lax.axis_index("model") * dsh
        hs = jax.lax.dynamic_slice_in_dim(h, i, dsh, axis=-1)
        k = jax.lax.psum(matmul_f32(hs, p["wk"]), "model")
        v = jax.lax.psum(matmul_f32(hs, p["wv"]), "model")
        if cfg.qkv_bias:
            k, v = k + p["bk"].astype(jnp.float32), v + p["bv"].astype(jnp.float32)
        k = k.astype(jnp.bfloat16).reshape(b, 1, nkv, hd).transpose(0, 2, 1, 3)
        v = v.astype(jnp.bfloat16).reshape(b, 1, nkv, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_tables(posv, hd, cfg.rope_theta)
    cos, sin = cos[:, None], sin[:, None]                # (B,1,1,hd/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q_full = jax.lax.all_gather(q, "model", axis=1, tiled=True)  # (B,Hq,1,hd)
    if mode == "col":
        k = jax.lax.all_gather(k, "model", axis=1, tiled=True)
        v = jax.lax.all_gather(v, "model", axis=1, tiled=True)
        # collapse kv replication when tp > nkv is impossible in col mode
    new_vals = jnp.stack([k[:, :, 0], v[:, :, 0]], axis=2)  # (B,Hkv,2,hd)
    new_vals = new_vals.reshape(b, -1)                       # (B, 2*Hkv*hd)
    return q_full, new_vals


def decode_out(cfg: ModelConfig, p, merged: jax.Array, tp: int) -> jax.Array:
    """merged (B,Hq,1,hd_v) full heads -> PARTIAL o-projection (B,1,D) f32.

    Each shard slices its own heads and applies its wo rows; the block sums
    partials (attn + ssm for hybrids) and psums once.
    """
    b, hq, _, _ = merged.shape
    hq_loc = hq // tp
    ti = jax.lax.axis_index("model")
    loc = jax.lax.dynamic_slice_in_dim(merged, ti * hq_loc, hq_loc, axis=1)
    if cfg.mla is not None:
        m = cfg.mla
        w_uv = raw_weight(p["w_uv"]).reshape(m.kv_lora_rank, hq_loc, m.v_dim)
        loc = jnp.einsum("bhsl,lhv->bhsv", loc.astype(jnp.float32),
                         w_uv.astype(jnp.float32)).astype(jnp.bfloat16)
    loc = loc.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return matmul_f32(loc, p["wo"])


def new_vals_width_matches(cfg: ModelConfig) -> int:
    from .cache import kv_width
    return kv_width(cfg)
