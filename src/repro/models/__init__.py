"""Model zoo: manual-SPMD transformers / SSMs / hybrids with LEXI hooks."""

from . import attention, blocks, cache, layers, lm, moe, params, ssm  # noqa: F401
