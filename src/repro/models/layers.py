"""Model-zoo primitives (manual-SPMD style).

Everything here runs *inside* shard_map: collectives are explicit, activations
arrive with known per-shard layouts, and the LEXI codec hooks sit exactly at
the layouts' transition points (the TPU analogue of the paper's NoC ports).

Layout conventions (train/prefill):
  * block-boundary activations: (B_loc, S_loc, D) — batch over ("pod","data"),
    sequence over "model" (Megatron-SP);
  * inside attention/FFN: full sequence, heads/FFN columns over "model".

Numerics: params/activations bf16, attention logits + softmax f32, norm
accumulation f32, matmul accumulation f32 (then cast back).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.weights import PackedWeight, unpack_weight
from repro.kernels import ops as kops


def matmul_f32(x: jax.Array, w) -> jax.Array:
    """``x @ w`` with f32 accumulation — the single weight-consuming matmul
    primitive.  ``w`` is either a raw array or a ``PackedWeight`` leaf of
    the compressed serving store; packed leaves dispatch to
    ``kernels.ops.matmul_packed`` on the backend baked in at pack time
    (fused decompress+matmul, or exact unpack-then-einsum)."""
    if isinstance(w, PackedWeight):
        return kops.matmul_packed(x, w)
    return jnp.einsum("...k,kn->...n", x, w,
                      preferred_element_type=jnp.float32)


def raw_weight(w):
    """Materialize a weight for non-matmul consumers (gathers, reshapes):
    exact in-graph decode for PackedWeight, identity for raw arrays."""
    return unpack_weight(w) if isinstance(w, PackedWeight) else w


def pdot(x: jax.Array, w, bias: jax.Array | None = None) -> jax.Array:
    """x @ w with f32 accumulation, bf16 result (MXU dtype policy)."""
    out = matmul_f32(x, w)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(jnp.bfloat16)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(jnp.bfloat16)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_tables(positions: jax.Array, dim: int, theta: float
                ) -> Tuple[jax.Array, jax.Array]:
    """positions (...,S) -> (cos, sin) of shape (...,S, dim/2), f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B,H,S,hd); cos/sin (S,hd/2) or broadcastable."""
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., ::2], xf[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash attention (chunked online softmax, pure JAX — the structural
# equivalent of a fused kernel: HLO working set is (chunk_q × chunk_kv)).
# Supports causal, sliding-window, softcap and GQA via kv-head groups.
# ---------------------------------------------------------------------------

NEG_INF = -2.0e38


class AttnSpec(NamedTuple):
    causal: bool = True
    softcap: Optional[float] = None
    scale: Optional[float] = None
    windowed: bool = False             # if True a traced window size is given


def _mask(qp, kp, spec: AttnSpec, window):
    """window may be a *traced* scalar (per-layer windows under one scan:
    global layers pass 2^30).  Structure stays static either way."""
    m = jnp.ones((qp.shape[0], kp.shape[0]), bool)
    if spec.causal:
        m &= kp[None, :] <= qp[:, None]
    if spec.windowed:
        m &= kp[None, :] > (qp[:, None] - window)
    return m


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, kv_pos: jax.Array,
                    spec: AttnSpec, *, window=None, chunk_q: int = 512,
                    chunk_kv: int = 512) -> jax.Array:
    """q (B,Hq,Sq,hd), k/v (B,Hkv,Skv,hd) -> (B,Hq,Sq,hd), all local.

    GQA: Hq must be a multiple of Hkv; query heads are grouped per kv head.
    Memory is O(chunk_q * chunk_kv) per (batch, head) — flash-style.

    Pure-causal full-square calls take the triangle-only pair schedule
    (skips the ~2x of chunk pairs that are fully masked — §Perf iteration).
    """
    if (spec.causal and not spec.windowed and q.shape[2] == k.shape[2]
            and q.shape[2] > max(chunk_q, chunk_kv)
            and q_pos.shape == kv_pos.shape):
        return _flash_causal_pairs(q, k, v, q_pos, spec,
                                   chunk=min(chunk_q, chunk_kv))
    return _flash_rect(q, k, v, q_pos, kv_pos, spec, window=window,
                       chunk_q=chunk_q, chunk_kv=chunk_kv)


def _flash_rect(q, k, v, q_pos, kv_pos, spec: AttnSpec, *, window,
                chunk_q, chunk_kv) -> jax.Array:
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    hd_v = v.shape[-1]              # may differ from hd (MLA: v_dim < qk_dim)
    g = hq // hkv
    scale = spec.scale if spec.scale is not None else hd ** -0.5
    cq = min(chunk_q, sq)
    ckv = min(chunk_kv, skv)
    nq, nkv = sq // cq, skv // ckv
    assert sq % cq == 0 and skv % ckv == 0, (sq, cq, skv, ckv)

    qc = q.reshape(b, hkv, g, nq, cq, hd)
    qp = q_pos.reshape(nq, cq)
    kc = k.reshape(b, hkv, nkv, ckv, hd)
    vc = v.reshape(b, hkv, nkv, ckv, hd_v)
    kp = kv_pos.reshape(nkv, ckv)

    def q_step(qi):
        qb = qc[:, :, :, qi]                    # (B,Hkv,g,cq,hd)
        qpb = qp[qi]

        def kv_step(carry, inp):
            out, m, l = carry
            kb, vb, kpb = inp                   # (B,Hkv,ckv,hd), (ckv,)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, spec.softcap)
            msk = _mask(qpb, kpb, spec, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bhkd->bhgqd", p,
                            vb.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            out = out * alpha[..., None] + pv
            return (out, m_new, l), None

        init = (jnp.zeros((b, hkv, g, cq, hd_v), jnp.float32),
                jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32),
                jnp.zeros((b, hkv, g, cq), jnp.float32))
        (out, m, l), _ = jax.lax.scan(
            kv_step, init,
            (jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0), kp))
        return (out / jnp.maximum(l, 1e-30)[..., None]).astype(jnp.bfloat16)

    outs = jax.lax.map(q_step, jnp.arange(nq))     # (nq,B,Hkv,g,cq,hd_v)
    outs = jnp.moveaxis(outs, 0, 3)                # (B,Hkv,g,nq,cq,hd_v)
    return outs.reshape(b, hq, sq, hd_v)


def _flash_causal_pairs(q, k, v, pos, spec: AttnSpec, *, chunk) -> jax.Array:
    """Causal flash over the lower-triangular (q_chunk, kv_chunk) pairs only.

    The rectangle schedule computes nq*nkv chunk pairs and masks half; this
    iterates the n(n+1)/2 live pairs — a ~2x attention-FLOP saving that the
    roofline's useful-FLOPs ratio shows directly.  Accumulators are held for
    all q chunks (f32) and updated by scatter at the pair's q index.
    """
    b, hq, s, hd = q.shape
    _, hkv, _, _ = k.shape
    hd_v = v.shape[-1]
    g = hq // hkv
    scale = spec.scale if spec.scale is not None else hd ** -0.5
    c = min(chunk, s)
    n = s // c
    assert s % c == 0

    qc = q.reshape(b, hkv, g, n, c, hd)
    kc = k.reshape(b, hkv, n, c, hd)
    vc = v.reshape(b, hkv, n, c, hd_v)
    pc = pos.reshape(n, c)

    import numpy as _np
    pairs = _np.array([(qi, ki) for qi in range(n) for ki in range(qi + 1)],
                      _np.int32)

    def step(carry, pair):
        out, m, l = carry                       # (B,hkv,g,n,c,·)/(...,n,c)
        qi, ki = pair[0], pair[1]
        qb = jnp.take(qc, qi, axis=3)           # (B,hkv,g,c,hd)
        kb = jnp.take(kc, ki, axis=2)
        vb = jnp.take(vc, ki, axis=2)
        qp = jnp.take(pc, qi, axis=0)
        kp = jnp.take(pc, ki, axis=0)
        sc = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                        preferred_element_type=jnp.float32) * scale
        sc = softcap(sc, spec.softcap)
        msk = kp[None, :] <= qp[:, None]
        sc = jnp.where(msk[None, None, None], sc, NEG_INF)
        m_old = jnp.take(m, qi, axis=3)
        l_old = jnp.take(l, qi, axis=3)
        o_old = jnp.take(out, qi, axis=3)
        m_new = jnp.maximum(m_old, sc.max(-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m_old - m_new)
        l_new = l_old * alpha + p.sum(-1)
        pv = jnp.einsum("bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        o_new = o_old * alpha[..., None] + pv
        out = jax.lax.dynamic_update_index_in_dim(out, o_new, qi, 3)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 3)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 3)
        return (out, m, l), None

    init = (jnp.zeros((b, hkv, g, n, c, hd_v), jnp.float32),
            jnp.full((b, hkv, g, n, c), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, n, c), jnp.float32))
    (out, m, l), _ = jax.lax.scan(step, init, jnp.asarray(pairs))
    out = out / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(jnp.bfloat16).reshape(b, hq, s, hd_v)


def attention_partial(q: jax.Array, k: jax.Array, v: jax.Array,
                      valid: jax.Array, spec: AttnSpec,
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Decode-phase partial attention over a local KV shard.

    q (B,Hq,1,hd); k/v (B,Hkv,L,hd); valid (B,L) bool marks live cache slots
    (windowing for decode is folded into ``valid`` by the cache layer).
    Returns (out_unnormalized (B,Hq,1,hd) f32, m (B,Hq,1), l (B,Hq,1)) for the
    cross-shard logsumexp merge (context-parallel decode).
    """
    b, hq, _, hd = q.shape
    _, hkv, L, _ = k.shape
    g = hq // hkv
    scale = spec.scale if spec.scale is not None else hd ** -0.5
    qb = q.reshape(b, hkv, g, 1, hd)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, k,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, spec.softcap)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, None, :], p, 0.0)
    l = p.sum(axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return (out.reshape(b, hq, 1, v.shape[-1]), m.reshape(b, hq, 1),
            l.reshape(b, hq, 1))


def merge_partials(out: jax.Array, m: jax.Array, l: jax.Array,
                   axis_name) -> jax.Array:
    """Combine per-shard partial attention over ``axis_name``.

    out (B,H,1,hd) f32 unnormalized, m/l (B,H,1).  One tiny psum per decode
    step — the price of the always-divisible sequence-sharded cache.
    """
    m_g = jax.lax.pmax(m, axis_name)
    w = jnp.exp(m - m_g)
    num = jax.lax.psum(out * w[..., None], axis_name)
    den = jax.lax.psum(l * w, axis_name)
    return (num / jnp.maximum(den, 1e-30)[..., None]).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# Activation functions
# ---------------------------------------------------------------------------

def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return (jax.nn.silu(gate.astype(jnp.float32))
            * up.astype(jnp.float32)).astype(jnp.bfloat16)


def gelu_tanh(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True
                       ).astype(jnp.bfloat16)
