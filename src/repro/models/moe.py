"""Mixture-of-Experts FFN with expert parallelism and LEXI-compressed
dispatch (manual-SPMD).

Experts are sharded over "model" (EP).  Token dispatch/return cross the ICI
through ``lexi_all_to_all`` — exactly the inter-chiplet activation traffic
the paper compresses (its Fig 1c reports MoE blocks gain 36 %).  Capacity-
factor dispatch with drop-on-overflow keeps every shape static.

Shared experts (deepseek-v2) run as a dense Megatron FFN on every token.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core import collectives as cl
from . import layers
from .params import PDef


def moe_table(cfg: ModelConfig, tp: int) -> Dict[str, PDef]:
    d = cfg.d_model
    e = cfg.moe
    assert e.n_experts % tp == 0, (e.n_experts, tp)
    t = {
        "router": PDef((d, e.n_experts), (None, None), "normal:0.006"),
        "w_gate": PDef((e.n_experts, d, e.d_ff), ("model", None, None)),
        "w_up": PDef((e.n_experts, d, e.d_ff), ("model", None, None)),
        "w_down": PDef((e.n_experts, e.d_ff, d), ("model", None, None)),
    }
    if e.n_shared:
        f = e.n_shared * e.d_ff
        t["ws_gate"] = PDef((d, f), (None, "model"))
        t["ws_up"] = PDef((d, f), (None, "model"))
        t["ws_down"] = PDef((f, d), ("model", None))
    return t


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    e = cfg.moe
    c = int(n_tokens * e.top_k * e.capacity_factor / e.n_experts) + 1
    return -(-c // 8) * 8    # pad to 8 for tidy layouts


def moe_forward(cfg: ModelConfig, run: RunConfig, p, x: jax.Array,
                tp: int) -> Tuple[jax.Array, jax.Array]:
    """x (B,S_loc,D) seq-sharded (NOT gathered: routing is per-token local).

    Returns (output (B,S_loc,D) bf16 — fully reduced, no caller psum needed —
    and the load-balancing aux loss (scalar, per shard)).
    """
    e = cfg.moe
    b, s_loc, d = x.shape
    n = b * s_loc
    xt = x.reshape(n, d)

    # --- routing (local) ------------------------------------------------
    logits = layers.matmul_f32(xt, p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, e.top_k)        # (n, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((e.n_experts,), jnp.float32).at[experts.reshape(-1)].add(
        1.0) / (n * e.top_k)
    aux = e.n_experts * jnp.sum(me * ce)

    # --- capacity-based dispatch ----------------------------------------
    cap = _capacity(n, cfg)
    flat_e = experts.reshape(-1)                          # (n*k,)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n), e.top_k)
    # slot within expert via one-hot cumsum (stable, order = token order)
    onehot = jax.nn.one_hot(flat_e, e.n_experts, dtype=jnp.int32)
    slot = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(n * e.top_k), flat_e]
    keep = slot < cap
    # dispatch buffer (E, cap, D); dropped tokens contribute nothing
    buf = jnp.zeros((e.n_experts, cap, d), jnp.bfloat16)
    src = jnp.where(keep, flat_t, n)                      # n = sentinel row
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
    buf = buf.at[flat_e, jnp.where(keep, slot, cap)].set(
        xt_pad[src], mode="drop")

    # --- EP all_to_all (LEXI-compressed activations; local at tp=1) -----
    el = e.n_experts // tp
    if tp == 1:
        moved = buf                                       # all experts local
    else:
        moved = cl.lexi_all_to_all(buf, "model", run.codec, 0, 0)
    moved = moved.reshape(tp, el, cap, d).transpose(1, 0, 2, 3) \
        .reshape(el, tp * cap, d)                         # tokens per local expert

    # --- expert FFN (local slice of experts; stacked packed leaves are
    # decoded per-expert in-graph via raw_weight) ------------------------
    h = layers.swiglu(
        jnp.einsum("ecd,edf->ecf", moved, layers.raw_weight(p["w_gate"]),
                   preferred_element_type=jnp.float32).astype(jnp.bfloat16),
        jnp.einsum("ecd,edf->ecf", moved, layers.raw_weight(p["w_up"]),
                   preferred_element_type=jnp.float32).astype(jnp.bfloat16))
    out = jnp.einsum("ecf,efd->ecd", h, layers.raw_weight(p["w_down"]),
                     preferred_element_type=jnp.float32).astype(jnp.bfloat16)

    # --- return a2a + combine -------------------------------------------
    out = out.reshape(el, tp, cap, d).transpose(1, 0, 2, 3) \
        .reshape(e.n_experts, cap, d)
    back = (out if tp == 1
            else cl.lexi_all_to_all(out, "model", run.codec, 0, 0))
    gathered = back[flat_e, jnp.where(keep, slot, 0)]     # (n*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = jnp.zeros((n, d), jnp.float32).at[flat_t].add(
        gathered.astype(jnp.float32) * flat_g[:, None])

    # --- shared experts (dense Megatron FFN on the local tokens) --------
    if e.n_shared:
        hs = layers.swiglu(layers.pdot(xt, p["ws_gate"]),
                           layers.pdot(xt, p["ws_up"]))
        ys = layers.matmul_f32(hs, p["ws_down"])
        y = y + (ys if tp == 1
                 else jax.lax.psum(ys.astype(jnp.bfloat16), "model"
                                   ).astype(jnp.float32))

    return y.astype(jnp.bfloat16).reshape(b, s_loc, d), aux
