"""Compression baselines the paper compares against (Table 2).

* RLE — run-length encoding [Golomb 1966]: each maximal run of identical
  exponent bytes is emitted as (8-bit value, 8-bit run length).  The paper
  measures CR ≈ 0.64× (expansion) because runs are mostly length 1.
* BDI — base-delta-immediate [Pekhimenko et al. 2012] adapted to the exponent
  stream: fixed 32-byte blocks, 8-bit base (block minimum), per-block best
  delta width w ∈ {0,1,2,3,4} chosen from a 3-bit encoding tag (real BDI
  likewise picks the narrowest of several base+delta encodings per block);
  an incompressible block falls back to raw bytes.  The paper measures
  CR ≈ 2.4× with "3-bit delta encoding" — the dominant width here is indeed
  w = 3 (~72 % of blocks on normal-distributed exponents).

Both operate on the 8-bit exponent stream only, like LEXI, so the three CRs
are directly comparable.
"""

from __future__ import annotations

import numpy as np

RLE_VALUE_BITS = 8
RLE_RUN_BITS = 8
BDI_BLOCK = 32
BDI_DELTA_BITS = 3


def rle_bits(exp: np.ndarray) -> int:
    """Total coded bits under RLE (value, run-length) pairs."""
    x = np.ascontiguousarray(exp, dtype=np.uint8).reshape(-1)
    if x.size == 0:
        return 0
    boundaries = np.nonzero(np.diff(x) != 0)[0]
    n_runs = len(boundaries) + 1
    # Runs longer than 255 split into multiple pairs.
    run_starts = np.concatenate([[0], boundaries + 1, [x.size]])
    run_lens = np.diff(run_starts)
    n_pairs = int(np.ceil(run_lens / 255.0).sum())
    del n_runs
    return n_pairs * (RLE_VALUE_BITS + RLE_RUN_BITS)


def rle_cr(exp: np.ndarray) -> float:
    x = np.asarray(exp).reshape(-1)
    return (8.0 * x.size) / max(rle_bits(x), 1)


BDI_TAG_BITS = 3      # selects delta width 0..4 or raw fallback
BDI_WIDTHS = (0, 1, 2, 3, 4)


def bdi_bits(exp: np.ndarray, *, block: int = BDI_BLOCK) -> int:
    """Total coded bits under multi-width base+delta with raw fallback."""
    x = np.ascontiguousarray(exp, dtype=np.int32).reshape(-1)
    n = x.size
    if n == 0:
        return 0
    pad = (-n) % block
    x = np.pad(x, (0, pad), mode="edge")
    blocks = x.reshape(-1, block)
    span = blocks.max(axis=1) - blocks.min(axis=1)   # deltas from block min
    bits = np.full(len(blocks), BDI_TAG_BITS + block * 8, dtype=np.int64)
    for w in reversed(BDI_WIDTHS):                    # narrowest wins
        fits = span < (1 << w) if w > 0 else span == 0
        per = BDI_TAG_BITS + 8 + (block - 1) * w
        bits = np.where(fits, per, bits)
    return int(bits.sum())


def bdi_cr(exp: np.ndarray, **kw) -> float:
    x = np.asarray(exp).reshape(-1)
    return (8.0 * x.size) / max(bdi_bits(x, **kw), 1)
