"""LEXI-FW: the static-shape deployment codec (TPU adaptation of LEXI).

XLA collectives need static shapes, so the in-graph codec trades Huffman's
variable-length entropy coding for a *fixed-width dictionary* code while
keeping the paper's structure intact:

  * per-tensor histogram of the 8-bit exponent field (the paper's M-lane
    histogram unit),
  * frequency-ranked dictionary of the 2^k - 1 most common exponents (the
    paper's 32-entry codebook; default k=5 → 31 symbols + escape),
  * reserved ESCAPE index (2^k - 1) with a fixed-capacity side channel of
    (position, raw exponent) pairs (the paper's escape code + raw suffix),
  * sign+mantissa travel verbatim as one byte (the paper's flit layout
    {header, signs, mantissas, coded exponents}).

Wire cost per value: 8 (signman) + k (code) bits + C/N·(32+8) (escape slots)
+ 2^k·8/N (dictionary) ⇒ ~1.20× for k=5, ~1.30× for k=4, vs Huffman's ~1.47×.
Losslessness: exact whenever #escapes <= C; the encoder reports ``n_escapes``
so callers can detect overflow (never observed on real tensor distributions —
the paper reports zero escapes; property tests exercise the path anyway).

Everything here is jit/vmap/shard_map-compatible pure JAX; the Pallas kernels
in ``repro.kernels`` implement the hot paths with identical semantics.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import entropy as E
from . import packing

DEFAULT_K = 5
# Escape side-channel capacity as a fraction of N (1/128 ≈ 0.8% of values).
DEFAULT_ESC_FRAC = 128


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Compressed:
    """A LEXI-FW compressed BF16 tensor (all fields static-shaped).

    ``signman``: (N,) uint8 — sign<<7 | mantissa, verbatim.
    ``planes``:  (k, Np/32) uint32 — bit-plane-packed dictionary indices
                 (Np = N padded to a multiple of 32).
    ``dict_syms``: (2^k,) uint8 — frequency-ranked exponent dictionary;
                 slot 2^k - 1 is the reserved ESCAPE (stored as 0).
    ``esc_pos``: (C,) int32 — element positions of escapes (Np = empty slot).
    ``esc_raw``: (C,) uint8 — raw exponents for the escape slots.
    ``n_escapes``: () int32 — total escapes seen (> C means overflow).
    ``shape``/``k``/``n``: static aux data.
    """

    signman: jax.Array
    planes: jax.Array
    dict_syms: jax.Array
    esc_pos: jax.Array
    esc_raw: jax.Array
    n_escapes: jax.Array
    shape: Tuple[int, ...]
    k: int

    # -- pytree plumbing -----------------------------------------------------
    def tree_flatten(self):
        children = (self.signman, self.planes, self.dict_syms,
                    self.esc_pos, self.esc_raw, self.n_escapes)
        return children, (self.shape, self.k)

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, k = aux
        return cls(*children, shape=shape, k=k)

    # -- accounting -----------------------------------------------------------
    @property
    def n(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    def wire_bytes(self) -> int:
        """Bytes that actually cross a link / sit in HBM."""
        return (self.signman.size * 1 + self.planes.size * 4 +
                self.dict_syms.size * 1 + self.esc_pos.size * 4 +
                self.esc_raw.size * 1 + 4)

    def ratio(self) -> float:
        """Compression ratio vs raw BF16."""
        return (2.0 * self.n) / self.wire_bytes()


def esc_index(k: int) -> int:
    return (1 << k) - 1


def build_dictionary(hist: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Frequency-ranked dictionary + 256-entry encode LUT.

    Returns (dict_syms (2^k,) uint8, enc_lut (256,) uint32).  Exponents not in
    the top 2^k - 1 map to the ESCAPE index.  Mirrors the paper's bitonic-
    sort + LUT-programming pipeline (hw model: ``repro.hw.codebook_pipeline``).
    """
    esc = esc_index(k)
    order = jnp.argsort(-hist.astype(jnp.int32), stable=True)  # 256 symbols
    top = order[:esc]
    present = hist[top] > 0
    dict_syms = jnp.where(present, top, 0).astype(jnp.uint8)
    dict_syms = jnp.concatenate(
        [dict_syms, jnp.zeros((1,), jnp.uint8)])  # escape slot
    enc_lut = jnp.full((256,), esc, jnp.uint32)
    # Only program slots whose symbol actually occurs (absent symbols keep
    # the escape mapping, so duplicate zeros in dict_syms are harmless).
    slot = jnp.where(present, jnp.arange(esc, dtype=jnp.uint32),
                     jnp.uint32(esc))
    enc_lut = enc_lut.at[top.astype(jnp.int32)].set(slot)
    return dict_syms, enc_lut


@functools.partial(jax.jit, static_argnames=("k", "esc_capacity"))
def compress(x: jax.Array, *, k: int = DEFAULT_K,
             esc_capacity: int | None = None) -> Compressed:
    """Compress a BF16 tensor (any shape) into a :class:`Compressed`."""
    shape = tuple(x.shape)
    u16 = E.jnp_to_u16(x).reshape(-1)
    n = u16.size
    np_ = packing.pad_to_lanes(n)
    c = esc_capacity if esc_capacity is not None else max(n // DEFAULT_ESC_FRAC, 8)
    esc = esc_index(k)

    signman = E.jnp_signman(u16)
    exp = ((u16 >> 7) & 0xFF).astype(jnp.int32)
    hist = jnp.zeros((256,), jnp.int32).at[exp].add(1)
    dict_syms, enc_lut = build_dictionary(hist, k)

    codes = enc_lut[exp]                                   # (n,) uint32
    codes = jnp.pad(codes, (0, np_ - n))                   # pad w/ code 0
    planes = packing.bitplane_pack(codes, k)               # (k, np/32)

    esc_mask = codes[:n] == esc
    slot = jnp.cumsum(esc_mask.astype(jnp.int32)) - 1       # slot per escape
    n_escapes = jnp.sum(esc_mask.astype(jnp.int32))
    write_slot = jnp.where(esc_mask & (slot < c), slot, c)  # overflow -> drop
    esc_pos = jnp.full((c + 1,), np_, jnp.int32).at[write_slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")[:c]
    esc_raw = jnp.zeros((c + 1,), jnp.uint8).at[write_slot].set(
        exp.astype(jnp.uint8), mode="drop")[:c]

    return Compressed(signman=signman, planes=planes, dict_syms=dict_syms,
                      esc_pos=esc_pos, esc_raw=esc_raw, n_escapes=n_escapes,
                      shape=shape, k=k)


@jax.jit
def decompress(ct: Compressed) -> jax.Array:
    """Exact inverse of :func:`compress` (given no escape overflow)."""
    n = ct.n
    codes = packing.bitplane_unpack(ct.planes, ct.k)[:n]     # (n,) uint32
    exp = ct.dict_syms[codes.astype(jnp.int32)]              # (n,) uint8
    # Patch escapes from the side channel (sentinel positions drop).
    exp = exp.at[ct.esc_pos].set(ct.esc_raw, mode="drop")
    u16 = E.jnp_combine(ct.signman, exp)
    return E.jnp_from_u16(u16).reshape(ct.shape)


# ---------------------------------------------------------------------------
# Dictionary-free variant for inner loops (collectives): the dictionary is
# built per call anyway, but some call sites (e.g. a2a dispatch) prefer a
# caller-provided dictionary so all shards agree on the mapping.
# ---------------------------------------------------------------------------

def compress_with_dict(x: jax.Array, dict_syms: jax.Array, enc_lut: jax.Array,
                       *, k: int = DEFAULT_K,
                       esc_capacity: int | None = None) -> Compressed:
    shape = tuple(x.shape)
    u16 = E.jnp_to_u16(x).reshape(-1)
    n = u16.size
    np_ = packing.pad_to_lanes(n)
    c = esc_capacity if esc_capacity is not None else max(n // DEFAULT_ESC_FRAC, 8)
    esc = esc_index(k)
    signman = E.jnp_signman(u16)
    exp = ((u16 >> 7) & 0xFF).astype(jnp.int32)
    codes = enc_lut[exp]
    codes = jnp.pad(codes, (0, np_ - n))
    planes = packing.bitplane_pack(codes, k)
    esc_mask = codes[:n] == esc
    slot = jnp.cumsum(esc_mask.astype(jnp.int32)) - 1
    n_escapes = jnp.sum(esc_mask.astype(jnp.int32))
    write_slot = jnp.where(esc_mask & (slot < c), slot, c)
    esc_pos = jnp.full((c + 1,), np_, jnp.int32).at[write_slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")[:c]
    esc_raw = jnp.zeros((c + 1,), jnp.uint8).at[write_slot].set(
        exp.astype(jnp.uint8), mode="drop")[:c]
    return Compressed(signman=signman, planes=planes, dict_syms=dict_syms,
                      esc_pos=esc_pos, esc_raw=esc_raw, n_escapes=n_escapes,
                      shape=shape, k=k)


def wire_ratio(k: int = DEFAULT_K, esc_frac: int = DEFAULT_ESC_FRAC) -> float:
    """Analytic wire compression ratio of LEXI-FW (per-value amortized)."""
    bits = 8.0 + k + (40.0 / esc_frac)  # 32-bit pos + 8-bit raw per slot
    return 16.0 / bits
