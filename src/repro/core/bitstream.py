"""Bit-exact LEXI-H bitstream encode/decode (numpy oracle).

This is the software model of the paper's wire format: a per-layer canonical
codebook header followed by the concatenated prefix-free codewords (escapes
carry a raw 8-bit exponent suffix).  It is used as

* the oracle for compression-ratio experiments (Table 2),
* the reference the staged-LUT hardware decoder model is checked against,
* the storage format of LEXI-compressed checkpoints.

Encoding is vectorized (bit matrix + scatter + packbits); decoding walks the
stream with canonical first-code tables — O(total_symbols) python, used on
test/benchmark-sized streams.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from . import huffman
from .huffman import Codebook, ESCAPE, RAW_EXP_BITS

_HEADER_SYM_BITS = 8
_HEADER_LEN_BITS = 5


@dataclasses.dataclass(frozen=True)
class EncodedStream:
    """A compressed exponent stream + its codebook (flit payload model)."""

    payload: bytes          # packed codeword bitstream (MSB-first)
    n_symbols: int          # number of exponents coded
    total_bits: int         # payload bits actually used (<= 8*len(payload))
    book: Codebook

    @property
    def header_bits(self) -> int:
        return self.book.header_bits()

    @property
    def coded_bits(self) -> int:
        """Header + payload bits — the Table-2 denominator."""
        return self.total_bits + self.header_bits

    @property
    def cr(self) -> float:
        return (8.0 * self.n_symbols) / max(self.coded_bits, 1)


def encode(exp_stream: np.ndarray, book: Codebook | None = None) -> EncodedStream:
    """Encode an exponent byte stream with a (possibly fresh) codebook."""
    exp = np.ascontiguousarray(exp_stream, dtype=np.uint8).reshape(-1)
    if book is None:
        hist = np.bincount(exp, minlength=256).astype(np.float64)
        book = huffman.build_codebook(hist)
    # Per-element emitted (value, nbits): escapes append the raw exponent.
    codes = book.enc_code[exp].astype(np.uint64)
    lens = book.enc_len[exp].astype(np.int64)
    is_esc = ~book.in_alphabet[exp]
    codes = np.where(is_esc, (codes << RAW_EXP_BITS) | exp.astype(np.uint64), codes)
    lens = np.where(is_esc, lens + RAW_EXP_BITS, lens)
    total_bits = int(lens.sum())
    offsets = np.cumsum(lens) - lens
    lmax = int(lens.max()) if len(lens) else 1
    # bit j (MSB-first within each codeword) of element i:
    shift = (lens[:, None] - 1 - np.arange(lmax)[None, :])
    valid = shift >= 0
    bits = (codes[:, None] >> np.maximum(shift, 0).astype(np.uint64)) & 1
    pos = offsets[:, None] + np.arange(lmax)[None, :]
    flat = np.zeros(total_bits + 8, dtype=np.uint8)
    flat[pos[valid]] = bits[valid].astype(np.uint8)
    payload = np.packbits(flat[:total_bits]).tobytes()
    return EncodedStream(payload=payload, n_symbols=len(exp),
                         total_bits=total_bits, book=book)


def decode(stream: EncodedStream) -> np.ndarray:
    """Canonical decode back to the exponent byte stream (bit-exact)."""
    book = stream.book
    first_code, first_index, symbols = book.decode_tables()
    max_l = int(book.lengths.max())
    counts = np.bincount(book.lengths, minlength=max_l + 2)
    bits = np.unpackbits(np.frombuffer(stream.payload, dtype=np.uint8))
    out = np.empty(stream.n_symbols, dtype=np.uint8)
    p = 0
    for i in range(stream.n_symbols):
        code = 0
        l = 0
        while True:
            code = (code << 1) | int(bits[p]); p += 1; l += 1
            if l > max_l:
                raise ValueError("corrupt bitstream: no codeword match")
            idx = code - int(first_code[l])
            if counts[l] > 0 and 0 <= idx < counts[l]:
                sym = int(symbols[int(first_index[l]) + idx])
                break
        if sym == ESCAPE:
            raw = 0
            for _ in range(RAW_EXP_BITS):
                raw = (raw << 1) | int(bits[p]); p += 1
            out[i] = raw
        else:
            out[i] = sym
    assert p == stream.total_bits, (p, stream.total_bits)
    return out


# ---------------------------------------------------------------------------
# Whole-tensor (BF16) container: signman bytes + coded exponents + header.
# This is the checkpoint/wire format for full values, not just exponents.
# ---------------------------------------------------------------------------

def serialize_codebook(book: Codebook) -> bytes:
    """Canonical header: count + (symbol, length) pairs; symbol 0xFF+len marks
    ESCAPE (symbol id 256 does not fit a byte, so it is flagged)."""
    out = bytearray([len(book.symbols)])
    for s, l in zip(book.symbols, book.lengths):
        if int(s) == ESCAPE:
            out += bytes([0xFF, 0x80 | int(l)])
        else:
            out += bytes([int(s), int(l)])
    return bytes(out)


def deserialize_codebook(data: bytes) -> Tuple[Codebook, int]:
    n = data[0]
    symbols = np.zeros(n, dtype=np.int32)
    lengths = np.zeros(n, dtype=np.int32)
    for i in range(n):
        s, l = data[1 + 2 * i], data[2 + 2 * i]
        if l & 0x80:
            symbols[i], lengths[i] = ESCAPE, l & 0x7F
        else:
            symbols[i], lengths[i] = s, l
    codes = huffman.canonical_codes(
        {int(s): int(l) for s, l in zip(symbols, lengths)})
    enc_code = np.zeros(257, dtype=np.int64)
    enc_len = np.zeros(257, dtype=np.int32)
    in_alpha = np.zeros(256, dtype=bool)
    esc_code, esc_len = codes[ESCAPE]
    for s in range(256):
        if s in codes:
            enc_code[s], enc_len[s] = codes[s]
            in_alpha[s] = True
        else:
            enc_code[s], enc_len[s] = esc_code, esc_len
    enc_code[ESCAPE], enc_len[ESCAPE] = esc_code, esc_len
    book = Codebook(symbols=symbols, lengths=lengths, enc_code=enc_code,
                    enc_len=enc_len, in_alphabet=in_alpha)
    return book, 1 + 2 * n


def compress_bf16(u16: np.ndarray) -> bytes:
    """Full LEXI container for a BF16 tensor (given as uint16 bit patterns):

        [u32 n] [codebook] [signman bytes] [u32 payload_bits] [payload]
    """
    from . import entropy as E
    u16 = np.ascontiguousarray(u16, dtype=np.uint16).reshape(-1)
    _, exp, _ = E.split_fields(u16)
    sm = E.signman_byte(u16)
    st = encode(exp)
    out = bytearray()
    out += np.uint32(len(u16)).tobytes()
    out += serialize_codebook(st.book)
    out += sm.tobytes()
    out += np.uint32(st.total_bits).tobytes()
    out += st.payload
    return bytes(out)


def decompress_bf16(blob: bytes) -> np.ndarray:
    """Inverse of :func:`compress_bf16` -> uint16 bit patterns."""
    from . import entropy as E
    n = int(np.frombuffer(blob[:4], dtype=np.uint32)[0])
    book, consumed = deserialize_codebook(blob[4:])
    off = 4 + consumed
    sm = np.frombuffer(blob[off:off + n], dtype=np.uint8)
    off += n
    total_bits = int(np.frombuffer(blob[off:off + 4], dtype=np.uint32)[0])
    off += 4
    payload = blob[off:]
    st = EncodedStream(payload=payload, n_symbols=n, total_bits=total_bits,
                       book=book)
    exp = decode(st)
    sign = (sm >> 7).astype(np.uint16)
    man = (sm & 0x7F).astype(np.uint16)
    return E.combine_fields(sign, exp, man)


# ---------------------------------------------------------------------------
# LEXI-F32 (beyond-paper): the same exponent-only coding applied to float32.
# f32 = sign(1) | exp(8) | mantissa(23): exponents of optimizer states share
# the bell-shaped concentration the paper profiles for bf16, so coding the
# exponent byte takes 32 -> ~26.6 bits (~1.2x) — applied to checkpointed
# AdamW master/m (v is chi-squared-ish but still compresses ~1.15x).
# ---------------------------------------------------------------------------

def compress_f32(x: np.ndarray) -> bytes:
    """Container: [u32 n][codebook][signman24 3n bytes][u32 bits][payload]."""
    u32 = np.ascontiguousarray(x, dtype=np.float32).view(np.uint32).reshape(-1)
    exp = ((u32 >> 23) & 0xFF).astype(np.uint8)
    # sign bit + 23-bit mantissa packed as 3 little-endian bytes per value
    sm = ((u32 >> 31) << 23) | (u32 & 0x7FFFFF)
    sm_bytes = np.empty((u32.size, 3), np.uint8)
    sm_bytes[:, 0] = sm & 0xFF
    sm_bytes[:, 1] = (sm >> 8) & 0xFF
    sm_bytes[:, 2] = (sm >> 16) & 0xFF
    st = encode(exp)
    out = bytearray()
    out += np.uint32(u32.size).tobytes()
    out += serialize_codebook(st.book)
    out += sm_bytes.tobytes()
    out += np.uint32(st.total_bits).tobytes()
    out += st.payload
    return bytes(out)


def decompress_f32(blob: bytes) -> np.ndarray:
    n = int(np.frombuffer(blob[:4], dtype=np.uint32)[0])
    book, consumed = deserialize_codebook(blob[4:])
    off = 4 + consumed
    sm_bytes = np.frombuffer(blob[off:off + 3 * n], dtype=np.uint8
                             ).reshape(n, 3).astype(np.uint32)
    off += 3 * n
    total_bits = int(np.frombuffer(blob[off:off + 4], dtype=np.uint32)[0])
    payload = blob[off + 4:]
    st = EncodedStream(payload=payload, n_symbols=n, total_bits=total_bits,
                       book=book)
    exp = decode(st).astype(np.uint32)
    sm = sm_bytes[:, 0] | (sm_bytes[:, 1] << 8) | (sm_bytes[:, 2] << 16)
    u32 = ((sm >> 23) << 31) | (exp << 23) | (sm & 0x7FFFFF)
    return u32.astype(np.uint32).view(np.float32)
