"""LEXI-compressed ICI collectives (the TPU analogue of NoC-port codecs).

The paper places codecs at the egress/ingress ports of chiplet routers so
that activations/caches cross the interconnect compressed.  On a TPU pod the
"ports" are the collectives, so each wrapper here:

    pack (VPU, near compute)  ->  collective on packed buffers  ->  unpack

All wrappers are meant to be called *inside* ``shard_map`` (they use named
axes).  With ``CodecConfig.enabled=False`` they degrade to the plain
collective so compressed/uncompressed graphs differ only in the codec — this
is how the roofline A/B in EXPERIMENTS.md is produced.

Compressible collectives: all_gather / all_to_all / ppermute (pure data
movement) and the all-gather half of psum (reduce_scatter must stay
uncompressed: lossless exponent coding does not commute with addition — the
paper's NoC never reduces in transit, so this is the honest TPU mapping).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import fixed


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    """Where/how LEXI applies in a model run (first-class config knob)."""

    enabled: bool = True                # master switch (activations/ICI)
    weights: bool = True                # compressed-at-rest params (+FSDP AG)
    cache: bool = True                  # block-compressed hybrid caches
    grads: bool = True                  # compressed AG half of grad sync
    k: int = fixed.DEFAULT_K            # dictionary index width (bits)
    esc_frac: int = fixed.DEFAULT_ESC_FRAC  # escape capacity = N // esc_frac
    cache_block: int = 256              # tokens per compressed KV block
    # decode-attention backend: auto | pallas | interpret | jax (see
    # repro.kernels.ops.resolve_decode_backend).  auto = pallas on TPU,
    # pure-JAX elsewhere; interpret runs the fused kernels on CPU.
    decode_backend: str = "auto"
    # serving weight-matmul backend: auto | pallas | interpret | jax (see
    # repro.kernels.ops.resolve_weight_backend).  Same semantics: how
    # PackedWeight leaves are multiplied — fused decompress_matmul
    # (pallas/interpret) or exact unpack-then-einsum (jax).
    weight_backend: str = "auto"

    def esc_capacity(self, n: int) -> int:
        return max(n // self.esc_frac, 8)

    @classmethod
    def off(cls) -> "CodecConfig":
        return cls(enabled=False, weights=False, cache=False, grads=False)

    @classmethod
    def weights_only(cls) -> "CodecConfig":
        """Paper Table 3 middle row: offline-compressed weights only."""
        return cls(enabled=False, weights=True, cache=False, grads=False)


DEFAULT_CODEC = CodecConfig()


def shmap(f, mesh, in_specs, out_specs):
    """Project-standard shard_map: vma/rep checking off (the codec's scatter
    ops defeat replication inference; correctness is covered by tests).

    Version shim: jax >= 0.6 exposes ``jax.shard_map(..., check_vma=...)``;
    0.4.x has ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
    Every call site in the repo routes through here so the compat logic
    lives in exactly one place.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def _compress(x: jax.Array, cfg: CodecConfig) -> fixed.Compressed:
    return fixed.compress(x, k=cfg.k, esc_capacity=cfg.esc_capacity(x.size))


# ---------------------------------------------------------------------------
# all_gather
# ---------------------------------------------------------------------------

def compressed_all_gather(x: jax.Array, axis_name: str | Tuple[str, ...],
                          cfg: CodecConfig = DEFAULT_CODEC, *,
                          gather_axis: int = 0, tiled: bool = True) -> jax.Array:
    """all_gather with LEXI-FW packing on the wire.

    ``x`` is the local shard; the result concatenates all shards along
    ``gather_axis`` (tiled) or stacks a new leading axis (not tiled).
    """
    if not cfg.enabled:
        return jax.lax.all_gather(x, axis_name, axis=gather_axis, tiled=tiled)
    ct = _compress(x, cfg)
    gathered = jax.lax.all_gather(ct, axis_name, axis=0, tiled=False)
    parts = jax.vmap(fixed.decompress)(gathered)      # (S, *x.shape)
    if not tiled:
        # untiled inserts a NEW axis: gather_axis indexes the output's
        # ndim+1 axes, so it must not be folded modulo x.ndim
        return jnp.moveaxis(parts, 0, gather_axis)
    gather_axis = gather_axis % x.ndim          # normalize negative axes
    # tiled: fold the shard axis into gather_axis with one moveaxis+reshape
    # (constant trace size; a per-shard concat loop grows with shard count)
    moved = jnp.moveaxis(parts, 0, gather_axis)       # (..., S, g, ...)
    shape = list(x.shape)
    shape[gather_axis] = parts.shape[0] * x.shape[gather_axis]
    return moved.reshape(shape)


# ---------------------------------------------------------------------------
# psum = reduce_scatter (raw) + all_gather (compressed)
# ---------------------------------------------------------------------------

def _axis_size(axis_name) -> int:
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    size = 1
    for a in names:
        size *= jax.lax.psum(1, a)
    return int(size)


def compressed_psum(x: jax.Array, axis_name: str | Tuple[str, ...],
                    cfg: CodecConfig = DEFAULT_CODEC, *,
                    scatter_axis: int | None = None) -> jax.Array:
    """Allreduce as RS + LEXI-compressed AG (beyond-paper gradient trick).

    The RS half moves raw bf16 (it sums); the AG half moves packed bytes —
    total wire bytes drop from 2·(S-1)/S·|x| to (1 + 1/r)·(S-1)/S·|x| with r
    the packing ratio.  ``scatter_axis`` must divide by the axis size; if
    none is given the first divisible axis is used, and if none divides the
    call falls back to a plain (uncompressed) psum.
    """
    if not cfg.enabled:
        return jax.lax.psum(x, axis_name)
    size = _axis_size(axis_name)
    if scatter_axis is None:
        scatter_axis = next((i for i, d in enumerate(x.shape) if d % size == 0),
                            None)
        if scatter_axis is None:
            return jax.lax.psum(x, axis_name)
    part = jax.lax.psum_scatter(x, axis_name, scatter_dimension=scatter_axis,
                                tiled=True)
    return compressed_all_gather(part, axis_name, cfg, gather_axis=scatter_axis)


def sync_gradients(grads: Any, axis_names: Sequence[str],
                   cfg: CodecConfig = DEFAULT_CODEC) -> Any:
    """Data-parallel gradient synchronization for a pytree.

    Leaves are flattened and concatenated into one fused buffer (single
    collective — latency-optimal at scale), padded to the axis size, then
    mean-reduced with the compressed RS+AG schedule.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    sizes = [l.size for l in leaves]
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.bfloat16) for l in leaves])
    axis_size = 1
    for a in axis_names:
        axis_size *= jax.lax.psum(1, a)
    pad = (-flat.size) % int(axis_size)
    flat = jnp.pad(flat, (0, pad))
    if cfg.enabled and cfg.grads:
        total = compressed_psum(flat, tuple(axis_names), cfg)
    else:
        total = jax.lax.psum(flat, tuple(axis_names))
    total = total / axis_size
    out = []
    off = 0
    for sz, shp, leaf in zip(sizes, shapes, leaves):
        out.append(total[off:off + sz].reshape(shp).astype(leaf.dtype))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# all_to_all (MoE dispatch/return)
# ---------------------------------------------------------------------------

def compressed_all_to_all(x: jax.Array, axis_name: str,
                          cfg: CodecConfig = DEFAULT_CODEC, *,
                          split_axis: int = 0, concat_axis: int = 0) -> jax.Array:
    """all_to_all with per-destination-slice LEXI packing.

    ``x`` has its ``split_axis`` divisible by the axis size; each slice is
    compressed with its own dictionary (the paper's per-layer codebook --
    here per-destination), shuffled packed, and decompressed at the receiver.
    """
    if not cfg.enabled:
        return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)
    size = jax.lax.psum(1, axis_name)
    x = jnp.moveaxis(x, split_axis, 0)
    lead = x.shape[0]
    x = x.reshape((size, lead // size) + x.shape[1:])
    ct = jax.vmap(functools.partial(
        fixed.compress, k=cfg.k,
        esc_capacity=cfg.esc_capacity(x[0].size)))(x)
    shuffled = jax.tree_util.tree_map(
        lambda f: jax.lax.all_to_all(f, axis_name, split_axis=0,
                                     concat_axis=0, tiled=False), ct)
    parts = jax.vmap(fixed.decompress)(shuffled)
    parts = parts.reshape((lead,) + parts.shape[2:])
    parts = jnp.moveaxis(parts, 0, split_axis)
    if concat_axis != split_axis:
        parts = jnp.moveaxis(parts, split_axis, concat_axis)
    return parts


# ---------------------------------------------------------------------------
# ppermute (pipeline stage forwarding / halo exchange)
# ---------------------------------------------------------------------------

def compressed_ppermute(x: jax.Array, axis_name: str,
                        perm: Sequence[Tuple[int, int]],
                        cfg: CodecConfig = DEFAULT_CODEC) -> jax.Array:
    """collective_permute with LEXI packing (inter-stage activations)."""
    if not cfg.enabled:
        return jax.lax.ppermute(x, axis_name, perm)
    ct = _compress(x, cfg)
    moved = jax.tree_util.tree_map(
        lambda f: jax.lax.ppermute(f, axis_name, perm), ct)
    return fixed.decompress(moved)


# ---------------------------------------------------------------------------
# Differentiable wrappers — used in model *forward* passes.
#
# The codec's bit ops are not differentiable, but decompress∘compress is the
# identity (lossless), so each wrapper carries a custom VJP whose cotangent
# path is the transposed collective — itself LEXI-compressed when it is pure
# data movement (activation gradients cross the same links in reverse, and
# the paper's codec sits on every port).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def lexi_all_gather(x: jax.Array, axis_name, cfg: CodecConfig,
                    gather_axis: int = 0) -> jax.Array:
    """Differentiable compressed all_gather (tiled along ``gather_axis``)."""
    return compressed_all_gather(x, axis_name, cfg, gather_axis=gather_axis)


def _lag_fwd(x, axis_name, cfg, gather_axis):
    return lexi_all_gather(x, axis_name, cfg, gather_axis), None


def _lag_bwd(axis_name, cfg, gather_axis, _, ct):
    # transpose of (tiled) all_gather = psum_scatter; it sums, so it moves raw.
    return (jax.lax.psum_scatter(ct, axis_name,
                                 scatter_dimension=gather_axis, tiled=True),)


lexi_all_gather.defvjp(_lag_fwd, _lag_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def lexi_psum(x: jax.Array, axis_name, cfg: CodecConfig) -> jax.Array:
    """Differentiable psum whose AG half is compressed (see compressed_psum).

    Requires ``x.shape[0]`` divisible by the axis size when compression is on.
    """
    return compressed_psum(x, axis_name, cfg)


def _lps_fwd(x, axis_name, cfg):
    return lexi_psum(x, axis_name, cfg), None


def _lps_bwd(axis_name, cfg, _, ct):
    # JAX convention: transpose(psum) = psum (per-shard losses sum).  The
    # backward collective is itself an allreduce, so reuse the compressed
    # RS+AG schedule for it.
    return (compressed_psum(ct, axis_name, cfg),)


lexi_psum.defvjp(_lps_fwd, _lps_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def lexi_all_to_all(x: jax.Array, axis_name, cfg: CodecConfig,
                    split_axis: int = 0, concat_axis: int = 0) -> jax.Array:
    """Differentiable compressed all_to_all (MoE dispatch/return)."""
    return compressed_all_to_all(x, axis_name, cfg, split_axis=split_axis,
                                 concat_axis=concat_axis)


def _la2a_fwd(x, axis_name, cfg, split_axis, concat_axis):
    return lexi_all_to_all(x, axis_name, cfg, split_axis, concat_axis), None


def _la2a_bwd(axis_name, cfg, split_axis, concat_axis, _, ct):
    # all_to_all is its own transpose with split/concat swapped; gradients
    # are activations in transit -> compress them too.
    return (lexi_all_to_all(ct, axis_name, cfg, concat_axis, split_axis),)


lexi_all_to_all.defvjp(_la2a_fwd, _la2a_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def lexi_ppermute(x: jax.Array, axis_name,
                  perm: Tuple[Tuple[int, int], ...],
                  cfg: CodecConfig = DEFAULT_CODEC) -> jax.Array:
    """Differentiable compressed collective_permute (pipeline forwarding)."""
    return compressed_ppermute(x, axis_name, perm, cfg)


def _lpp_fwd(x, axis_name, perm, cfg):
    return lexi_ppermute(x, axis_name, perm, cfg), None


def _lpp_bwd(axis_name, perm, cfg, _, ct):
    inv = tuple((d, s) for (s, d) in perm)
    return (lexi_ppermute(ct, axis_name, inv, cfg),)


lexi_ppermute.defvjp(_lpp_fwd, _lpp_bwd)
