"""BF16 field extraction and exponent-stream entropy profiling (paper §3).

The paper's observation: BF16 exponent streams of LLM weights, activations
and hybrid caches carry < 3 bits of Shannon entropy and concentrate on < 32
distinct values, while mantissas are ~7-bit incompressible.  These utilities
extract the {sign, exponent, mantissa} fields and compute the statistics that
drive both the codec design and the Fig-1 reproduction.

Both numpy (host-side profiling, benchmarks) and jnp (in-graph, jit-able)
variants are provided.  BF16 layout: [sign(1) | exponent(8) | mantissa(7)].
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

BF16_EXP_BITS = 8
BF16_MAN_BITS = 7
EXP_ALPHABET = 256  # 8-bit exponent field


# ---------------------------------------------------------------------------
# numpy (host) variants
# ---------------------------------------------------------------------------

def to_bf16_u16(x: np.ndarray) -> np.ndarray:
    """View an array as BF16 bit patterns (uint16), rounding from wider types.

    Uses round-to-nearest-even via ml_dtypes so host profiling matches what a
    TPU would hold in HBM.
    """
    if x.dtype == np.uint16:
        return x
    if x.dtype == ml_dtypes.bfloat16:
        return x.view(np.uint16)
    return x.astype(ml_dtypes.bfloat16).view(np.uint16)


def split_fields(u16: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(sign, exponent, mantissa) uint8 arrays from BF16 bit patterns."""
    sign = (u16 >> 15).astype(np.uint8)
    exp = ((u16 >> 7) & 0xFF).astype(np.uint8)
    man = (u16 & 0x7F).astype(np.uint8)
    return sign, exp, man


def signman_byte(u16: np.ndarray) -> np.ndarray:
    """Pack {sign, mantissa} into one byte: sign<<7 | mantissa."""
    sign, _, man = split_fields(u16)
    return ((sign << 7) | man).astype(np.uint8)


def combine_fields(sign: np.ndarray, exp: np.ndarray, man: np.ndarray) -> np.ndarray:
    """Inverse of :func:`split_fields` -> uint16 BF16 bit patterns."""
    return (
        (sign.astype(np.uint16) << 15)
        | (exp.astype(np.uint16) << 7)
        | man.astype(np.uint16)
    )


def exponent_histogram(exp: np.ndarray) -> np.ndarray:
    """256-bin histogram of the exponent stream (float64 counts)."""
    return np.bincount(exp.reshape(-1), minlength=EXP_ALPHABET).astype(np.float64)


def shannon_entropy(hist: np.ndarray) -> float:
    """Shannon entropy (bits/symbol) of a histogram."""
    total = hist.sum()
    if total == 0:
        return 0.0
    p = hist[hist > 0] / total
    return float(-(p * np.log2(p)).sum())


@dataclasses.dataclass(frozen=True)
class ExponentStats:
    """Fig-1-style profile of one tensor/stream."""

    n: int
    exp_entropy_bits: float
    man_entropy_bits: float
    distinct_exponents: int
    top32_coverage: float      # fraction of values covered by the 32 most
                               # frequent exponents (paper: ~1.0)
    huffman_bits_per_exp: float  # optimal prefix-code cost (filled by codec)

    @property
    def exp_cr(self) -> float:
        """Exponent-only compression ratio at the Huffman code cost."""
        return BF16_EXP_BITS / max(self.huffman_bits_per_exp, 1e-9)

    @property
    def overall_cr(self) -> float:
        """Whole-BF16-value CR: sign+mantissa travel verbatim (8 bits)."""
        return 16.0 / (8.0 + max(self.huffman_bits_per_exp, 1e-9))


def profile_exponents(x: np.ndarray) -> ExponentStats:
    """Profile a tensor per paper §3.1 (entropy, distinct count, coverage)."""
    from . import huffman  # local import to avoid cycle

    u16 = to_bf16_u16(np.asarray(x))
    _, exp, man = split_fields(u16)
    hist = exponent_histogram(exp)
    man_hist = np.bincount(man.reshape(-1), minlength=128).astype(np.float64)
    order = np.argsort(-hist, kind="stable")
    top32 = hist[order[:32]].sum() / max(hist.sum(), 1.0)
    lengths = huffman.length_limited_lengths(hist, max_len=huffman.MAX_CODE_LEN)
    code_bits = sum(hist[s] * l for s, l in lengths.items())
    return ExponentStats(
        n=int(hist.sum()),
        exp_entropy_bits=shannon_entropy(hist),
        man_entropy_bits=shannon_entropy(man_hist),
        distinct_exponents=int((hist > 0).sum()),
        top32_coverage=float(top32),
        huffman_bits_per_exp=float(code_bits / max(hist.sum(), 1.0)),
    )


# ---------------------------------------------------------------------------
# jnp (in-graph) variants — used by the deployment codec and kernels' refs
# ---------------------------------------------------------------------------

def jnp_to_u16(x: jax.Array) -> jax.Array:
    """Bitcast a bf16 array to uint16 (casts other floats to bf16 first)."""
    if x.dtype != jnp.bfloat16:
        x = x.astype(jnp.bfloat16)
    return jax.lax.bitcast_convert_type(x, jnp.uint16)


def jnp_from_u16(u16: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(u16.astype(jnp.uint16), jnp.bfloat16)


def jnp_split_fields(u16: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    sign = (u16 >> 15).astype(jnp.uint8)
    exp = ((u16 >> 7) & 0xFF).astype(jnp.uint8)
    man = (u16 & 0x7F).astype(jnp.uint8)
    return sign, exp, man


def jnp_signman(u16: jax.Array) -> jax.Array:
    sign, _, man = jnp_split_fields(u16)
    return ((sign << 7) | man).astype(jnp.uint8)


def jnp_combine(signman: jax.Array, exp: jax.Array) -> jax.Array:
    """Rebuild uint16 BF16 patterns from a signman byte + exponent byte."""
    sm = signman.astype(jnp.uint16)
    return ((sm & 0x80) << 8) | (exp.astype(jnp.uint16) << 7) | (sm & 0x7F)


def jnp_exponent_histogram(exp: jax.Array) -> jax.Array:
    """256-bin histogram, int32, jit/vmap-friendly (scatter-add)."""
    flat = exp.reshape(-1).astype(jnp.int32)
    return jnp.zeros((EXP_ALPHABET,), jnp.int32).at[flat].add(1)


def jnp_entropy(hist: jax.Array) -> jax.Array:
    total = jnp.maximum(hist.sum(), 1).astype(jnp.float32)
    p = hist.astype(jnp.float32) / total
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.where(p > 0, p, 1.0)), 0.0))
