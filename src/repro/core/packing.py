"""Bit-plane packing of k-bit exponent codes into uint32 lanes (pure jnp).

The deployment codec stores each element's k-bit dictionary index "bit-plane
transposed": lane j of plane b holds bit b of element 32*i + j.  This layout
is fully vectorizable on the VPU (shift/and/sum — no horizontal dependencies),
is trivially tileable for Pallas BlockSpecs, and wastes zero bits:

    codes (..., N) uint32, N % 32 == 0   ->   planes (..., k, N // 32) uint32

The same functions are used by the pure-JAX deployment path, the Pallas
kernel references, and tests.
"""

from __future__ import annotations

import jax.numpy as jnp

LANES = 32


def pad_to_lanes(n: int) -> int:
    """Smallest multiple of 32 >= n."""
    return (n + LANES - 1) // LANES * LANES


def bitplane_pack(codes: jnp.ndarray, k: int) -> jnp.ndarray:
    """Pack k-bit codes (last dim divisible by 32) into uint32 planes."""
    assert codes.shape[-1] % LANES == 0, codes.shape
    x = codes.astype(jnp.uint32).reshape(*codes.shape[:-1], -1, LANES)
    lane = jnp.arange(LANES, dtype=jnp.uint32)
    planes = [
        jnp.sum(((x >> jnp.uint32(b)) & jnp.uint32(1)) << lane,
                axis=-1, dtype=jnp.uint32)
        for b in range(k)
    ]
    return jnp.stack(planes, axis=-2)  # (..., k, N/32)


def bitplane_unpack(planes: jnp.ndarray, k: int) -> jnp.ndarray:
    """Inverse of :func:`bitplane_pack` -> (..., N) uint32 codes."""
    assert planes.shape[-2] == k, planes.shape
    lane = jnp.arange(LANES, dtype=jnp.uint32)
    # (..., k, W, 32): bit b of element (w, j)
    bits = (planes[..., None] >> lane) & jnp.uint32(1)
    weights = (jnp.uint32(1) << jnp.arange(k, dtype=jnp.uint32))
    codes = jnp.sum(bits * weights[..., :, None, None], axis=-3,
                    dtype=jnp.uint32)
    return codes.reshape(*planes.shape[:-2], -1)
