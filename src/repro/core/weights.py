"""Compressed-at-rest parameter store (paper path (i): offline weights).

Weights are compressed once (offline / at load), live in HBM as LEXI-FW
packed buffers, and are decompressed just-in-time near compute — either by
the pure-JAX path here (dry-run friendly) or by the fused
``decompress_matmul`` Pallas kernel on real hardware.

Small leaves (norm scales, biases, scalars) stay raw: packing them would cost
more in dictionary/escape overhead than it saves, exactly like the paper only
compresses the bulk streams.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import entropy, fixed, packing
from .collectives import CodecConfig

MIN_COMPRESS_SIZE = 1 << 12   # leaves below 4096 elements stay raw
WEIGHT_K = 6                  # exponent-code width for at-rest serving weights
LANES = 32                    # bit-plane word width (columns per u32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MaybeCompressed:
    """A leaf that is either raw or a :class:`fixed.Compressed`."""

    value: Any           # jax.Array | fixed.Compressed
    compressed: bool

    def tree_flatten(self):
        return (self.value,), (self.compressed,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])


def _should_compress(x: jax.Array) -> bool:
    return (x.ndim >= 1 and x.size >= MIN_COMPRESS_SIZE
            and x.dtype in (jnp.bfloat16, jnp.float32))


def compress_params(params: Any, cfg: CodecConfig) -> Any:
    """Pytree of arrays -> pytree of MaybeCompressed."""

    def one(x):
        if cfg.weights and _should_compress(x):
            return MaybeCompressed(
                fixed.compress(x.astype(jnp.bfloat16), k=cfg.k,
                               esc_capacity=cfg.esc_capacity(x.size)),
                True)
        return MaybeCompressed(x, False)

    return jax.tree_util.tree_map(one, params)


def decompress_params(cparams: Any) -> Any:
    """Inverse of :func:`compress_params` (exact for the compressed leaves)."""

    def one(leaf: MaybeCompressed):
        return fixed.decompress(leaf.value) if leaf.compressed else leaf.value

    return jax.tree_util.tree_map(
        one, cparams, is_leaf=lambda l: isinstance(l, MaybeCompressed))


def stored_bytes(cparams: Any) -> int:
    """HBM bytes of the compressed store (the paper's Fig-1b metric)."""
    total = 0

    def one(leaf: MaybeCompressed):
        nonlocal total
        if leaf.compressed:
            total += leaf.value.wire_bytes()
        else:
            total += leaf.value.size * leaf.value.dtype.itemsize
        return leaf

    jax.tree_util.tree_map(one, cparams,
                           is_leaf=lambda l: isinstance(l, MaybeCompressed))
    return total


def param_bytes(params: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(params))


def fsdp_gather_params(cparams: Any, axis_name: str,
                       cfg: CodecConfig) -> Any:
    """FSDP-style per-layer weight all-gather with packed wire format.

    Parameters live sharded *and* compressed; gathering for use moves packed
    bytes over ICI (the paper's "transmit weights in compact lossless form"),
    decompressing only at the consumer.  Call inside shard_map with leaves
    pre-sharded along their first axis.
    """

    def one(leaf: MaybeCompressed):
        if leaf.compressed:
            gathered = jax.lax.all_gather(leaf.value, axis_name, axis=0,
                                          tiled=False)
            parts = jax.vmap(fixed.decompress)(gathered)
            return parts.reshape((-1,) + parts.shape[2:])
        return jax.lax.all_gather(leaf.value, axis_name, axis=0, tiled=True)

    return jax.tree_util.tree_map(
        one, cparams, is_leaf=lambda l: isinstance(l, MaybeCompressed))


# ---------------------------------------------------------------------------
# serving-side packed store: whole-model weights in the LEXI-FW 2-D layout
# consumed by the fused ``kernels.decompress_matmul`` kernel
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedWeight:
    """A bulk 2-D (or stacked-2-D) weight leaf in LEXI-FW packed form.

    Fields follow ``kernels.ref.compress_weight_2d``, with any leading
    stack dims (scan-stacked layers, MoE experts) prepended to every child
    so ``lax.scan`` / indexing slice all three buffers coherently:

      signman   (..., K, N)       u8   sign<<7 | mantissa
      planes    (..., k, K, N/32) u32  bit-planes of k-bit exponent codes
      dict_syms (..., 2^k)        u8   per-slice exponent dictionary

    ``aux`` carries ``k`` and the *resolved* compute backend baked in at
    pack time ("pallas" | "interpret" | "jax"), so jit caches key on the
    dispatch decision and model code needs no config threading.  The format
    is escape-free by construction: the packer verifies zero escapes per
    slice and leaves escaping tensors raw.
    """

    signman: Any
    planes: Any
    dict_syms: Any
    k: int = WEIGHT_K
    backend: str = "jax"

    @property
    def shape(self):          # logical (unpacked) weight shape
        return self.signman.shape

    @property
    def ndim(self):
        return self.signman.ndim

    def tree_flatten(self):
        return ((self.signman, self.planes, self.dict_syms),
                (self.k, self.backend))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux[0], aux[1])


def _is_packed(x) -> bool:
    return isinstance(x, PackedWeight)


def unpack_weight(pw: PackedWeight) -> jax.Array:
    """Exact in-graph decode of a packed leaf back to bf16 (the pure-JAX
    reference plane — mirrors ``kernels.ref.decompress_matmul_ref``'s
    decode, vmapped over any leading stack dims)."""

    def one(sm, pls, d):
        codes = packing.bitplane_unpack(jnp.moveaxis(pls, 0, -2), pw.k)
        exp = d[codes.astype(jnp.int32)]
        return entropy.jnp_from_u16(entropy.jnp_combine(sm, exp))

    fn = one
    for _ in range(pw.signman.ndim - 2):
        fn = jax.vmap(fn)
    return fn(pw.signman, pw.planes, pw.dict_syms)


def _leaf_eligible(path: str, x, spec, tp: int) -> bool:
    """Bulk 2-D matmul operands only.  Raw stays raw when:

    - it is an embedding table (consumed by gather, not matmul),
    - it is small (dictionary overhead beats the savings), not bf16, or <2-D,
    - its tp-local column count breaks the 32-lane bit-plane alignment, or
    - (checked later, at pack time) any 2-D slice needs escape symbols.
    """
    if "embed" in path:
        return False
    if not hasattr(x, "dtype") or x.dtype != jnp.bfloat16 or x.ndim < 2:
        return False
    if x.shape[-2] * x.shape[-1] < MIN_COMPRESS_SIZE:
        return False
    dims = tuple(spec) if spec is not None else ()
    dims = dims + (None,) * (x.ndim - len(dims))
    n_local = x.shape[-1] // tp if dims[-1] is not None else x.shape[-1]
    return n_local % LANES == 0


def _pack_leaf(x, max_k: int):
    """Host-side pack of one leaf at the smallest escape-free code width
    k ∈ {4..max_k} (weight exponent histograms are narrow, so most leaves
    fit k=4 → 12 of 16 bits per element).  All leading-dim slices must
    agree on k (it is leaf-level aux).  Returns ``(fields, k)`` or None if
    even max_k would need escapes — that leaf stays raw."""
    import numpy as np

    from ..kernels import ref   # lazy: core must not import kernels at load

    arr = np.asarray(x)
    lead = arr.shape[:-2]
    for k in range(4, max_k + 1):
        sms, plss, ds = [], [], []
        for idx in np.ndindex(*lead):
            sm, pls, d, nesc = ref.compress_weight_2d(jnp.asarray(arr[idx]),
                                                      k=k)
            if int(nesc) != 0:
                break
            sms.append(np.asarray(sm))
            plss.append(np.asarray(pls))
            ds.append(np.asarray(d))
        else:
            def stack(parts):
                if not lead:
                    return jnp.asarray(parts[0])
                return jnp.asarray(
                    np.stack(parts).reshape(lead + parts[0].shape))
            return (stack(sms), stack(plss), stack(ds)), k
    return None


def _packed_spec(spec, ndim: int, k: int, backend: str):
    """Derive the PartitionSpec node for a packed leaf from the raw leaf's
    spec: signman keeps it, planes gain an unsharded ``k`` axis before K
    (the N/32 word axis shards exactly like N — eligibility guarantees the
    local column count is lane-aligned), the per-slice dictionary keeps
    only the leading stack dims.  The node's aux (k, backend) must equal
    the param node's so shard_map's tree matching lines the specs up."""
    from jax.sharding import PartitionSpec as P
    dims = tuple(spec) if spec is not None else ()
    dims = dims + (None,) * (ndim - len(dims))
    lead, kd, nd = dims[:-2], dims[-2], dims[-1]
    return PackedWeight(P(*lead, kd, nd),
                        P(*lead, None, kd, nd),
                        P(*lead, None), k, backend)


def pack_serving_params(params: Any, pspecs: Any, *, k: int = WEIGHT_K,
                        backend: str = "jax", tp: int = 1):
    """Whole-model serving param store: bulk 2-D leaves -> PackedWeight
    (escape-free LEXI-FW layout at the smallest code width ≤ ``k``),
    everything else raw.  Returns ``(packed_params, packed_pspecs)`` with
    spec nodes swapped to match.  Idempotent: already-packed leaves pass
    through (disagg replicas share one params tree)."""
    from jax.sharding import PartitionSpec as P
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=_is_packed)
    # PartitionSpec is tuple-like, so flatten the spec tree with its own
    # is_leaf (None / P / PackedWeight) instead of flatten_up_to
    sflat, sdef = jax.tree_util.tree_flatten(
        pspecs, is_leaf=lambda s: s is None or isinstance(s, (P, PackedWeight)))
    assert len(flat) == len(sflat), (len(flat), len(sflat))
    out_p, out_s = [], []
    for (path, x), spec in zip(flat, sflat):
        pstr = jax.tree_util.keystr(path)
        if _is_packed(x):
            out_p.append(x)
            out_s.append(spec if _is_packed(spec)
                         else _packed_spec(spec, x.ndim, x.k, x.backend))
            continue
        packed = (_pack_leaf(x, k)
                  if _leaf_eligible(pstr, x, spec, tp) else None)
        if packed is None:
            out_p.append(x)
            out_s.append(spec)
        else:
            fields, leaf_k = packed
            out_p.append(PackedWeight(*fields, leaf_k, backend))
            out_s.append(_packed_spec(spec, x.ndim, leaf_k, backend))
    return (jax.tree_util.tree_unflatten(treedef, out_p),
            jax.tree_util.tree_unflatten(sdef, out_s))


def weight_plane_bytes(params: Any) -> tuple:
    """(stored, raw_bf16) HBM bytes of the serving weight store — the
    per-decode-step weight traffic, analytically, the way
    ``models/cache.py:page_bytes`` meters KV bytes.  ``stored`` counts
    packed buffers for PackedWeight leaves and full bf16 for raw ones;
    ``raw_bf16`` is the same store with every leaf unpacked."""
    stored = raw = 0
    for leaf in jax.tree_util.tree_leaves(params, is_leaf=_is_packed):
        if _is_packed(leaf):
            stored += sum(int(b.size) * b.dtype.itemsize
                          for b in (leaf.signman, leaf.planes,
                                    leaf.dict_syms))
            raw += int(leaf.signman.size) * 2
        else:
            stored += int(leaf.size) * leaf.dtype.itemsize
            raw += int(leaf.size) * leaf.dtype.itemsize
    return stored, raw
