"""Compressed-at-rest parameter store (paper path (i): offline weights).

Weights are compressed once (offline / at load), live in HBM as LEXI-FW
packed buffers, and are decompressed just-in-time near compute — either by
the pure-JAX path here (dry-run friendly) or by the fused
``decompress_matmul`` Pallas kernel on real hardware.

Small leaves (norm scales, biases, scalars) stay raw: packing them would cost
more in dictionary/escape overhead than it saves, exactly like the paper only
compresses the bulk streams.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import fixed
from .collectives import CodecConfig

MIN_COMPRESS_SIZE = 1 << 12   # leaves below 4096 elements stay raw


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MaybeCompressed:
    """A leaf that is either raw or a :class:`fixed.Compressed`."""

    value: Any           # jax.Array | fixed.Compressed
    compressed: bool

    def tree_flatten(self):
        return (self.value,), (self.compressed,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])


def _should_compress(x: jax.Array) -> bool:
    return (x.ndim >= 1 and x.size >= MIN_COMPRESS_SIZE
            and x.dtype in (jnp.bfloat16, jnp.float32))


def compress_params(params: Any, cfg: CodecConfig) -> Any:
    """Pytree of arrays -> pytree of MaybeCompressed."""

    def one(x):
        if cfg.weights and _should_compress(x):
            return MaybeCompressed(
                fixed.compress(x.astype(jnp.bfloat16), k=cfg.k,
                               esc_capacity=cfg.esc_capacity(x.size)),
                True)
        return MaybeCompressed(x, False)

    return jax.tree_util.tree_map(one, params)


def decompress_params(cparams: Any) -> Any:
    """Inverse of :func:`compress_params` (exact for the compressed leaves)."""

    def one(leaf: MaybeCompressed):
        return fixed.decompress(leaf.value) if leaf.compressed else leaf.value

    return jax.tree_util.tree_map(
        one, cparams, is_leaf=lambda l: isinstance(l, MaybeCompressed))


def stored_bytes(cparams: Any) -> int:
    """HBM bytes of the compressed store (the paper's Fig-1b metric)."""
    total = 0

    def one(leaf: MaybeCompressed):
        nonlocal total
        if leaf.compressed:
            total += leaf.value.wire_bytes()
        else:
            total += leaf.value.size * leaf.value.dtype.itemsize
        return leaf

    jax.tree_util.tree_map(one, cparams,
                           is_leaf=lambda l: isinstance(l, MaybeCompressed))
    return total


def param_bytes(params: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(params))


def fsdp_gather_params(cparams: Any, axis_name: str,
                       cfg: CodecConfig) -> Any:
    """FSDP-style per-layer weight all-gather with packed wire format.

    Parameters live sharded *and* compressed; gathering for use moves packed
    bytes over ICI (the paper's "transmit weights in compact lossless form"),
    decompressing only at the consumer.  Call inside shard_map with leaves
    pre-sharded along their first axis.
    """

    def one(leaf: MaybeCompressed):
        if leaf.compressed:
            gathered = jax.lax.all_gather(leaf.value, axis_name, axis=0,
                                          tiled=False)
            parts = jax.vmap(fixed.decompress)(gathered)
            return parts.reshape((-1,) + parts.shape[2:])
        return jax.lax.all_gather(leaf.value, axis_name, axis=0, tiled=True)

    return jax.tree_util.tree_map(
        one, cparams, is_leaf=lambda l: isinstance(l, MaybeCompressed))
