"""High-level LEXI codec API.

``LexiCodec`` is the paper-faithful LEXI-H (per-layer canonical Huffman,
variable-length, bit-exact, host-side — used for checkpoints, benchmarks and
as the oracle).  The in-graph deployment codec is ``repro.core.fixed``
(LEXI-FW); this module also exposes convenience CR measurement helpers that
the benchmark suite shares.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from . import baselines, bitstream, entropy, huffman


@dataclasses.dataclass
class LexiCodec:
    """Per-layer LEXI-H codec: fit on a stream, then encode/decode exactly."""

    main_alphabet: int = huffman.MAIN_ALPHABET
    max_len: int = huffman.MAX_CODE_LEN
    book: huffman.Codebook | None = None

    def fit(self, exp_stream: np.ndarray, n_train: int | None = 512) -> "LexiCodec":
        """Build the codebook from the first ``n_train`` symbols (paper §4.1:
        the tree is trained on the first 512 activations of a layer)."""
        x = np.asarray(exp_stream, dtype=np.uint8).reshape(-1)
        if n_train is not None:
            x = x[:n_train]
        hist = np.bincount(x, minlength=256).astype(np.float64)
        self.book = huffman.build_codebook(hist, main_alphabet=self.main_alphabet,
                                           max_len=self.max_len)
        return self

    def encode(self, exp_stream: np.ndarray) -> bitstream.EncodedStream:
        assert self.book is not None, "call fit() first"
        return bitstream.encode(np.asarray(exp_stream, dtype=np.uint8), self.book)

    def decode(self, stream: bitstream.EncodedStream) -> np.ndarray:
        return bitstream.decode(stream)

    # -- whole-tensor helpers -------------------------------------------------
    @staticmethod
    def compress_tensor(x: np.ndarray) -> bytes:
        return bitstream.compress_bf16(entropy.to_bf16_u16(np.asarray(x)))

    @staticmethod
    def decompress_tensor(blob: bytes, shape, dtype="bfloat16") -> np.ndarray:
        import ml_dtypes
        u16 = bitstream.decompress_bf16(blob)
        return u16.view(ml_dtypes.bfloat16).reshape(shape).astype(dtype)


def measure_crs(x: np.ndarray) -> Dict[str, float]:
    """Exponent-stream CRs of every method in paper Table 2 on one tensor."""
    u16 = entropy.to_bf16_u16(np.asarray(x))
    _, exp, _ = entropy.split_fields(u16)
    exp = exp.reshape(-1)
    return {
        "base": 1.0,
        "rle": baselines.rle_cr(exp),
        "bdi": baselines.bdi_cr(exp),
        "lexi": huffman.compression_ratio(exp),
    }


def overall_bf16_ratio(exp_cr: float) -> float:
    """Whole-value CR given an exponent CR (sign+mantissa = 8 bits verbatim)."""
    return 16.0 / (8.0 + 8.0 / exp_cr)
