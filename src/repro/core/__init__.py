"""LEXI core: lossless BF16 exponent coding (the paper's contribution).

Public surface:
  entropy   -- field extraction + Shannon profiling (paper section 3)
  huffman   -- length-limited canonical Huffman codebooks (LEXI-H)
  bitstream -- bit-exact encode/decode + container format (LEXI-H)
  fixed     -- static-shape deployment codec (LEXI-FW, TPU adaptation)
  packing   -- bit-plane pack/unpack primitives
  baselines -- RLE / BDI comparison codecs (Table 2)
  codec     -- high-level API + CR measurement
  collectives -- LEXI-compressed ICI collectives (shard_map)
  weights   -- compressed-at-rest parameter store
"""

from . import baselines, bitstream, codec, entropy, fixed, huffman, packing

__all__ = [
    "baselines", "bitstream", "codec", "entropy", "fixed", "huffman",
    "packing",
]
