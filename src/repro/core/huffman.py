"""Length-limited canonical Huffman coding over the BF16 exponent alphabet.

This is the paper-faithful LEXI-H codec core (§4.2):

* the main alphabet is the <=31 most frequent exponent symbols plus a
  reserved ESCAPE symbol (32 entries total, matching the 32-entry hardware
  pipeline);
* code lengths are limited to ``MAX_CODE_LEN = 24`` bits (the paper's naive
  decoder is indexed by L_max = 24 bits, and the escape is a 24-bit prefix),
  computed with the package-merge algorithm (optimal under the limit);
* codes are *canonical* so the decoder can be reconstructed from the
  (symbol, length) list alone — this is exactly what the hardware piggybacks
  alongside the bitstream as the per-layer codebook header.

Escape semantics (paper §4.2.2 "Exception handling"): an out-of-alphabet
exponent is emitted as ``ESCAPE code + raw 8-bit exponent``.  In hardware the
escape is the reserved all-ones 24-bit pattern; canonically we give ESCAPE a
pseudo-count of 1 so it lands among the longest codes.  Either choice decodes
identically through the staged-LUT model because canonical order is preserved.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

MAX_CODE_LEN = 24
MAIN_ALPHABET = 32           # paper: 32-entry pipeline (31 symbols + escape)
ESCAPE = 256                 # symbol id for the escape (outside the 8-bit range)
RAW_EXP_BITS = 8             # bits appended after an escape code


def length_limited_lengths(hist: Sequence[float], max_len: int = MAX_CODE_LEN,
                           symbols: Sequence[int] | None = None) -> Dict[int, int]:
    """Optimal length-limited code lengths via package-merge.

    ``hist`` is indexed by symbol; only strictly positive entries (or the
    explicit ``symbols`` subset) participate.  Returns {symbol: length}.
    """
    hist = np.asarray(hist, dtype=np.float64)
    if symbols is None:
        symbols = [int(s) for s in np.nonzero(hist > 0)[0]]
    items: List[Tuple[float, Tuple[int, ...]]] = [
        (float(hist[s]), (int(s),)) for s in symbols
    ]
    n = len(items)
    if n == 0:
        return {}
    if n == 1:
        return {items[0][1][0]: 1}
    if (1 << max_len) < n:
        raise ValueError(f"cannot code {n} symbols within {max_len} bits")
    original = sorted(items)
    packages = list(original)
    for _ in range(max_len - 1):
        paired = [
            (packages[i][0] + packages[i + 1][0],
             packages[i][1] + packages[i + 1][1])
            for i in range(0, len(packages) - 1, 2)
        ]
        packages = sorted(paired + original)
    lengths: Dict[int, int] = {}
    for _, syms in packages[: 2 * n - 2]:
        for s in syms:
            lengths[s] = lengths.get(s, 0) + 1
    # Kraft equality must hold for an optimal prefix code.
    kraft = sum(2.0 ** -l for l in lengths.values())
    assert abs(kraft - 1.0) < 1e-9, f"package-merge Kraft sum {kraft}"
    return lengths


def canonical_codes(lengths: Dict[int, int]) -> Dict[int, Tuple[int, int]]:
    """Canonical (code, length) assignment: sort by (length, symbol)."""
    order = sorted(lengths.items(), key=lambda kv: (kv[1], kv[0]))
    codes: Dict[int, Tuple[int, int]] = {}
    code = 0
    prev_len = order[0][1] if order else 0
    for sym, l in order:
        code <<= (l - prev_len)
        codes[sym] = (code, l)
        code += 1
        prev_len = l
    return codes


@dataclasses.dataclass(frozen=True)
class Codebook:
    """A per-layer LEXI-H codebook (what the flit header carries).

    ``symbols``/``lengths`` are parallel arrays in canonical order; everything
    else is derived.  ``enc_code``/``enc_len`` are 257-entry encoder LUTs
    (index 256 = ESCAPE).  Out-of-alphabet exponents map to ESCAPE.
    """

    symbols: np.ndarray          # (S,) int32, canonical order (incl. ESCAPE)
    lengths: np.ndarray          # (S,) int32
    enc_code: np.ndarray         # (257,) int64: symbol -> codeword
    enc_len: np.ndarray          # (257,) int32: symbol -> code length;
                                 # escapes get len(ESCAPE)+8 at the call site
    in_alphabet: np.ndarray      # (256,) bool

    @property
    def escape_code(self) -> Tuple[int, int]:
        return int(self.enc_code[ESCAPE]), int(self.enc_len[ESCAPE])

    def header_bits(self) -> int:
        """Canonical header: 8-bit symbol + 5-bit length per entry."""
        return int(len(self.symbols) * (8 + 5))

    def decode_tables(self):
        """(first_code, first_index, by-length symbol array) for canonical
        decode — the software analogue of the staged LUTs."""
        max_l = int(self.lengths.max())
        first_code = np.zeros(max_l + 2, dtype=np.int64)
        first_index = np.zeros(max_l + 2, dtype=np.int64)
        counts = np.bincount(self.lengths, minlength=max_l + 2)
        code = 0
        idx = 0
        for l in range(1, max_l + 1):
            first_code[l] = code
            first_index[l] = idx
            code = (code + counts[l]) << 1
            idx += counts[l]
        return first_code, first_index, self.symbols


def build_codebook(hist: np.ndarray, *, main_alphabet: int = MAIN_ALPHABET,
                   max_len: int = MAX_CODE_LEN) -> Codebook:
    """Histogram -> canonical length-limited codebook with escape.

    Mirrors the hardware pipeline: take the (main_alphabet - 1) most frequent
    exponents, add ESCAPE with the residual count (>= 1 pseudo-count), run
    package-merge, assign canonical codes.
    """
    hist = np.asarray(hist, dtype=np.float64)
    order = np.argsort(-hist, kind="stable")
    keep = [int(s) for s in order[: main_alphabet - 1] if hist[s] > 0]
    residual = float(hist.sum() - sum(hist[s] for s in keep))
    freqs = np.zeros(257, dtype=np.float64)
    freqs[keep] = hist[keep]
    freqs[ESCAPE] = max(residual, 1.0)
    lengths = length_limited_lengths(freqs, max_len=max_len,
                                     symbols=keep + [ESCAPE])
    codes = canonical_codes(lengths)
    order2 = sorted(lengths.items(), key=lambda kv: (kv[1], kv[0]))
    symbols = np.array([s for s, _ in order2], dtype=np.int32)
    lens = np.array([l for _, l in order2], dtype=np.int32)
    enc_code = np.zeros(257, dtype=np.int64)
    enc_len = np.zeros(257, dtype=np.int32)
    in_alpha = np.zeros(256, dtype=bool)
    esc_code, esc_len = codes[ESCAPE]
    for s in range(256):
        if s in codes:
            enc_code[s], enc_len[s] = codes[s]
            in_alpha[s] = True
        else:
            enc_code[s], enc_len[s] = esc_code, esc_len  # escape prefix only
    enc_code[ESCAPE], enc_len[ESCAPE] = esc_code, esc_len
    return Codebook(symbols=symbols, lengths=lens, enc_code=enc_code,
                    enc_len=enc_len, in_alphabet=in_alpha)


def code_cost_bits(hist: np.ndarray, book: Codebook) -> float:
    """Total bitstream cost (excluding header) of coding ``hist`` with ``book``."""
    hist = np.asarray(hist, dtype=np.float64)
    cost = 0.0
    esc_len = book.escape_code[1] + RAW_EXP_BITS
    for s in range(256):
        if hist[s] <= 0:
            continue
        cost += hist[s] * (book.enc_len[s] if book.in_alphabet[s] else esc_len)
    return cost


def compression_ratio(exp_stream: np.ndarray, *, include_header: bool = True,
                      main_alphabet: int = MAIN_ALPHABET) -> float:
    """Exponent-stream CR = raw bits / coded bits (paper Table 2 metric)."""
    hist = np.bincount(exp_stream.reshape(-1), minlength=256).astype(np.float64)
    book = build_codebook(hist, main_alphabet=main_alphabet)
    bits = code_cost_bits(hist, book)
    if include_header:
        bits += book.header_bits()
    return (8.0 * hist.sum()) / max(bits, 1.0)
