"""Deterministic synthetic token pipeline (host-sharded, resumable).

Real WikiText-2/C4 are unavailable offline (DESIGN §7); this pipeline
generates a reproducible token stream whose *statistics* (Zipfian token
distribution -> bell-shaped activations after embedding) match what the
LEXI profiling needs.  Every batch is a pure function of (seed, step,
host_slice), so training resumes exactly after restart and every data shard
is independent — the properties a production loader must have.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Zipf-distributed LM batches with next-token labels."""

    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    # multimodal extras
    d_model: int = 0
    n_front_tokens: int = 0       # vision stub
    enc_embeds: bool = False      # audio stub (encoder frame embeddings)

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # Zipf via inverse-CDF on a truncated power law (fast, vectorized)
        u = rng.random((self.global_batch, self.seq_len + 1))
        ranks = np.floor(
            (u * (self.vocab_size ** (1 - self.zipf_a) - 1) + 1)
            ** (1 / (1 - self.zipf_a))).astype(np.int64)
        toks = np.clip(ranks - 1, 0, self.vocab_size - 1).astype(np.int32)
        out: Dict[str, jnp.ndarray] = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        if self.n_front_tokens:
            out["front_embeds"] = jnp.asarray(
                rng.normal(0, 1, (self.global_batch, self.n_front_tokens,
                                  self.d_model)), jnp.bfloat16)
        if self.enc_embeds:
            out["enc_embeds"] = jnp.asarray(
                rng.normal(0, 1, (self.global_batch, self.seq_len,
                                  self.d_model)), jnp.bfloat16)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[Dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def for_config(cfg, shape, seed: int = 0) -> SyntheticLM:
    return SyntheticLM(
        vocab_size=cfg.vocab_size, global_batch=shape.global_batch,
        seq_len=shape.seq_len, seed=seed, d_model=cfg.d_model,
        n_front_tokens=(cfg.n_frontend_tokens
                        if cfg.frontend == "vision_stub" else 0),
        enc_embeds=cfg.encdec)
