"""Data substrate: deterministic synthetic token pipeline."""
from . import pipeline  # noqa: F401
