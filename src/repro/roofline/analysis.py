"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes; collective bytes are
parsed from the *lowered* StableHLO text — our models are manual-SPMD, so
every collective appears there explicitly with true dtypes and per-shard
operand shapes (the compiled CPU HLO upcasts bf16 collectives to f32, which
would inflate byte counts ~2x; we cross-check against it but report the
lowered numbers).  Per task spec we sum *operand* sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants (TPU v5e class, from the assignment):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per-chip effective)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "i32": 4, "ui32": 4,
    "s16": 2, "u16": 2, "i16": 2, "s8": 1, "u8": 1, "i8": 1, "i1": 1,
    "pred": 1,
}

_COLLECTIVES = ("all_gather", "all_reduce", "reduce_scatter", "all_to_all",
                "collective_permute", "collective_broadcast")

_TENSOR_RE = re.compile(r"tensor<([0-9x]*)x?(f64|f32|bf16|f16|u64|i64|u32|"
                        r"i32|u16|i16|u8|i8|i1)>")


def _tensor_bytes(t: str) -> int:
    m = _TENSOR_RE.match(t.strip())
    if not m:
        return 0
    dims, dt = m.groups()
    n = 1
    for d in dims.split("x"):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    operand_bytes: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.operand_bytes.values())


def parse_collectives(stablehlo_text: str,
                      scan_trip_counts: bool = True) -> CollectiveStats:
    """Sum operand bytes of every collective op in the lowered module.

    Collectives inside ``stablehlo.while`` bodies (scan over layers) execute
    once per trip; we multiply by the trip count inferred from the iota/scan
    upper bound when detectable (conservative: if not detectable, count 1).
    """
    counts: Dict[str, int] = {}
    obytes: Dict[str, int] = {}

    # trip counts: map function name -> multiplier (main = 1)
    # StableHLO lowers lax.scan to stablehlo.while inside the same func with
    # the trip count visible as a constant compare limit; a robust simple
    # heuristic: find `stablehlo.while` regions and their `compare LT, c`
    # bounds, then scale collectives found inside by that bound.
    lines = stablehlo_text.splitlines()
    region_mult: List[int] = [1]
    mults: List[Tuple[int, int]] = []  # (line_no, multiplier at that line)
    cur = 1
    stack: List[int] = []
    bound_re = re.compile(r"stablehlo.constant dense<(\d+)> : tensor<i32>")
    # Pre-scan: record while-region bounds in order of appearance.
    while_bounds: List[int] = []
    for i, ln in enumerate(lines):
        if "stablehlo.while" in ln:
            # look back a few lines for the loop bound constant
            bound = None
            for j in range(max(0, i - 30), i):
                m = bound_re.search(lines[j])
                if m:
                    bound = int(m.group(1))
            while_bounds.append(bound if bound and bound > 1 else 1)

    wi = 0
    depth_mult = {0: 1}
    depth = 0
    for ln in lines:
        if "stablehlo.while" in ln and scan_trip_counts:
            depth += 1
            mult = depth_mult[depth - 1] * (while_bounds[wi]
                                            if wi < len(while_bounds) else 1)
            depth_mult[depth] = mult
            wi += 1
        # region close heuristic
        if ln.strip().startswith("}") and depth > 0 and "while" not in ln:
            # conservative: only decrement on bare closes following a while
            pass
        for op in _COLLECTIVES:
            if f"stablehlo.{op}" in ln:
                # operand types: the `: (tensor<...>, ...) -> ...` suffix
                m = re.search(r":\s*\(([^)]*)\)\s*->", ln)
                if m:
                    types = m.group(1).split(",")
                else:
                    m2 = re.search(r":\s*(tensor<[^>]*>)\s*->", ln)
                    types = [m2.group(1)] if m2 else []
                b = sum(_tensor_bytes(t) for t in types)
                mult = depth_mult.get(depth, 1)
                counts[op] = counts.get(op, 0) + mult
                obytes[op] = obytes.get(op, 0) + b * mult
    return CollectiveStats(counts=counts, operand_bytes=obytes)


# ---------------------------------------------------------------------------
# Exact jaxpr-based accounting.
#
# compiled.cost_analysis() on the CPU backend counts while/scan bodies ONCE
# (off by n_layers), so the roofline instead walks the jaxpr: scan bodies are
# multiplied by their trip count, collectives report exact per-shard operand
# bytes (inside shard_map avals are per-chip), and dot_generals give FLOPs.
# ---------------------------------------------------------------------------

_COLL_PRIMS = {
    "all_gather": "all_gather",
    "all_gather_invariant": "all_gather",
    "psum": "all_reduce",
    "psum2": "all_reduce",
    "psum_invariant": "all_reduce",
    "reduce_scatter": "reduce_scatter",
    "psum_scatter": "reduce_scatter",
    "all_to_all": "all_to_all",
    "ppermute": "collective_permute",
    "pmax": "all_reduce",
    "pmin": "all_reduce",
}


@dataclasses.dataclass
class JaxprStats:
    """coll_bytes: spec metric (operand sizes, as the task asks to record).
    wire_bytes: physical per-chip ICI traffic — all_gather moves ~(N-1)x its
    operand, allreduce ~2x, RS/a2a ~1x — used for the roofline term."""
    flops: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    wire_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def collective_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def _aval_bytes(aval) -> int:
    try:
        return int(aval.size) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001 — abstract tokens etc.
        return 0


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    batch = 1.0
    for d in lb:
        batch *= a.shape[d]
    contract = 1.0
    for d in lc:
        contract *= a.shape[d]
    m = 1.0
    for i, s in enumerate(a.shape):
        if i not in lc and i not in lb:
            m *= s
    n = 1.0
    for i, s in enumerate(b.shape):
        if i not in rc and i not in rb:
            n *= s
    return 2.0 * batch * m * n * contract


def _axis_n(eqn, axis_sizes: Dict[str, int]) -> int:
    p = eqn.params or {}
    if "axis_size" in p:
        return int(p["axis_size"])
    names = p.get("axes") or p.get("axis_name") or ()
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= axis_sizes.get(a, 1)
    return max(n, 1)


def _wire_factor(kind: str, n: int) -> float:
    """Per-chip ICI bytes as a multiple of the per-chip operand size
    (ring schedules): AG receives (n-1) shards; AR = RS+AG = 2(n-1)/n;
    RS and a2a move (n-1)/n of the operand; permute moves it once."""
    if n <= 1:
        return 0.0
    return {"all_gather": float(n - 1),
            "all_reduce": 2.0 * (n - 1) / n,
            "reduce_scatter": (n - 1) / n,
            "all_to_all": (n - 1) / n,
            "collective_permute": 1.0,
            "collective_broadcast": 1.0}.get(kind, 1.0)


def _walk(jaxpr, mult: float, st: JaxprStats,
          axis_sizes: Dict[str, int]) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            st.flops += mult * _dot_flops(eqn)
        elif prim in _COLL_PRIMS:
            kind = _COLL_PRIMS[prim]
            b = sum(_aval_bytes(v.aval) for v in eqn.invars
                    if hasattr(v, "aval"))
            n = _axis_n(eqn, axis_sizes)
            st.coll_bytes[kind] = st.coll_bytes.get(kind, 0.0) + mult * b
            st.wire_bytes[kind] = st.wire_bytes.get(kind, 0.0) \
                + mult * b * _wire_factor(kind, n)
            st.coll_counts[kind] = st.coll_counts.get(kind, 0.0) + mult
        elif prim == "scan":
            _walk(eqn.params["jaxpr"].jaxpr, mult * eqn.params["length"],
                  st, axis_sizes)
        elif prim == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            _walk(body, mult, st, axis_sizes)   # unknown trips: count once
        elif prim == "cond":
            # both branches lower to selects on TPU; count the max branch
            branches = eqn.params["branches"]
            subs = []
            for br in branches:
                sub = JaxprStats()
                _walk(br.jaxpr, mult, sub, axis_sizes)
                subs.append(sub)
            best = max(subs, key=lambda s: s.flops + s.collective_bytes)
            st.flops += best.flops
            for field in ("coll_bytes", "wire_bytes", "coll_counts"):
                dst = getattr(st, field)
                for k, v in getattr(best, field).items():
                    dst[k] = dst.get(k, 0.0) + v
        else:
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                sub = eqn.params.get(key) if eqn.params else None
                if sub is not None:
                    _walk(getattr(sub, "jaxpr", sub), mult, st, axis_sizes)
                    break
            else:
                if eqn.params:
                    for v in eqn.params.values():
                        if hasattr(v, "jaxpr"):
                            _walk(v.jaxpr, mult, st, axis_sizes)


def analyze_jaxpr(closed_jaxpr, axis_sizes: Dict[str, int] | None = None
                  ) -> JaxprStats:
    st = JaxprStats()
    _walk(closed_jaxpr.jaxpr, 1.0, st, axis_sizes or {})
    return st


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # total, all chips
    hlo_bytes: float             # total, all chips
    collective_bytes: float      # per chip (lowered text is per-shard)
    model_flops: float           # 6·N·D analytic
    min_bytes: float = 0.0       # per-chip mandatory HBM reads (params,
                                 # caches — packed sizes when codec on)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hlo_bytes / (self.chips * HBM_BW)
        self.collective_s = self.collective_bytes / ICI_BW
        return self

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def step_time_s(self) -> float:
        """Naive no-overlap bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def ideal_s(self) -> float:
        """Best achievable step time: compute bound OR the mandatory HBM
        floor (params + caches must be read once), whichever is larger —
        decode can never reach compute peak, so its roofline target is the
        bandwidth bound."""
        return max(self.model_flops / (self.chips * PEAK_FLOPS),
                   self.min_bytes / HBM_BW)

    @property
    def roofline_fraction(self) -> float:
        """ideal_s / step_time: 1.0 = sitting on the roofline."""
        return self.ideal_s / max(self.step_time_s, 1e-12)

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "ideal_s": self.ideal_s,
            "min_bytes_per_chip": self.min_bytes, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analytic_memory_bytes(cfg, shape, mesh_cfg, run) -> Dict[str, float]:
    """Per-chip steady-state HBM bytes per step (documented model).

    cost_analysis() undercounts scan bodies, so the memory term uses this
    transparent accounting instead (the raw cost number is recorded too):

    * params: each chip READS its own shard from HBM (remote shards arrive
      over ICI and are counted in the collective term).  Compressed-at-rest
      weights scale by the LEXI-FW wire ratio.  Training adds optimizer
      state (f32 master+m+v read+write) and parameter writes.
    * caches (decode): this chip's cache shard is streamed once per step
      (packed when codec.cache) + one block write amortized.
    * activations: boundary tensors + mixer intermediates per layer,
      2 bytes, with a fixed structural multiplier (reads+writes ≈ 6 streams
      per layer), plus remat recompute reads for training.
    """
    from repro.core import fixed
    chips = mesh_cfg.chips
    tp = mesh_cfg.model
    nbatch = mesh_cfg.data * mesh_cfg.pod
    b = shape.global_batch
    s = shape.seq_len
    bshard = nbatch if b % nbatch == 0 else 1
    wratio = fixed.wire_ratio(run.codec.k, run.codec.esc_frac)

    pbytes_total = cfg.param_count() * 2.0
    shard_f = tp * (mesh_cfg.data if run.fsdp else 1)
    params_read = pbytes_total / shard_f
    if run.codec.weights and shape.kind != "train":
        params_read /= wratio

    comp = {"params": params_read}
    if shape.kind == "train":
        # opt state f32 x3 read+write + param write + grads f32 RW
        comp["optimizer"] = cfg.param_count() * (24.0 + 24.0 + 8.0) / shard_f
        comp["params"] = params_read * 3.0      # fwd + remat + bwd reads
    # activations
    tokens_loc = (b * (s if shape.kind != "decode" else 1)) / (bshard * 1)
    d_eff = cfg.d_model
    if cfg.moe is not None:
        d_eff += 2 * cfg.moe.top_k * cfg.moe.d_ff / tp
    elif cfg.d_ff:
        d_eff += 2 * cfg.d_ff / tp
    if cfg.ssm is not None:
        d_eff += 2 * cfg.ssm.d_inner(cfg.d_model) / tp
    act = tokens_loc / (tp if shape.kind != "decode" else 1) \
        * d_eff * cfg.n_layers * 2.0 * 6.0
    if shape.kind == "train":
        act *= 1.5                              # remat recompute reads
    comp["activations"] = act
    # caches
    if shape.kind == "decode" and cfg.n_heads > 0:
        w = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim if cfg.mla
             else 2 * cfg.n_kv_heads * cfg.head_dim)
        cache = (b / bshard) * (s / tp) * w * cfg.n_layers * 2.0
        if run.codec.cache:
            cache /= wratio
        comp["kv_cache"] = cache
    if shape.kind == "decode" and cfg.ssm is not None:
        di = cfg.ssm.d_inner(cfg.d_model)
        nh = cfg.ssm.n_heads(cfg.d_model)
        comp["ssm_state"] = (b / bshard) * (nh / tp) * cfg.ssm.headdim \
            * cfg.ssm.d_state * cfg.n_layers * 4.0 * 2.0
    if shape.kind == "prefill" and cfg.n_heads > 0:
        w = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim if cfg.mla
             else 2 * cfg.n_kv_heads * cfg.head_dim)
        cache = (b / bshard) * (s / tp) * w * cfg.n_layers * 2.0
        if run.codec.cache:
            cache /= wratio
        comp["kv_cache_write"] = cache
    comp["total"] = sum(comp.values())
    return comp


def model_flops_for(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) per step; decode: D = batch·1."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens        # forward only
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
