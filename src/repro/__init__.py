"""LEXI reproduction: lossless BF16 exponent coding as a first-class feature
of a multi-pod JAX training/serving framework.

Subpackages: core (the paper's codec + compressed collectives), kernels
(Pallas TPU), models (manual-SPMD zoo), configs, sharding, train, serve,
data, hw (paper's hardware models), roofline, launch.  See DESIGN.md.
"""

__version__ = "1.0.0"
