"""Compressed page transfer between serving replicas (the wire half of
disaggregated prefill).

A prefill replica exports each admitted sequence as a :class:`SequenceBlob`
— the LEXI-FW-compressed full pages (byte-identical to its pool pages, see
``repro.models.cache.export_sequence`` for the canonical WIRE FORMAT spec),
the partial-tail ring, per-slot length/position, and the SSM-state slot for
hybrids — and ships it through a :class:`PageTransport` to a decode
replica, which scatters it into its own pool.

The paper's end-to-end argument (and Huff-LLM's) is that the win lives on
the LINK: keep the cache entropy-coded across every hop and decode only at
compute.  The transport therefore meters every transfer twice —

  * ``wire_bytes``      what actually crossed (compressed pages + dedup),
  * ``raw_bytes``       the bf16-dense bytes of the same payload,

and prices both through ``repro.hw.noc.LinkModel`` so the serving bench can
report the link-byte/latency reduction next to tokens/s.

**Content-addressed page dedup.**  Full pages are immutable and content-
deterministic (the same prompt prefix always compresses to the same
bytes — PR 3's prefix-index invariant), so the transport keeps a per-
destination digest store and replaces pages the receiver already holds
with 13-byte references (tag + sha256[:12]).  That is what pushes link
bytes below the LEXI-FW storage floor of ~13/16 bits per value on
prefix-heavy request mixes; the codec-only number is metered separately
(``wire_bytes_nodedup``).  Dedup never changes decode state: a reference
resolves to the byte-identical payload, or the import fails loudly.

``LoopbackTransport`` is the in-process implementation (prefill and decode
replicas in one process); the ``PageTransport`` interface is the seam a
multi-host transport implements later — everything it needs is the byte
format plus the digest-store contract, both specified in
``cache.export_sequence``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import Dict, Iterator, List, Optional, Set, Tuple

import numpy as np

try:  # ml_dtypes ships with jax; the wire format needs its bfloat16
    import ml_dtypes
    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - jax always bundles ml_dtypes
    BF16 = np.dtype(np.uint16)

from repro.hw.noc import LinkModel

MAGIC = b"LXSQ"
VERSION = 1
_DIGEST_BYTES = 12
_FLAG_CODEC, _FLAG_KV, _FLAG_SSM = 1, 2, 4
_HDR = struct.Struct("<4sBBHHHHIHIIIiH")   # through n_emitted


def _page_digest(payload: bytes) -> bytes:
    return hashlib.sha256(payload).digest()[:_DIGEST_BYTES]


@dataclasses.dataclass
class SequenceBlob:
    """One admitted sequence's transferable cache state (host arrays).

    Array layout is per-shard, shard-major: every array carries a leading
    ``(tp, n_layers)`` axis pair (the stacked per-shard views the engine's
    ``export_slot`` produces under shard_map).  ``kv`` is None for
    attention-free configs, ``ssm`` for attention-only ones.  See
    ``repro.models.cache.export_sequence`` for the byte-level WIRE FORMAT
    this serializes to.
    """
    codec_on: bool
    tp: int
    n_layers: int
    n_cols: int
    blk: int
    w: int
    k: int
    esc_cap: int
    npad: int
    length: int
    cur_token: int
    emitted: List[int]
    kv: Optional[Dict[str, np.ndarray]]     # field name -> (tp, L, ...) array
    ssm: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]

    # -- geometry ----------------------------------------------------------

    def valid_cols(self, shard: int) -> int:
        """Full pages shard ``shard`` contributed (host mirror of
        ``cache.local_full_pages``)."""
        if self.length <= 0:
            return 0
        loc = max((self.length - 1 - shard) // self.tp + 1, 0)
        return loc // self.blk

    @property
    def n_valid_pages(self) -> int:
        return sum(self.valid_cols(t) for t in range(self.tp)) * self.n_layers

    @property
    def raw_bytes(self) -> int:
        """bf16-dense bytes of the same payload — the uncompressed-transfer
        baseline the link metering divides by (pages at 2 B/value + the
        ring rows + the SSM state at its native width)."""
        n = 0
        if self.kv is not None:
            n += self.n_valid_pages * self.blk * self.w * 2
            n += self.kv["ring"].nbytes
        if self.ssm is not None:
            n += sum(a.nbytes for a in self.ssm)
        return n

    # -- page payload extraction ------------------------------------------

    def _page_payload(self, t: int, l: int, c: int) -> bytes:
        kv = self.kv
        if self.codec_on:
            return b"".join((
                kv["signman"][t, l, c].tobytes(),
                kv["planes"][t, l, c].tobytes(),
                kv["dict_syms"][t, l, c].tobytes(),
                kv["esc_pos"][t, l, c].tobytes(),
                kv["esc_raw"][t, l, c].tobytes()))
        return kv["raw_pages"][t, l, c].tobytes()

    def page_entries(self) -> Iterator[Tuple[int, int, int, bytes]]:
        """(shard, layer, col, payload) for every VALID page, in wire
        order (shard-major, then layer, then column)."""
        for t in range(self.tp):
            for l in range(self.n_layers):
                for c in range(self.valid_cols(t)):
                    yield t, l, c, self._page_payload(t, l, c)

    # -- serialization -----------------------------------------------------

    def to_wire(self, known: Optional[Set[bytes]] = None
                ) -> Tuple[bytes, List[Tuple[bytes, bytes]], int]:
        """Serialize to the version-1 wire format.

        ``known``: digests the receiver already holds — matching pages ship
        as 13-byte references instead of payloads.  Returns ``(data,
        inline, n_refs)`` where ``inline`` lists the (digest, payload)
        pairs that crossed in full (the sender adds them to its picture of
        the receiver's store after a successful send).
        """
        flags = ((_FLAG_CODEC if self.codec_on else 0)
                 | (_FLAG_KV if self.kv is not None else 0)
                 | (_FLAG_SSM if self.ssm is not None else 0))
        parts = [_HDR.pack(MAGIC, VERSION, flags, self.tp, self.n_layers,
                           self.n_cols, self.blk, self.w, self.k,
                           self.esc_cap, self.npad, self.length,
                           self.cur_token, len(self.emitted))]
        parts.append(np.asarray(self.emitted, np.int32).tobytes())
        if self.ssm is not None:
            h, cx, cbc = self.ssm
            nh_loc, hd, nst = h.shape[2:]
            parts.append(struct.pack("<HHHHI", nh_loc, hd, nst,
                                     cx.shape[2], cx.shape[3]))
            parts += [h.tobytes(), cx.tobytes(), cbc.tobytes()]
        if self.kv is not None:
            parts.append(self.kv["ring"].tobytes())
        inline: List[Tuple[bytes, bytes]] = []
        n_refs = 0
        if self.kv is not None:
            known = set(known) if known is not None else None
            for _, _, _, payload in self.page_entries():
                digest = _page_digest(payload)
                if known is not None and digest in known:
                    parts.append(b"\x01" + digest)
                    n_refs += 1
                else:
                    parts.append(b"\x00" + digest + payload)
                    inline.append((digest, payload))
                    if known is not None:
                        known.add(digest)          # dedupe within one blob
        return b"".join(parts), inline, n_refs

    @classmethod
    def from_wire(cls, data: bytes,
                  store: Optional[Dict[bytes, bytes]] = None
                  ) -> "SequenceBlob":
        """Parse a version-1 wire blob.  ``store`` resolves tag-1 page
        references (content digest -> payload); an unknown digest or a
        version/magic mismatch raises ``ValueError`` before any state is
        touched."""
        (magic, version, flags, tp, n_layers, n_cols, blk, w, k, esc_cap,
         npad, length, cur_token, n_emitted) = _HDR.unpack_from(data, 0)
        if magic != MAGIC:
            raise ValueError(f"bad wire magic {magic!r}")
        if version != VERSION:
            raise ValueError(f"unsupported wire version {version} "
                             f"(this codec speaks {VERSION})")
        off = _HDR.size
        codec_on = bool(flags & _FLAG_CODEC)
        emitted = np.frombuffer(data, np.int32, n_emitted, off).tolist()
        off += 4 * n_emitted

        def rd(dtype, shape):
            nonlocal off
            dt = np.dtype(dtype)
            n = int(np.prod(shape))
            a = np.frombuffer(data, dt, n, off).reshape(shape).copy()
            off += n * dt.itemsize
            return a

        ssm = None
        if flags & _FLAG_SSM:
            nh_loc, hd, nst, kc, di_loc = struct.unpack_from("<HHHHI",
                                                             data, off)
            off += struct.calcsize("<HHHHI")
            ssm = (rd(np.float32, (tp, n_layers, nh_loc, hd, nst)),
                   rd(BF16, (tp, n_layers, kc, di_loc)),
                   rd(BF16, (tp, n_layers, kc, 2 * nst)))

        kv = None
        if flags & _FLAG_KV:
            ring = rd(BF16, (tp, n_layers, blk, w))
            n = blk * w
            if codec_on:
                kv = {
                    "signman": np.zeros((tp, n_layers, n_cols, n), np.uint8),
                    "planes": np.zeros((tp, n_layers, n_cols, k, npad // 32),
                                       np.uint32),
                    "dict_syms": np.zeros((tp, n_layers, n_cols, 1 << k),
                                          np.uint8),
                    "esc_pos": np.zeros((tp, n_layers, n_cols, esc_cap),
                                        np.int32),
                    "esc_raw": np.zeros((tp, n_layers, n_cols, esc_cap),
                                        np.uint8),
                    "ring": ring,
                }
            else:
                kv = {"raw_pages": np.zeros((tp, n_layers, n_cols, blk, w),
                                            BF16),
                      "ring": ring}
            blob = cls(codec_on=codec_on, tp=tp, n_layers=n_layers,
                       n_cols=n_cols, blk=blk, w=w, k=k, esc_cap=esc_cap,
                       npad=npad, length=length, cur_token=cur_token,
                       emitted=emitted, kv=kv, ssm=ssm)
            for t in range(tp):
                for l in range(n_layers):
                    for c in range(blob.valid_cols(t)):
                        tag = data[off]
                        digest = data[off + 1:off + 1 + _DIGEST_BYTES]
                        off += 1 + _DIGEST_BYTES
                        if tag == 1:
                            if store is None or digest not in store:
                                raise ValueError(
                                    "unknown page digest on wire — the "
                                    "receiver's content store is missing "
                                    f"{digest.hex()} (shard {t}, layer {l},"
                                    f" col {c})")
                            payload = store[digest]
                        else:
                            size = blob._payload_size()
                            payload = data[off:off + size]
                            off += size
                            if store is not None:
                                store[digest] = payload
                        blob._scatter_payload(t, l, c, payload)
            return blob
        return cls(codec_on=codec_on, tp=tp, n_layers=n_layers,
                   n_cols=n_cols, blk=blk, w=w, k=k, esc_cap=esc_cap,
                   npad=npad, length=length, cur_token=cur_token,
                   emitted=emitted, kv=None, ssm=ssm)

    def _payload_size(self) -> int:
        n = self.blk * self.w
        if not self.codec_on:
            return n * 2
        return (n + self.k * (self.npad // 32) * 4 + (1 << self.k)
                + self.esc_cap * 4 + self.esc_cap)

    def _scatter_payload(self, t: int, l: int, c: int,
                         payload: bytes) -> None:
        kv = self.kv
        if not self.codec_on:
            kv["raw_pages"][t, l, c] = np.frombuffer(
                payload, BF16).reshape(self.blk, self.w)
            return
        n = self.blk * self.w
        o = 0
        kv["signman"][t, l, c] = np.frombuffer(payload, np.uint8, n, o)
        o += n
        npl = self.k * (self.npad // 32)
        kv["planes"][t, l, c] = np.frombuffer(
            payload, np.uint32, npl, o).reshape(self.k, self.npad // 32)
        o += npl * 4
        nd = 1 << self.k
        kv["dict_syms"][t, l, c] = np.frombuffer(payload, np.uint8, nd, o)
        o += nd
        kv["esc_pos"][t, l, c] = np.frombuffer(payload, np.int32,
                                               self.esc_cap, o)
        o += self.esc_cap * 4
        kv["esc_raw"][t, l, c] = np.frombuffer(payload, np.uint8,
                                               self.esc_cap, o)


@dataclasses.dataclass
class TransportStats:
    """Cumulative link accounting across transfers (one link / direction)."""
    n_transfers: int = 0
    wire_bytes: int = 0          # bytes that actually crossed (with dedup)
    wire_bytes_nodedup: int = 0  # same transfers, dedup disabled (codec only)
    raw_bytes: int = 0           # bf16-dense bytes of the same payloads
    pages_inline: int = 0        # page payloads shipped in full
    pages_ref: int = 0           # pages replaced by content references
    model_ns: float = 0.0        # LinkModel latency of the wire bytes
    model_ns_raw: float = 0.0    # LinkModel latency of the raw baseline

    @property
    def reduction(self) -> float:
        """Fractional link-byte reduction vs the bf16-dense transfer —
        the serving-stack analogue of the paper's Table 3 column."""
        return 1.0 - self.wire_bytes / max(self.raw_bytes, 1)


class PageTransport:
    """Interface of the prefill→decode handoff link.

    ``send`` serializes (and meters) a blob for a destination; ``recv``
    reconstructs it on the destination side.  Implementations own the
    per-destination content store that backs page dedup.  In-process today
    (:class:`LoopbackTransport`); a multi-host implementation only needs
    these two methods plus the WIRE FORMAT in ``cache.export_sequence``.
    """

    stats: TransportStats

    def send(self, blob: SequenceBlob, dst: str) -> bytes:
        raise NotImplementedError

    def recv(self, data: bytes, dst: str) -> SequenceBlob:
        raise NotImplementedError


class LoopbackTransport(PageTransport):
    """In-process transport: full serialize → bytes → parse round trip (so
    the byte format is exercised on every handoff), with content-addressed
    page dedup and LinkModel metering.

    ``dedup=False`` ships every page inline (the codec-only baseline).
    ``hops`` positions the prefill and decode replicas on the chiplet mesh
    for the latency model.  The digest store is per-destination and grows
    with distinct page content; ``max_store_pages`` bounds it FIFO (a real
    multi-host transport would tie eviction to the receiver's pool instead).
    """

    def __init__(self, dedup: bool = True, hops: int = 2,
                 link: Optional[LinkModel] = None,
                 max_store_pages: int = 4096):
        self.dedup = dedup
        self.hops = hops
        self.link = link if link is not None else LinkModel()
        self.max_store_pages = max_store_pages
        self.stats = TransportStats()
        self._stores: Dict[str, Dict[bytes, bytes]] = {}

    def _store(self, dst: str) -> Dict[bytes, bytes]:
        return self._stores.setdefault(dst, {})

    def send(self, blob: SequenceBlob, dst: str) -> bytes:
        store = self._store(dst)
        if self.dedup:
            # Evict BEFORE snapshotting the known set, never after: a blob
            # serialized against the pre-eviction store could carry tag-1
            # references to exactly the entries evicted under it, making
            # the very next recv fail on a healthy transfer.  The store
            # may overshoot the bound by one blob's inline pages until the
            # next send.  (Loopback contract: recv a wire blob before the
            # next send to the same destination.)
            while len(store) > self.max_store_pages:
                store.pop(next(iter(store)))
        known = set(store) if self.dedup else None
        data, inline, n_refs = blob.to_wire(known)
        # a ref entry is the inline entry minus its payload, so the
        # dedup-off size is pure arithmetic — no second serialization
        nodedup_len = len(data) + n_refs * blob._payload_size()
        st = self.stats
        st.n_transfers += 1
        st.wire_bytes += len(data)
        st.wire_bytes_nodedup += nodedup_len
        st.raw_bytes += blob.raw_bytes
        st.pages_inline += len(inline)
        st.pages_ref += n_refs
        st.model_ns += self.link.transfer_ns(len(data), self.hops)
        st.model_ns_raw += self.link.transfer_ns(blob.raw_bytes, self.hops)
        if self.dedup:
            for digest, payload in inline:
                store[digest] = payload
        return data

    def recv(self, data: bytes, dst: str) -> SequenceBlob:
        # the loopback receiver shares the sender-maintained store (same
        # host); a remote receiver maintains its own from inline payloads
        return SequenceBlob.from_wire(data, self._store(dst))
