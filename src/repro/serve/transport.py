"""Compressed page transfer between serving replicas (the wire half of
disaggregated prefill).

A prefill replica exports each admitted sequence as a :class:`SequenceBlob`
— the LEXI-FW-compressed full pages (byte-identical to its pool pages, see
``repro.models.cache.export_sequence`` for the canonical WIRE FORMAT spec),
the partial-tail ring, per-slot length/position, and the SSM-state slot for
hybrids — and ships it through a :class:`PageTransport` to a decode
replica, which scatters it into its own pool.

The paper's end-to-end argument (and Huff-LLM's) is that the win lives on
the LINK: keep the cache entropy-coded across every hop and decode only at
compute.  The transport therefore meters every transfer twice —

  * ``wire_bytes``      what actually crossed (compressed pages + dedup),
  * ``raw_bytes``       the bf16-dense bytes of the same payload,

and prices both through ``repro.hw.noc.LinkModel`` so the serving bench can
report the link-byte/latency reduction next to tokens/s.

**Content-addressed page dedup (receiver-side).**  Full pages are immutable
and content-deterministic (the same prompt prefix always compresses to the
same bytes — PR 3's prefix-index invariant), so the RECEIVER of a link owns
a :class:`DigestStore` (digest -> payload, LRU-bounded) and the sender
queries its inventory before serializing: pages the receiver already holds
ship as 13-byte references (tag + sha256[:12]).  That is what pushes link
bytes below the LEXI-FW storage floor of ~13/16 bits per value on
prefix-heavy request mixes; the codec-only number is metered separately
(``wire_bytes_nodedup``).  Dedup never changes decode state: a reference
resolves to the byte-identical payload, or the import fails loudly.

**Streaming chunks.**  A transfer need not wait for admission to finish:
full pages can stream ahead of the tail as :func:`pack_chunk` frames (one
per batch of freshly filled page columns), landing in the receiver's digest
store (pinned against LRU eviction until the transfer completes — see
``DigestStore.pin``).  The closing :class:`SequenceBlob` then carries the
header/ring/SSM sections plus tag-1 references for every streamed page, so
``from_wire`` doubles as the completeness check: a missing chunk is an
unknown digest and the import fails loudly with the pool untouched.

``LoopbackTransport`` is the in-process implementation (prefill and decode
replicas in one process); ``repro.serve.net.client.SocketTransport``
carries the same bytes over TCP between OS processes.  Both meter into the
same :class:`TransportStats` so the serving bench reads one ledger.
"""

from __future__ import annotations

import dataclasses
import itertools
import struct
from collections import OrderedDict
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

import numpy as np

try:  # ml_dtypes ships with jax; the wire format needs its bfloat16
    import ml_dtypes
    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - jax always bundles ml_dtypes
    BF16 = np.dtype(np.uint16)

from repro.hw.noc import LinkModel, MeteredLink

from .telemetry import MetricsRegistry

# content addressing is shared with the scheduler's prefix index and the
# tiered PageCache (repro.serve.digest owns both hash conventions); the
# old private names stay importable — framing, server, and tests key on
# them
from .digest import DIGEST_BYTES as _DIGEST_BYTES
from .digest import page_digest as _page_digest

MAGIC = b"LXSQ"
VERSION = 1
_FLAG_CODEC, _FLAG_KV, _FLAG_SSM = 1, 2, 4
_HDR = struct.Struct("<4sBBHHHHIHIIIiH")   # through n_emitted

CHUNK_MAGIC = b"LXPC"
_CHDR = struct.Struct("<4sBIH")            # magic, version, seq_id, entries
_CENT = struct.Struct("<HHHB")             # shard, layer, col, tag


def page_payload(kv: Dict[str, np.ndarray], codec_on: bool,
                 t: int, l: int, c: int) -> bytes:
    """One page's wire payload (the field concatenation of the WIRE FORMAT
    page section) from a ``(tp, L, cols, ...)`` field dict — shared by the
    whole-blob serializer, the streaming chunk exporter, and the warm-tier
    spill path of ``repro.serve.pagecache``."""
    if codec_on:
        return b"".join((
            kv["signman"][t, l, c].tobytes(),
            kv["planes"][t, l, c].tobytes(),
            kv["dict_syms"][t, l, c].tobytes(),
            kv["esc_pos"][t, l, c].tobytes(),
            kv["esc_raw"][t, l, c].tobytes()))
    return kv["raw_pages"][t, l, c].tobytes()


def payload_nbytes(codec_on: bool, blk: int, w: int, k: int,
                   esc_cap: int, npad: int) -> int:
    """Byte size of one page payload under the given codec geometry."""
    n = blk * w
    if not codec_on:
        return n * 2
    return (n + k * (npad // 32) * 4 + (1 << k) + esc_cap * 4 + esc_cap)


def empty_page_fields(codec_on: bool, tp: int, n_layers: int, n_cols: int,
                      blk: int, w: int, k: int, esc_cap: int,
                      npad: int) -> Dict[str, np.ndarray]:
    """Zeroed ``(tp, L, cols, ...)`` field arrays for ``n_cols`` page
    columns (the host-side shape :func:`scatter_page_payload` fills)."""
    n = blk * w
    if codec_on:
        return {
            "signman": np.zeros((tp, n_layers, n_cols, n), np.uint8),
            "planes": np.zeros((tp, n_layers, n_cols, k, npad // 32),
                               np.uint32),
            "dict_syms": np.zeros((tp, n_layers, n_cols, 1 << k), np.uint8),
            "esc_pos": np.zeros((tp, n_layers, n_cols, esc_cap), np.int32),
            "esc_raw": np.zeros((tp, n_layers, n_cols, esc_cap), np.uint8),
        }
    return {"raw_pages": np.zeros((tp, n_layers, n_cols, blk, w), BF16)}


def scatter_page_payload(kv: Dict[str, np.ndarray], codec_on: bool,
                         t: int, l: int, c: int, payload: bytes, *,
                         blk: int, w: int, k: int, esc_cap: int,
                         npad: int) -> None:
    """Inverse of :func:`page_payload`: split one payload back into the
    ``(tp, L, cols, ...)`` field dict at ``[t, l, c]``.  Loud on a length
    mismatch — a payload that does not fit the geometry never lands."""
    size = payload_nbytes(codec_on, blk, w, k, esc_cap, npad)
    if len(payload) != size:
        raise ValueError(
            f"page payload is {len(payload)} bytes, geometry says "
            f"{size} (shard {t}, layer {l}, col {c})")
    if not codec_on:
        kv["raw_pages"][t, l, c] = np.frombuffer(
            payload, BF16).reshape(blk, w)
        return
    n = blk * w
    o = 0
    kv["signman"][t, l, c] = np.frombuffer(payload, np.uint8, n, o)
    o += n
    npl = k * (npad // 32)
    kv["planes"][t, l, c] = np.frombuffer(
        payload, np.uint32, npl, o).reshape(k, npad // 32)
    o += npl * 4
    nd = 1 << k
    kv["dict_syms"][t, l, c] = np.frombuffer(payload, np.uint8, nd, o)
    o += nd
    kv["esc_pos"][t, l, c] = np.frombuffer(payload, np.int32, esc_cap, o)
    o += esc_cap * 4
    kv["esc_raw"][t, l, c] = np.frombuffer(payload, np.uint8, esc_cap, o)


# ---------------------------------------------------------------------------
# streaming page chunks
# ---------------------------------------------------------------------------


def pack_chunk(seq_id: int, entries: Sequence[Tuple[int, int, int, bytes]],
               known: Optional[Set[bytes]] = None
               ) -> Tuple[bytes, List[Tuple[bytes, bytes]], List[bytes]]:
    """Serialize one streaming page chunk.

    ``entries`` are ``(shard, layer, col, payload)`` for full pages that
    just became available; ``known`` are digests the receiver already holds
    (those ship as tag-1 references).  Returns ``(data, inline, refs)``
    like :meth:`SequenceBlob.to_wire`.  Chunk entries are self-describing
    (explicit payload length) so a receiver can parse them before it has
    seen any geometry header.
    """
    parts = [_CHDR.pack(CHUNK_MAGIC, VERSION, seq_id, len(entries))]
    inline: List[Tuple[bytes, bytes]] = []
    refs: List[bytes] = []
    known = set(known) if known is not None else None
    for t, l, c, payload in entries:
        digest = _page_digest(payload)
        if known is not None and digest in known:
            parts.append(_CENT.pack(t, l, c, 1) + digest)
            refs.append(digest)
        else:
            parts.append(_CENT.pack(t, l, c, 0) + digest
                         + struct.pack("<I", len(payload)) + payload)
            inline.append((digest, payload))
            if known is not None:
                known.add(digest)
    return b"".join(parts), inline, refs


def unpack_chunk(data: bytes
                 ) -> Tuple[int, List[Tuple[int, int, int, int, bytes,
                                            Optional[bytes]]]]:
    """Parse a streaming chunk; loud ``ValueError`` on bad magic/version,
    a truncated entry, or a corrupted payload length.  Returns
    ``(seq_id, [(shard, layer, col, tag, digest, payload-or-None)])``."""
    if len(data) < _CHDR.size:
        raise ValueError(f"truncated chunk header ({len(data)} bytes)")
    magic, version, seq_id, n_entries = _CHDR.unpack_from(data, 0)
    if magic != CHUNK_MAGIC:
        raise ValueError(f"bad chunk magic {magic!r}")
    if version != VERSION:
        raise ValueError(f"unsupported chunk version {version} "
                         f"(this codec speaks {VERSION})")
    off = _CHDR.size
    out = []
    for _ in range(n_entries):
        if off + _CENT.size + _DIGEST_BYTES > len(data):
            raise ValueError("truncated chunk entry")
        t, l, c, tag = _CENT.unpack_from(data, off)
        off += _CENT.size
        digest = data[off:off + _DIGEST_BYTES]
        off += _DIGEST_BYTES
        payload = None
        if tag == 0:
            if off + 4 > len(data):
                raise ValueError("truncated chunk payload length")
            (size,) = struct.unpack_from("<I", data, off)
            off += 4
            if off + size > len(data):
                raise ValueError(
                    f"corrupted chunk payload length {size} overruns the "
                    f"frame ({len(data) - off} bytes left)")
            payload = data[off:off + size]
            off += size
        elif tag != 1:
            raise ValueError(f"unknown chunk entry tag {tag}")
        out.append((t, l, c, tag, digest, payload))
    if off != len(data):
        raise ValueError(f"{len(data) - off} trailing bytes after the last "
                         f"chunk entry")
    return seq_id, out


@dataclasses.dataclass
class SequenceBlob:
    """One admitted sequence's transferable cache state (host arrays).

    Array layout is per-shard, shard-major: every array carries a leading
    ``(tp, n_layers)`` axis pair (the stacked per-shard views the engine's
    ``export_slot`` produces under shard_map).  ``kv`` is None for
    attention-free configs, ``ssm`` for attention-only ones.  See
    ``repro.models.cache.export_sequence`` for the byte-level WIRE FORMAT
    this serializes to.  In streaming mode, page payloads travel ahead of
    the blob as :func:`pack_chunk` frames and the blob's page section
    carries tag-1 references to them (the receiver resolves them from its
    digest store, where the chunks landed).
    """
    codec_on: bool
    tp: int
    n_layers: int
    n_cols: int
    blk: int
    w: int
    k: int
    esc_cap: int
    npad: int
    length: int
    cur_token: int
    emitted: List[int]
    kv: Optional[Dict[str, np.ndarray]]     # field name -> (tp, L, ...) array
    ssm: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]

    # -- geometry ----------------------------------------------------------

    def valid_cols(self, shard: int) -> int:
        """Full pages shard ``shard`` contributed (host mirror of
        ``cache.local_full_pages``)."""
        if self.length <= 0:
            return 0
        loc = max((self.length - 1 - shard) // self.tp + 1, 0)
        return loc // self.blk

    @property
    def n_valid_pages(self) -> int:
        return sum(self.valid_cols(t) for t in range(self.tp)) * self.n_layers

    @property
    def raw_bytes(self) -> int:
        """bf16-dense bytes of the same payload — the uncompressed-transfer
        baseline the link metering divides by (pages at 2 B/value + the
        ring rows + the SSM state at its native width)."""
        n = 0
        if self.kv is not None:
            n += self.n_valid_pages * self.blk * self.w * 2
            n += self.kv["ring"].nbytes
        if self.ssm is not None:
            n += sum(a.nbytes for a in self.ssm)
        return n

    # -- page payload extraction ------------------------------------------

    def _page_payload(self, t: int, l: int, c: int) -> bytes:
        return page_payload(self.kv, self.codec_on, t, l, c)

    def page_entries(self) -> Iterator[Tuple[int, int, int, bytes]]:
        """(shard, layer, col, payload) for every VALID page, in wire
        order (shard-major, then layer, then column)."""
        for t in range(self.tp):
            for l in range(self.n_layers):
                for c in range(self.valid_cols(t)):
                    yield t, l, c, self._page_payload(t, l, c)

    # -- serialization -----------------------------------------------------

    def to_wire(self, known: Optional[Set[bytes]] = None
                ) -> Tuple[bytes, List[Tuple[bytes, bytes]], List[bytes]]:
        """Serialize to the version-1 wire format.

        ``known``: digests the receiver already holds — matching pages ship
        as 13-byte references instead of payloads.  Returns ``(data,
        inline, refs)`` where ``inline`` lists the (digest, payload) pairs
        that crossed in full (the sender adds them to its picture of the
        receiver's store after a successful send) and ``refs`` the digests
        that shipped as references.
        """
        flags = ((_FLAG_CODEC if self.codec_on else 0)
                 | (_FLAG_KV if self.kv is not None else 0)
                 | (_FLAG_SSM if self.ssm is not None else 0))
        parts = [_HDR.pack(MAGIC, VERSION, flags, self.tp, self.n_layers,
                           self.n_cols, self.blk, self.w, self.k,
                           self.esc_cap, self.npad, self.length,
                           self.cur_token, len(self.emitted))]
        parts.append(np.asarray(self.emitted, np.int32).tobytes())
        if self.ssm is not None:
            h, cx, cbc = self.ssm
            nh_loc, hd, nst = h.shape[2:]
            parts.append(struct.pack("<HHHHI", nh_loc, hd, nst,
                                     cx.shape[2], cx.shape[3]))
            parts += [h.tobytes(), cx.tobytes(), cbc.tobytes()]
        if self.kv is not None:
            parts.append(self.kv["ring"].tobytes())
        inline: List[Tuple[bytes, bytes]] = []
        refs: List[bytes] = []
        if self.kv is not None:
            known = set(known) if known is not None else None
            for _, _, _, payload in self.page_entries():
                digest = _page_digest(payload)
                if known is not None and digest in known:
                    parts.append(b"\x01" + digest)
                    refs.append(digest)
                else:
                    parts.append(b"\x00" + digest + payload)
                    inline.append((digest, payload))
                    if known is not None:
                        known.add(digest)          # dedupe within one blob
        return b"".join(parts), inline, refs

    @classmethod
    def from_wire(cls, data: bytes,
                  store: Optional["DigestStore"] = None
                  ) -> "SequenceBlob":
        """Parse a version-1 wire blob.  ``store`` resolves tag-1 page
        references (content digest -> payload; a plain dict works too); an
        unknown digest or a version/magic mismatch raises ``ValueError``
        before any state is touched."""
        if len(data) < _HDR.size:
            raise ValueError(f"truncated wire header ({len(data)} bytes)")
        (magic, version, flags, tp, n_layers, n_cols, blk, w, k, esc_cap,
         npad, length, cur_token, n_emitted) = _HDR.unpack_from(data, 0)
        if magic != MAGIC:
            raise ValueError(f"bad wire magic {magic!r}")
        if version != VERSION:
            raise ValueError(f"unsupported wire version {version} "
                             f"(this codec speaks {VERSION})")
        off = _HDR.size
        codec_on = bool(flags & _FLAG_CODEC)
        emitted = np.frombuffer(data, np.int32, n_emitted, off).tolist()
        off += 4 * n_emitted

        def rd(dtype, shape):
            nonlocal off
            dt = np.dtype(dtype)
            n = int(np.prod(shape))
            if off + n * dt.itemsize > len(data):
                raise ValueError(
                    f"truncated wire section at offset {off}: need "
                    f"{n * dt.itemsize} bytes, {len(data) - off} left")
            a = np.frombuffer(data, dt, n, off).reshape(shape).copy()
            off += n * dt.itemsize
            return a

        ssm = None
        if flags & _FLAG_SSM:
            nh_loc, hd, nst, kc, di_loc = struct.unpack_from("<HHHHI",
                                                             data, off)
            off += struct.calcsize("<HHHHI")
            ssm = (rd(np.float32, (tp, n_layers, nh_loc, hd, nst)),
                   rd(BF16, (tp, n_layers, kc, di_loc)),
                   rd(BF16, (tp, n_layers, kc, 2 * nst)))

        kv = None
        if flags & _FLAG_KV:
            ring = rd(BF16, (tp, n_layers, blk, w))
            kv = empty_page_fields(codec_on, tp, n_layers, n_cols, blk, w,
                                   k, esc_cap, npad)
            kv["ring"] = ring
            blob = cls(codec_on=codec_on, tp=tp, n_layers=n_layers,
                       n_cols=n_cols, blk=blk, w=w, k=k, esc_cap=esc_cap,
                       npad=npad, length=length, cur_token=cur_token,
                       emitted=emitted, kv=kv, ssm=ssm)
            size = blob._payload_size()
            for t in range(tp):
                for l in range(n_layers):
                    for c in range(blob.valid_cols(t)):
                        if off + 1 + _DIGEST_BYTES > len(data):
                            raise ValueError(
                                f"truncated page entry (shard {t}, layer "
                                f"{l}, col {c})")
                        tag = data[off]
                        digest = data[off + 1:off + 1 + _DIGEST_BYTES]
                        off += 1 + _DIGEST_BYTES
                        if tag == 1:
                            if store is None or digest not in store:
                                raise ValueError(
                                    "unknown page digest on wire — the "
                                    "receiver's content store is missing "
                                    f"{digest.hex()} (shard {t}, layer {l},"
                                    f" col {c})")
                            payload = store[digest]
                        else:
                            if off + size > len(data):
                                raise ValueError(
                                    f"truncated page payload (shard {t}, "
                                    f"layer {l}, col {c}): need {size} "
                                    f"bytes, {len(data) - off} left")
                            payload = data[off:off + size]
                            off += size
                            if store is not None:
                                store[digest] = payload
                        blob._scatter_payload(t, l, c, payload)
            return blob
        return cls(codec_on=codec_on, tp=tp, n_layers=n_layers,
                   n_cols=n_cols, blk=blk, w=w, k=k, esc_cap=esc_cap,
                   npad=npad, length=length, cur_token=cur_token,
                   emitted=emitted, kv=None, ssm=ssm)

    def _payload_size(self) -> int:
        return payload_nbytes(self.codec_on, self.blk, self.w, self.k,
                              self.esc_cap, self.npad)

    def _scatter_payload(self, t: int, l: int, c: int,
                         payload: bytes) -> None:
        scatter_page_payload(self.kv, self.codec_on, t, l, c, payload,
                             blk=self.blk, w=self.w, k=self.k,
                             esc_cap=self.esc_cap, npad=self.npad)


# ---------------------------------------------------------------------------
# the receiver-side content store
# ---------------------------------------------------------------------------


class DigestStore:
    """Receiver-side content-addressed page store: digest -> payload,
    LRU-bounded with pinning.

    The store is the RECEIVER's half of page dedup: inline payloads land
    here as they arrive (wire blobs and streaming chunks alike), tag-1
    references resolve from here, and a sender decides what to inline by
    querying ``digests()`` (the inventory).  Every insert is verified
    against its digest, so a corrupted payload fails loudly at ingest.

    Eviction is explicit: :meth:`trim` drops least-recently-used entries
    down to ``max_pages`` and is called by transports at transfer
    boundaries only — never mid-parse, so a blob can always resolve the
    references its sender serialized against a pre-trim inventory.
    In-flight streamed pages are pinned per transfer (:meth:`pin` /
    :meth:`release`); trim skips pinned entries, so the store may overshoot
    its bound while streams are open.
    """

    def __init__(self, max_pages: int = 4096):
        if max_pages < 1:
            raise ValueError("max_pages must be >= 1")
        self.max_pages = max_pages
        self._lru: "OrderedDict[bytes, bytes]" = OrderedDict()
        self._pins: Dict[int, Set[bytes]] = {}
        self._pin_count: Dict[bytes, int] = {}
        self.n_inserted = 0
        self.n_evicted = 0
        self.n_hits = 0

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, digest: bytes) -> bool:
        return digest in self._lru

    def __getitem__(self, digest: bytes) -> bytes:
        payload = self._lru[digest]
        self._lru.move_to_end(digest)
        self.n_hits += 1
        return payload

    def __setitem__(self, digest: bytes, payload: bytes) -> None:
        if _page_digest(payload) != digest:
            raise ValueError(
                f"payload does not hash to its digest {digest.hex()} — "
                "corrupted page on the wire")
        if digest in self._lru:
            self._lru.move_to_end(digest)
            return
        self._lru[digest] = payload
        self.n_inserted += 1

    def digests(self) -> Set[bytes]:
        """The inventory a sender dedups against."""
        return set(self._lru)

    def pin(self, seq_id: int, digest: bytes) -> None:
        """Protect ``digest`` from eviction until transfer ``seq_id``
        completes (:meth:`release`)."""
        pins = self._pins.setdefault(seq_id, set())
        if digest not in pins:
            pins.add(digest)
            self._pin_count[digest] = self._pin_count.get(digest, 0) + 1

    def release(self, seq_id: int) -> None:
        for digest in self._pins.pop(seq_id, ()):  # absent seq is a no-op
            n = self._pin_count[digest] - 1
            if n:
                self._pin_count[digest] = n
            else:
                del self._pin_count[digest]

    def trim(self) -> int:
        """Evict LRU entries (skipping pinned) down to ``max_pages``;
        returns how many were dropped."""
        evicted = 0
        if len(self._lru) > self.max_pages:
            for digest in list(self._lru):
                if len(self._lru) <= self.max_pages:
                    break
                if digest in self._pin_count:
                    continue
                del self._lru[digest]
                evicted += 1
        self.n_evicted += evicted
        return evicted


@dataclasses.dataclass
class TransportStats:
    """Cumulative link accounting across transfers (one link / direction).

    Since the telemetry refactor this is a *view*: every field is backed
    by a ``transport.*`` / ``link.*`` counter in the transport's
    :class:`~repro.serve.telemetry.MetricsRegistry` (see
    :meth:`from_registry`); ``PageTransport.stats`` materializes it on
    read, so the field names every test and bench row keys on are
    unchanged while the counters themselves live in the unified
    namespace.

    ``wire_bytes`` counts the data plane only — streaming chunks plus the
    closing wire blobs; a socket transport's control frames (hello,
    inventory, acks) are not metered, matching the loopback baseline."""
    n_transfers: int = 0
    wire_bytes: int = 0          # bytes that actually crossed (with dedup)
    wire_bytes_nodedup: int = 0  # same transfers, dedup disabled (codec only)
    raw_bytes: int = 0           # bf16-dense bytes of the same payloads
    pages_inline: int = 0        # page payloads shipped in full (incl. chunks)
    pages_ref: int = 0           # pages replaced by content references
    pages_streamed: int = 0      # inline payloads that went ahead in chunks
    stream_chunk_bytes: int = 0  # bytes of those chunk frames
    pages_resent: int = 0        # inline payloads re-sent after receiver
                                 # eviction (the store forgot them)
    store_evicted: int = 0       # receiver-store pages dropped by LRU trim
    pages_fetched: int = 0       # payloads pulled BACK by digest (FETCH —
                                 # the remote tier of the PageCache)
    fetch_bytes: int = 0         # bytes of those fetched payloads
    model_ns: float = 0.0        # LinkModel latency of the wire bytes
    model_ns_raw: float = 0.0    # LinkModel latency of the raw baseline

    @property
    def reduction(self) -> float:
        """Fractional link-byte reduction vs the bf16-dense transfer —
        the serving-stack analogue of the paper's Table 3 column."""
        return 1.0 - self.wire_bytes / max(self.raw_bytes, 1)

    @classmethod
    def from_registry(cls, reg: MetricsRegistry) -> "TransportStats":
        v = reg.value
        return cls(
            n_transfers=v("transport.transfers"),
            wire_bytes=v("transport.wire_bytes"),
            wire_bytes_nodedup=v("transport.wire_bytes_nodedup"),
            raw_bytes=v("transport.raw_bytes"),
            pages_inline=v("transport.pages_inline"),
            pages_ref=v("transport.pages_ref"),
            pages_streamed=v("transport.pages_streamed"),
            stream_chunk_bytes=v("transport.stream_chunk_bytes"),
            pages_resent=v("transport.pages_resent"),
            store_evicted=v("transport.store_evicted"),
            pages_fetched=v("transport.pages_fetched"),
            fetch_bytes=v("transport.fetch_bytes"),
            model_ns=float(v("link.model_ns")),
            model_ns_raw=float(v("link.model_ns_raw")))


class PageTransport:
    """Interface of the prefill→decode handoff link.

    ``send`` serializes (and meters) a blob for a destination; ``recv``
    reconstructs it on the destination side; ``stream_pages`` ships full
    pages ahead of the tail (``new_stream`` mints the transfer id,
    ``abort_stream`` cancels one whose sequence never transferred).
    Implementations own (or speak to) the per-destination
    :class:`DigestStore` that backs page dedup, and expose its
    ``inventory`` so senders ship only unknown digests.  In-process:
    :class:`LoopbackTransport`; across OS processes:
    ``repro.serve.net.client.SocketTransport`` (same WIRE FORMAT, framed
    over TCP — see ``repro.serve.net.framing``).
    """

    def __init__(self):
        # every byte/latency counter lives here (transport.* / link.*);
        # ``stats`` below is the compatibility view over it
        self.registry = MetricsRegistry()
        self._seq_ids = itertools.count(1)
        self._ever_sent: Dict[str, Set[bytes]] = {}

    @property
    def stats(self) -> TransportStats:
        return TransportStats.from_registry(self.registry)

    def new_stream(self) -> int:
        """Mint a transfer id for a streamed sequence."""
        return next(self._seq_ids)

    def _count_resent(self, dst: str,
                      inline: List[Tuple[bytes, bytes]]) -> None:
        """Meter inline payloads this link already shipped once: a repeat
        means the receiver's store evicted them (``pages_resent``)."""
        seen = self._ever_sent.setdefault(dst, set())
        resent = self.registry.counter("transport.pages_resent")
        for digest, _ in inline:
            if digest in seen:
                resent.inc()
            seen.add(digest)

    def inventory(self, dst: str) -> Set[bytes]:
        """Digests the receiver behind ``dst`` currently holds."""
        raise NotImplementedError

    def stream_pages(self, dst: str, seq_id: int,
                     entries: Sequence[Tuple[int, int, int, bytes]]) -> None:
        raise NotImplementedError

    def abort_stream(self, dst: str, seq_id: int) -> None:
        raise NotImplementedError

    def send(self, blob: SequenceBlob, dst: str,
             seq_id: Optional[int] = None) -> bytes:
        raise NotImplementedError

    def recv(self, data: bytes, dst: str,
             seq_id: Optional[int] = None) -> SequenceBlob:
        raise NotImplementedError

    def fetch(self, dst: str,
              digests: Sequence[bytes]) -> Dict[bytes, bytes]:
        """Remote tier: pull page payloads back OUT of ``dst``'s store by
        content digest (the reverse direction of ``send``).  Returns the
        subset found — a missing digest is not an error, the caller falls
        back to its next tier (ultimately re-prefill)."""
        raise NotImplementedError


class LoopbackTransport(PageTransport):
    """In-process transport: full serialize → bytes → parse round trip (so
    the byte format is exercised on every handoff), with receiver-side
    content-addressed page dedup and LinkModel metering.

    ``dedup=False`` ships every page inline (the codec-only baseline).
    ``hops`` positions the prefill and decode replicas on the chiplet mesh
    for the latency model.  Each destination owns a :class:`DigestStore`
    bounded at ``max_store_pages`` (LRU; in-flight streams are pinned).
    Loopback contract: ``recv`` a wire blob before the next ``send`` to the
    same destination — the store is only trimmed at ``recv``/abort
    boundaries, so references never dangle mid-transfer.
    """

    def __init__(self, dedup: bool = True, hops: int = 2,
                 link: Optional[LinkModel] = None,
                 max_store_pages: int = 4096):
        super().__init__()
        self.dedup = dedup
        self.hops = hops
        self.link = link if link is not None else LinkModel()
        # actual traffic is priced through the meter (-> link.bytes /
        # link.model_ns); the bare ``self.link`` stays for hypothetical
        # baselines (model_ns_raw) so they never pollute link bytes
        self._meter = MeteredLink(self.link, self.registry)
        self.max_store_pages = max_store_pages
        self._stores: Dict[str, DigestStore] = {}

    def store(self, dst: str) -> DigestStore:
        return self._stores.setdefault(dst,
                                       DigestStore(self.max_store_pages))

    def inventory(self, dst: str) -> Set[bytes]:
        return self.store(dst).digests()

    def stream_pages(self, dst, seq_id, entries) -> None:
        store = self.store(dst)
        known = store.digests() if self.dedup else None
        data, inline, refs = pack_chunk(seq_id, entries, known)
        if self.dedup:
            self._count_resent(dst, inline)
        reg = self.registry
        reg.counter("transport.stream_chunk_bytes").inc(len(data))
        reg.counter("transport.wire_bytes").inc(len(data))
        reg.counter("transport.pages_streamed").inc(len(inline))
        reg.counter("transport.pages_inline").inc(len(inline))
        reg.counter("transport.pages_ref").inc(len(refs))
        self._meter.transfer_ns(len(data), self.hops)
        for digest, payload in inline:
            store[digest] = payload
        for digest in itertools.chain((d for d, _ in inline), refs):
            store.pin(seq_id, digest)

    def abort_stream(self, dst, seq_id) -> None:
        store = self.store(dst)
        store.release(seq_id)
        self.registry.counter("transport.store_evicted").inc(store.trim())

    def send(self, blob: SequenceBlob, dst: str,
             seq_id: Optional[int] = None) -> bytes:
        store = self.store(dst)
        known = store.digests() if self.dedup else None
        data, inline, refs = blob.to_wire(known)
        if self.dedup:
            self._count_resent(dst, inline)
        # a ref entry is the inline entry minus its payload, so the
        # dedup-off size is pure arithmetic — no second serialization
        nodedup_len = len(data) + len(refs) * blob._payload_size()
        reg = self.registry
        reg.counter("transport.transfers").inc()
        reg.counter("transport.wire_bytes").inc(len(data))
        reg.counter("transport.wire_bytes_nodedup").inc(nodedup_len)
        reg.counter("transport.raw_bytes").inc(blob.raw_bytes)
        reg.counter("transport.pages_inline").inc(len(inline))
        reg.counter("transport.pages_ref").inc(len(refs))
        self._meter.transfer_ns(len(data), self.hops)
        reg.counter("link.model_ns_raw").inc(
            self.link.transfer_ns(blob.raw_bytes, self.hops))
        if self.dedup:
            for digest, payload in inline:
                store[digest] = payload
        return data

    def recv(self, data: bytes, dst: str,
             seq_id: Optional[int] = None) -> SequenceBlob:
        # the loopback receiver shares the sender-maintained store (same
        # host); a remote receiver maintains its own from inline payloads
        store = self.store(dst)
        blob = SequenceBlob.from_wire(data, store if self.dedup else None)
        if seq_id is not None:
            store.release(seq_id)
        self.registry.counter("transport.store_evicted").inc(store.trim())
        return blob

    def fetch(self, dst: str,
              digests: Sequence[bytes]) -> Dict[bytes, bytes]:
        store = self.store(dst)
        out = {d: store[d] for d in digests if d in store}
        nbytes = sum(len(p) for p in out.values())
        reg = self.registry
        reg.counter("transport.pages_fetched").inc(len(out))
        reg.counter("transport.fetch_bytes").inc(nbytes)
        self._meter.transfer_ns(nbytes, self.hops)
        return out
