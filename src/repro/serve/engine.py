"""Serving engine: prefill + decode with LEXI-compressed weights,
activations, and hybrid caches (manual-SPMD, runs inside shard_map).

Two decode dataflows share the per-layer compute:

**Fixed-batch** (``prefill`` → ``decode_step``): all B sequences advance in
lockstep from one shared length — the original research loop, still what
the dry-run shapes lower and what the correctness tests diff against.

**Continuous batching** (``serve.scheduler.ServeEngine`` drives the paged
entry points here): a slot-based engine where each decode slot holds one
independent request.  The scheduler dataflow is

  request queue ──admit──▶ vmapped B=1 prefills, ONE dispatch per length
                           bucket (blocks LEXI-compressed layer-by-layer,
                           per sequence) ──▶ ``insert_sequences`` scatters
                           each sequence's compressed blocks into its own
                           page-table row + SSM state slot; prefix-cache
                           hits skip prefill entirely (``map_shared_slot``)
                           and unaligned tails replay per slot through
                           ``paged_replay_steps``
        slots   ──step───▶ ``paged_decode_step``: every active slot appends
                           at its OWN length (per-slot rope, per-slot ring,
                           page allocation on block boundary) and attends
                           through its page table; one greedy token per slot
        finish  ──evict──▶ ``release_slots`` frees the slot's pages back to
                           the pool for the next admission

Per-layer decode compute (x (B,1,D) replicated over "model") is identical
in both modes: norm → sharded projections → tiny all_gathers (q to full
heads) → cache append (owner-shard ring, block-compress on fill) → partial
attention over the local cache shard (compressed blocks/pages streamed) →
logsumexp merge (one small psum) → sliced-head o-projection → [+ SSM
recurrent update for hybrids] → one psum → residual.  MoE decode routes
locally (tokens are replicated over "model", so each shard just runs its
own experts on the tokens routed to them — zero dispatch a2a at decode,
partial-sum combine).

Continuous mode currently covers decoder-only families (dense/MoE/SSM/
hybrid); enc-dec cross-attention memory stays on the fixed-batch path.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core import collectives as cl
from repro.models import attention, blocks, cache as cache_mod, layers, lm
from repro.models import ssm as ssm_mod
from repro.models.cache import KVBlocks
from repro.models.ssm import SSMState


class DecodeState(NamedTuple):
    kv: Optional[KVBlocks]       # stacked (L, ...) or None (pure SSM)
    ssm: Optional[SSMState]      # stacked (L, ...) or None
    xkv: Optional[KVBlocks]      # enc-dec cross-attention memory (static)
    length: jax.Array            # () i32 — global tokens so far


# ---------------------------------------------------------------------------
# state construction
# ---------------------------------------------------------------------------

def empty_state(cfg: ModelConfig, run: RunConfig, batch_loc: int,
                max_len: int, tp: int) -> DecodeState:
    """Zeroed decode state (also the dry-run's abstract cache shape)."""
    L = cfg.n_layers
    kv = ssm = xkv = None
    stack = lambda one: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), one)
    if cfg.n_heads > 0:
        kv = stack(cache_mod.empty_kv(cfg, run, batch_loc, max_len, tp))
    if cfg.encdec:
        xkv = stack(cache_mod.empty_kv(cfg, run, batch_loc, max_len, tp))
    if cfg.ssm is not None:
        di, nh, hd, n = ssm_mod.ssm_dims(cfg, tp)
        k = cfg.ssm.d_conv - 1
        ssm = SSMState(
            h=jnp.zeros((L, batch_loc, nh // tp, hd, n), jnp.float32),
            conv_x=jnp.zeros((L, batch_loc, k, di // tp), jnp.bfloat16),
            conv_bc=jnp.zeros((L, batch_loc, k, 2 * n), jnp.bfloat16))
    return DecodeState(kv=kv, ssm=ssm, xkv=xkv,
                       length=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# global (cross-shard) view of the state, for dry-run in_shardings.
#
# Per-shard cache stores are semantically *sharded objects*; the global
# arrays adopt the convention that per-shard dims are concatenated along a
# mesh-sharded axis (flattened shard-major where batch and model coexist).
# ---------------------------------------------------------------------------

def global_state_struct(cfg: ModelConfig, run: RunConfig, global_batch: int,
                        max_len: int, mesh_chips: Dict[str, int]):
    """Returns (state ShapeDtypeStruct pytree, state PartitionSpec pytree).

    ``mesh_chips``: {"pod": p, "data": d, "model": t}.  When the global
    batch does not divide pod*data the batch is replicated (long_500k: B=1).
    """
    from jax.sharding import PartitionSpec as P
    import numpy as np
    tp = mesh_chips["model"]
    nbatch = mesh_chips.get("pod", 1) * mesh_chips["data"]
    shardable = global_batch % nbatch == 0
    b_loc = global_batch // nbatch if shardable else global_batch
    baxes = (tuple(a for a in ("pod", "data") if mesh_chips.get(a, 1) > 1)
             if shardable else ())
    bspec = (baxes if len(baxes) > 1 else (baxes[0] if baxes else None))

    L = cfg.n_layers
    f32, bf16, i32, u8, u32 = (jnp.float32, jnp.bfloat16, jnp.int32,
                               jnp.uint8, jnp.uint32)
    sd = jax.ShapeDtypeStruct

    kv_s = kv_p = None
    if cfg.n_heads > 0:
        w = cache_mod.kv_width(cfg)
        blk = run.codec.cache_block
        nblk = cache_mod.n_blocks(cfg, run, max_len, tp)
        n = b_loc * blk * w
        from repro.core import packing
        npad = packing.pad_to_lanes(n)
        c = run.codec.esc_capacity(n)
        k = run.codec.k
        # flatten (batch shards x model shards) along the payload dim
        flat_axes = tuple(a for a in (*baxes, "model"))
        fspec = flat_axes if len(flat_axes) > 1 else flat_axes[0]
        nshard = nbatch * tp if shardable else tp
        if run.codec.cache:
            kv_s = KVBlocks(
                signman=sd((L, nblk, n * nshard), u8),
                planes=sd((L, nblk, k, (npad // 32) * nshard), u32),
                dict_syms=sd((L, nblk, (1 << k) * nshard), u8),
                esc_pos=sd((L, nblk, c * nshard), i32),
                esc_raw=sd((L, nblk, c * nshard), u8),
                raw_blocks=None,
                ring=sd((L, global_batch if shardable else b_loc,
                         blk * tp, w), bf16),
                length=sd((L,), i32))
            kv_p = KVBlocks(
                signman=P(None, None, fspec),
                planes=P(None, None, None, fspec),
                dict_syms=P(None, None, fspec),
                esc_pos=P(None, None, fspec),
                esc_raw=P(None, None, fspec),
                raw_blocks=None,
                ring=P(None, bspec, "model", None),
                length=P(None))
        else:
            kv_s = KVBlocks(
                signman=None, planes=None, dict_syms=None, esc_pos=None,
                esc_raw=None,
                raw_blocks=sd((L, nblk, global_batch if shardable else b_loc,
                               blk * tp, w), bf16),
                ring=sd((L, global_batch if shardable else b_loc,
                         blk * tp, w), bf16),
                length=sd((L,), i32))
            kv_p = KVBlocks(
                signman=None, planes=None, dict_syms=None, esc_pos=None,
                esc_raw=None,
                raw_blocks=P(None, None, bspec, "model", None),
                ring=P(None, bspec, "model", None),
                length=P(None))

    ssm_s = ssm_p = None
    if cfg.ssm is not None:
        di, nh, hd, nst = ssm_mod.ssm_dims(cfg, tp)
        kc = cfg.ssm.d_conv - 1
        gb = global_batch if shardable else b_loc
        ssm_s = SSMState(
            h=sd((L, gb, nh, hd, nst), jnp.float32),
            conv_x=sd((L, gb, kc, di), bf16),
            conv_bc=sd((L, gb, kc, 2 * nst), bf16))
        ssm_p = SSMState(
            h=P(None, bspec, "model", None, None),
            conv_x=P(None, bspec, None, "model"),
            conv_bc=P(None, bspec, None, None))

    xkv_s = xkv_p = None
    if cfg.encdec:
        xkv_s, xkv_p = kv_s, kv_p     # same geometry as the self cache

    state = DecodeState(kv=kv_s, ssm=ssm_s, xkv=xkv_s,
                        length=jax.ShapeDtypeStruct((), jnp.int32))
    specs = DecodeState(kv=kv_p, ssm=ssm_p, xkv=xkv_p, length=P())
    return state, specs


# ---------------------------------------------------------------------------
# decode block
# ---------------------------------------------------------------------------

def _moe_decode(cfg: ModelConfig, run: RunConfig, p, x: jax.Array,
                tp: int) -> jax.Array:
    """MoE on replicated decode tokens: local experts only, psum combine."""
    e = cfg.moe
    b = x.shape[0]
    xt = x[:, 0]                                        # (B, D)
    logits = layers.matmul_f32(xt, p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, e.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    el = e.n_experts // tp
    ti = jax.lax.axis_index("model")
    lo = ti * el
    y = jnp.zeros((b, cfg.d_model), jnp.float32)
    # expert stacks decoded once per step (raw_weight: exact in-graph decode
    # for packed leaves), then gathered per hit as before
    ewg = layers.raw_weight(p["w_gate"])
    ewu = layers.raw_weight(p["w_up"])
    ewd = layers.raw_weight(p["w_down"])
    # tokens are replicated: each shard evaluates only its experts' hits
    for j in range(e.top_k):                            # unrolled, small
        eid = experts[:, j]
        local = (eid >= lo) & (eid < lo + el)
        idx = jnp.clip(eid - lo, 0, el - 1)
        wg = ewg[idx]                                   # (B, D, F) gathered
        wu = ewu[idx]
        wd = ewd[idx]
        h = layers.swiglu(
            jnp.einsum("bd,bdf->bf", xt, wg,
                       preferred_element_type=jnp.float32).astype(jnp.bfloat16),
            jnp.einsum("bd,bdf->bf", xt, wu,
                       preferred_element_type=jnp.float32).astype(jnp.bfloat16))
        o = jnp.einsum("bf,bfd->bd", h, wd,
                       preferred_element_type=jnp.float32)
        y = y + jnp.where(local[:, None], o * gates[:, j:j + 1], 0.0)
    if e.n_shared:
        hs = layers.swiglu(layers.pdot(xt, p["ws_gate"]),
                           layers.pdot(xt, p["ws_up"]))
        y = y + layers.matmul_f32(hs, p["ws_down"])
    return jax.lax.psum(y.astype(jnp.bfloat16), "model")[:, None]


def decode_block(cfg: ModelConfig, run: RunConfig, p, x: jax.Array,
                 kv: Optional[KVBlocks], sst: Optional[SSMState],
                 length, spec: layers.AttnSpec, tp: int, window=None,
                 xkv: Optional[KVBlocks] = None):
    """One layer's decode step.  x (B,1,D) replicated; returns
    (x', kv', sst').  ``xkv`` is the (static) cross-attention memory."""
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    partial = jnp.zeros(x.shape, jnp.float32)
    new_kv, new_sst = kv, sst

    if cfg.n_heads > 0:
        q_full, new_vals = attention.decode_qkv(cfg, p["attn"], h, length, tp)
        new_kv = cache_mod.append_token(cfg, run, kv, new_vals, tp)
        aspec = spec
        if cfg.mla is not None:
            aspec = spec._replace(
                scale=(cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim) ** -0.5)
        merged = cache_mod.attend_cache(cfg, run, new_kv, q_full, aspec, tp,
                                        window=window)
        partial = partial + attention.decode_out(cfg, p["attn"], merged, tp)
    if cfg.ssm is not None:
        o, new_sst = ssm_mod.ssm_decode_step(cfg, p["ssm"], h, sst, tp)
        partial = partial + o

    out = jax.lax.psum(partial.astype(jnp.bfloat16), "model")
    if cfg.post_norm:
        out = layers.rms_norm(out, p["ln1b"], cfg.norm_eps)
    x = x + out

    if "xattn" in p and xkv is not None:
        # enc-dec cross attention against the static (prefill-built) memory
        hx = layers.rms_norm(x, p["ln_x"], cfg.norm_eps)
        q_full = cross_decode_q(cfg, p["xattn"], hx, tp)
        xspec = layers.AttnSpec(causal=False, softcap=None)
        merged = cache_mod.attend_cache(cfg, run, xkv, q_full, xspec, tp)
        xo = attention.decode_out(cfg, p["xattn"], merged, tp)
        x = x + jax.lax.psum(xo.astype(jnp.bfloat16), "model")

    x = _ffn_decode(cfg, run, p, x, tp)
    return x, new_kv, new_sst


def _ffn_decode(cfg: ModelConfig, run: RunConfig, p, x: jax.Array,
                tp: int) -> jax.Array:
    """The MoE/MLP tail of a decode layer (shared by both decode modes)."""
    if "moe" in p:
        h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + _moe_decode(cfg, run, p["moe"], h2, tp)
    elif "mlp" in p:
        h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        m = p["mlp"]
        act = layers.swiglu(layers.pdot(h2, m["w_gate"]),
                            layers.pdot(h2, m["w_up"]))
        y = layers.matmul_f32(act, m["w_down"])
        y = jax.lax.psum(y.astype(jnp.bfloat16), "model")
        if cfg.post_norm:
            y = layers.rms_norm(y, p["ln2b"], cfg.norm_eps)
        x = x + y
    return x


def cross_decode_q(cfg: ModelConfig, p, h: jax.Array, tp: int) -> jax.Array:
    """Cross-attention decode query: (B,1,D) -> full-head q (no rope/norm)."""
    hd = cfg.head_dim
    hq = cfg.padded_heads(tp)
    hq_loc = hq // tp
    b = h.shape[0]
    q = layers.pdot(h, p["wq"], p.get("bq")).reshape(b, 1, hq_loc, hd) \
        .transpose(0, 2, 1, 3)
    return jax.lax.all_gather(q, "model", axis=1, tiled=True)


# ---------------------------------------------------------------------------
# decode step (full model, one token)
# ---------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, run: RunConfig, params, dims,
                state: DecodeState, tokens: jax.Array, tp: int
                ) -> Tuple[jax.Array, DecodeState]:
    """tokens (B_loc, 1) -> (logits (B_loc, 1, V_loc) local, new state).

    This is the ``serve_step`` the decode_* dry-run shapes lower.
    """
    emb = lm.gathered_embed(params, dims, run)
    # decode tokens are replicated over model: embed via vocab-shard + psum
    x = lm.embed_tokens(cfg, run, emb, tokens, tp)       # (B,1,D)
    spec = attention.base_attn_spec(cfg)
    wins = attention.layer_windows(cfg)
    wins = (jnp.asarray(wins) if wins is not None
            else jnp.zeros((cfg.n_layers,), jnp.int32))
    bdims = dims.get("blocks") if dims else None

    def body(carry, xs):
        xb = carry
        p_layer, kv_l, ssm_l, xkv_l, win = xs
        p_layer = blocks.gather_fsdp(p_layer, bdims, run)
        xb, kv_n, ssm_n = decode_block(cfg, run, p_layer, xb, kv_l, ssm_l,
                                       state.length, spec, tp, window=win,
                                       xkv=xkv_l)
        return xb, (kv_n, ssm_n)

    xs = (params["blocks"], state.kv, state.ssm, state.xkv, wins)
    x, (kv_new, ssm_new) = jax.lax.scan(body, x, xs)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm.logits_for(cfg, run, params, dims, x)
    return logits, DecodeState(kv=kv_new, ssm=ssm_new, xkv=state.xkv,
                               length=state.length + 1)


def greedy_token(cfg: ModelConfig, logits: jax.Array, tp: int) -> jax.Array:
    """Vocab-sharded greedy argmax -> (B,1) int32 (replicated)."""
    v_loc = logits.shape[-1]
    off = jax.lax.axis_index("model") * v_loc
    loc_max = logits.max(-1)
    loc_idx = logits.argmax(-1).astype(jnp.int32) + off
    g_max = jax.lax.pmax(loc_max, "model")
    cand = jnp.where(loc_max >= g_max, loc_idx, jnp.int32(1 << 30))
    return jax.lax.pmin(cand, "model")


# ---------------------------------------------------------------------------
# prefill (trunk forward + cache transition)
# ---------------------------------------------------------------------------

def _interleave_heads_a2a(vals: jax.Array, tp: int) -> jax.Array:
    """(B, H_loc, S, hd) head-sharded -> (B, S/tp, H_full*hd) interleaved
    sequence slots via one all_to_all over "model"."""
    b, h_loc, s, hd = vals.shape
    x = vals.transpose(0, 2, 1, 3)                  # (B, S, H_loc, hd)
    x = x.reshape(b, s // tp, tp, h_loc, hd)        # pos = c*tp + j
    x = jnp.moveaxis(x, 2, 0)                       # (tp, B, S/tp, H_loc, hd)
    y = jax.lax.all_to_all(x, "model", split_axis=0, concat_axis=3,
                           tiled=False)             # (B, S/tp, H_full?, ...)
    # tiled=False: the tp axis is exchanged with the device axis and lands
    # at concat_axis -> (B, S/tp, H_loc, tp, hd); heads are ordered by shard.
    y = jnp.moveaxis(y, 3, 2)                       # (B, S/tp, tp, H_loc, hd)
    return y.reshape(b, s // tp, tp * h_loc * hd)


def _interleave_slice(vals: jax.Array, tp: int) -> jax.Array:
    """(B, S, W) replicated -> this shard's interleaved slots (B, S/tp, W)."""
    b, s, w = vals.shape
    ti = jax.lax.axis_index("model")
    x = vals.reshape(b, s // tp, tp, w)
    return jnp.take(x, ti, axis=2)


def prefill(cfg: ModelConfig, run: RunConfig, params, dims,
            tokens: jax.Array, max_len: int, tp: int,
            front_embeds=None, enc_embeds=None
            ) -> Tuple[jax.Array, DecodeState]:
    """tokens (B_loc, S) -> (last-position logits (B,1,V_loc), DecodeState).

    Runs the training-style trunk (sequence-sharded, head-parallel flash)
    and builds the decode cache INSIDE the layer scan: each layer's KV is
    resharded to the interleaved sequence-sharded layout (one a2a) and
    LEXI-block-compressed immediately, so peak HBM holds one layer of raw
    KV instead of all L (the difference between ~1 GB and ~25-55 GB per
    chip at 32k prefill — see EXPERIMENTS §Dry-run memory note).
    """
    b, s = tokens.shape
    state = empty_state(cfg, run, b, max_len, tp)
    mode = attention.kv_mode(cfg, tp) if cfg.n_heads > 0 else None

    def xform(cache, store):
        out = {}
        if "kv" in cache and cache["kv"] is not None:
            if cfg.mla is not None:
                vals = _interleave_slice(cache["kv"], tp)
            else:
                k_l, v_l = cache["kv"]
                if mode == "col":
                    kv2 = jnp.stack([k_l, v_l], axis=2)
                    kv2 = kv2.reshape(b, -1, s, cfg.head_dim)
                    vals = _interleave_heads_a2a(kv2, tp)
                else:
                    kv2 = jnp.stack([k_l, v_l], axis=3)
                    kv2 = kv2.transpose(0, 2, 1, 3, 4).reshape(b, s, -1)
                    vals = _interleave_slice(kv2, tp)
            out["kv"] = cache_mod.fill_from_prefill(
                cfg, run, store["kv"], vals, s, tp)
        if "xkv" in cache and cache["xkv"] is not None:
            k_l, v_l = cache["xkv"]
            sm = k_l.shape[2] * (tp if mode == "col" else 1)
            if mode == "col":
                sm = k_l.shape[2]
                kv2 = jnp.stack([k_l, v_l], axis=2)
                kv2 = kv2.reshape(b, -1, sm, cfg.head_dim)
                vals = _interleave_heads_a2a(kv2, tp)
            else:
                sm = k_l.shape[2]
                kv2 = jnp.stack([k_l, v_l], axis=3)
                kv2 = kv2.transpose(0, 2, 1, 3, 4).reshape(b, sm, -1)
                vals = _interleave_slice(kv2, tp)
            out["xkv"] = cache_mod.fill_from_prefill(
                cfg, run, store["xkv"], vals, sm, tp)
        if "ssm" in cache and cache["ssm"] is not None:
            out["ssm"] = cache["ssm"]
        return out

    stores = {}
    if state.kv is not None:
        stores["kv"] = state.kv
    if state.xkv is not None:
        stores["xkv"] = state.xkv
    x, caches, _ = lm.lm_forward(cfg, run, params, tokens, tp, dims=dims,
                                 front_embeds=front_embeds,
                                 enc_embeds=enc_embeds, want_cache=True,
                                 cache_stores=stores if stores else None,
                                 cache_xform=xform)
    # last-position logits: the contiguous seq layout puts the global last
    # position on shard tp-1; broadcast it with one tiny psum.
    xl = x[:, -1:, :]
    xl = jax.lax.psum(jnp.where(jax.lax.axis_index("model") == tp - 1,
                                xl.astype(jnp.float32), 0.0), "model")
    logits = lm.logits_for(cfg, run, params, dims, xl.astype(jnp.bfloat16))

    kv_new = caches.get("kv") if caches else None
    xkv_new = caches.get("xkv") if caches else None
    ssm_new = caches.get("ssm") if caches else None
    if kv_new is None:
        kv_new = state.kv
    if xkv_new is None:
        xkv_new = state.xkv
    if ssm_new is None:
        ssm_new = state.ssm
    return logits, DecodeState(kv=kv_new, ssm=ssm_new, xkv=xkv_new,
                               length=jnp.asarray(s, jnp.int32))

# ---------------------------------------------------------------------------
# continuous batching: paged decode state (slot-based, per-slot lengths)
# ---------------------------------------------------------------------------

class PagedState(NamedTuple):
    """Slot-based decode state for the continuous-batching engine.

    ``kv``/``ssm`` are stacked (L, ...); ``lengths``/``active`` are per-slot
    and shared by all layers (every layer of a sequence is at the same
    position by construction).
    """
    kv: Optional[cache_mod.PagedKV]   # stacked (L, ...) or None (pure SSM)
    ssm: Optional[SSMState]           # stacked (L, n_slots, ...) or None
    lengths: jax.Array                # (n_slots,) i32 tokens held per slot
    active: jax.Array                 # (n_slots,) bool slot occupied


def empty_paged_state(cfg: ModelConfig, run: RunConfig, n_slots: int,
                      max_len: int, tp: int,
                      n_pages: Optional[int] = None) -> PagedState:
    """Zeroed paged state with a per-layer page pool sized for n_slots."""
    L = cfg.n_layers
    assert not cfg.encdec, "continuous batching covers decoder-only archs"
    kv = ssm = None
    stack = lambda one: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), one)
    if cfg.n_heads > 0:
        kv = stack(cache_mod.empty_paged_kv(cfg, run, n_slots, max_len, tp,
                                            n_pages=n_pages))
    if cfg.ssm is not None:
        di, nh, hd, n = ssm_mod.ssm_dims(cfg, tp)
        k = cfg.ssm.d_conv - 1
        ssm = SSMState(
            h=jnp.zeros((L, n_slots, nh // tp, hd, n), jnp.float32),
            conv_x=jnp.zeros((L, n_slots, k, di // tp), jnp.bfloat16),
            conv_bc=jnp.zeros((L, n_slots, k, 2 * n), jnp.bfloat16))
    return PagedState(kv=kv, ssm=ssm,
                      lengths=jnp.zeros((n_slots,), jnp.int32),
                      active=jnp.zeros((n_slots,), jnp.bool_))


def paged_state_nbytes(state: PagedState) -> int:
    """Device-HBM footprint of a paged decode state in bytes, computed
    from array shape metadata only (never a device sync): the per-layer
    page pools, rings, page tables and recurrent state a decode replica
    keeps resident.  The telemetry layer reports this as the
    ``serve.pool_bytes`` gauge (``repro.serve.scheduler.sync_metrics``)."""
    return int(sum(a.nbytes for a in jax.tree_util.tree_leaves(state)))


def paged_decode_block(cfg: ModelConfig, run: RunConfig, p, x: jax.Array,
                       kv: Optional[cache_mod.PagedKV],
                       sst: Optional[SSMState], lengths: jax.Array,
                       active: jax.Array, spec: layers.AttnSpec, tp: int,
                       window=None):
    """One layer's decode step at per-slot positions.  x (n_slots,1,D)
    replicated; returns (x', kv', sst').  Inactive slots leave their cache
    and SSM state untouched (their outputs are garbage the scheduler drops).
    """
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    partial = jnp.zeros(x.shape, jnp.float32)
    new_kv, new_sst = kv, sst

    if cfg.n_heads > 0:
        q_full, new_vals = attention.decode_qkv(cfg, p["attn"], h, lengths,
                                                tp)
        new_kv = cache_mod.append_token_paged(cfg, run, kv, new_vals,
                                              lengths, active, tp)
        aspec = spec
        if cfg.mla is not None:
            aspec = spec._replace(
                scale=(cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim) ** -0.5)
        post = lengths + active.astype(jnp.int32)    # incl. the new token
        merged = cache_mod.attend_paged(cfg, run, new_kv, q_full, post,
                                        aspec, tp, window=window)
        partial = partial + attention.decode_out(cfg, p["attn"], merged, tp)
    if cfg.ssm is not None:
        o, upd = ssm_mod.ssm_decode_step(cfg, p["ssm"], h, sst, tp)
        # inactive slots keep their previous recurrent/conv state
        keep = lambda new, old: jnp.where(
            active.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)
        new_sst = jax.tree_util.tree_map(keep, upd, sst)
        partial = partial + o

    out = jax.lax.psum(partial.astype(jnp.bfloat16), "model")
    if cfg.post_norm:
        out = layers.rms_norm(out, p["ln1b"], cfg.norm_eps)
    x = x + out
    x = _ffn_decode(cfg, run, p, x, tp)
    return x, new_kv, new_sst


def paged_decode_step(cfg: ModelConfig, run: RunConfig, params, dims,
                      state: PagedState, tokens: jax.Array, tp: int
                      ) -> Tuple[jax.Array, PagedState]:
    """tokens (n_slots, 1) -> (logits (n_slots, 1, V_loc) local, new state).

    The continuous-batching analogue of ``decode_step``: every active slot
    advances one token at its own position; inactive slots are carried
    through untouched.
    """
    emb = lm.gathered_embed(params, dims, run)
    x = lm.embed_tokens(cfg, run, emb, tokens, tp)       # (S,1,D)
    spec = attention.base_attn_spec(cfg)
    wins = attention.layer_windows(cfg)
    wins = (jnp.asarray(wins) if wins is not None
            else jnp.zeros((cfg.n_layers,), jnp.int32))
    bdims = dims.get("blocks") if dims else None

    def body(carry, xs):
        xb = carry
        p_layer, kv_l, ssm_l, win = xs
        p_layer = blocks.gather_fsdp(p_layer, bdims, run)
        xb, kv_n, ssm_n = paged_decode_block(
            cfg, run, p_layer, xb, kv_l, ssm_l, state.lengths, state.active,
            spec, tp, window=win)
        return xb, (kv_n, ssm_n)

    xs = (params["blocks"], state.kv, state.ssm, wins)
    x, (kv_new, ssm_new) = jax.lax.scan(body, x, xs)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm.logits_for(cfg, run, params, dims, x)
    lengths = state.lengths + state.active.astype(jnp.int32)
    return logits, PagedState(kv=kv_new, ssm=ssm_new, lengths=lengths,
                              active=state.active)


def insert_sequences(cfg: ModelConfig, run: RunConfig, state: PagedState,
                     d: DecodeState, slots: jax.Array, seq_len: int, tp: int
                     ) -> PagedState:
    """Insert B prefilled B=1 ``DecodeState``s (stacked on a leading batch
    axis, as a vmapped ``prefill`` produces) into paged slots ``slots``.

    ``seq_len`` is the shared static trunk length and must be a multiple of
    tp (the admission bucket); unaligned prompt tails replay through
    ``paged_replay_steps`` afterwards.  The slots must be free (their pages
    released); the caller tracks occupancy.
    """
    slots = jnp.asarray(slots, jnp.int32)
    kv = state.kv
    if kv is not None:
        # state.kv leaves are (L, ...), d.kv leaves (B, L, ...): map layers
        kv = jax.vmap(lambda pkv, kvb: cache_mod.paged_insert_many(
            cfg, run, pkv, kvb, slots, seq_len, tp),
            in_axes=(0, 1))(kv, d.kv)
    ssm = state.ssm
    if ssm is not None:
        ssm = jax.tree_util.tree_map(
            lambda a, b: a.at[:, slots].set(
                jnp.moveaxis(b[:, :, 0], 0, 1).astype(a.dtype)),
            ssm, d.ssm)
    return PagedState(
        kv=kv, ssm=ssm,
        lengths=state.lengths.at[slots].set(seq_len),
        active=state.active.at[slots].set(True))


def map_shared_slot(state: PagedState, slot, page_ids: jax.Array,
                    n_cols, base_len) -> PagedState:
    """Admit a prefix-cache hit: map ``n_cols`` already-filled page columns
    (per-shard ids ``page_ids`` (maxp,)) into ``slot``'s page-table rows of
    every layer, with zero prefill FLOPs and zero page copies.  The slot
    starts at ``base_len`` tokens; for pure attention the prompt suffix
    replays through ``paged_replay_steps``.  Any recurrent state is left
    UNTOUCHED — for hybrids the scheduler restores the matching boundary
    SSM snapshot in a separate dispatch (pages alone cannot reconstruct a
    recurrence), and MoE/MLA never take this path at all (their suffix
    replay is not bit-equal to prefill).
    """
    slot = jnp.asarray(slot, jnp.int32)
    kv = jax.vmap(lambda pkv: cache_mod.map_prefix_pages(
        pkv, slot, page_ids, n_cols))(state.kv)
    return PagedState(
        kv=kv, ssm=state.ssm,
        lengths=state.lengths.at[slot].set(jnp.asarray(base_len, jnp.int32)),
        active=state.active.at[slot].set(True))


def paged_replay_steps(cfg: ModelConfig, run: RunConfig, params, dims,
                       state: PagedState, tokens: jax.Array,
                       feed: jax.Array, tp: int
                       ) -> Tuple[jax.Array, PagedState]:
    """Replay K known tokens through the paged decode path, per slot.

    ``tokens`` (K, n_slots, 1) are fed where ``feed`` (K, n_slots) is True;
    non-fed slots (mid-decode neighbours, or replaying slots whose shorter
    tail already finished) are masked inactive for that step, so their
    cache/SSM state and lengths are untouched.  Returns the per-step greedy
    tokens (K, n_slots, 1) — the scheduler reads slot s's first generated
    token from the step that consumed s's last prompt token — plus the new
    state.  Numerics per step are exactly ``paged_decode_step``; for PURE
    ATTENTION that makes trunk prefill + replay bit-equal to a full
    prefill, but MoE/SSM/MLA decode combines shard partials on a different
    float path than their batched prefill (see ``scheduler._bucket_of``),
    so for those the scheduler keeps in-prompt replays under tp tokens.
    """
    def body(st, xs):
        tok, fd = xs
        logits, st2 = paged_decode_step(
            cfg, run, params, dims, st._replace(active=st.active & fd),
            tok, tp)
        return st2._replace(active=st.active), greedy_token(cfg, logits, tp)

    state, seq = jax.lax.scan(body, state, (tokens, feed))
    return seq, state


def export_slot(state: PagedState, slot, n_cols: int, tp: int, col0=0):
    """Export one slot's full cache payload for a replica handoff.

    Returns ``(kv_wire, ssm_slot, length)``: ``kv_wire`` stacks
    ``cache.export_sequence`` over layers (leaves (L, ...) or None for
    attention-free configs), ``ssm_slot`` is the slot's recurrent state
    (leaves (L, ...) or None), ``length`` the slot's token count.  Runs
    per shard inside shard_map; the scheduler-side wrapper stacks the
    per-shard views into the wire blob's (tp, L, ...) layout.  ``col0``
    (traced) windows the page gather for streaming chunk export — see
    ``cache.export_sequence``.
    """
    slot = jnp.asarray(slot, jnp.int32)
    length = state.lengths[slot]
    kv_wire = None
    if state.kv is not None:
        kv_wire = jax.vmap(
            lambda pkv: cache_mod.export_sequence(pkv, slot, n_cols, length,
                                                  tp, col0))(state.kv)
    ssm_slot = None
    if state.ssm is not None:
        ssm_slot = jax.tree_util.tree_map(lambda a: a[:, slot], state.ssm)
    return kv_wire, ssm_slot, length


def import_slot(state: PagedState, slot, kv_wire, ssm_slot, length,
                tp: int, col0=0) -> PagedState:
    """Import an exported sequence into free slot ``slot`` of THIS pool.

    The decode-replica half of the handoff: pages are allocated from this
    pool's own free list (any permutation works) and the compressed planes
    byte-copied in (``cache.import_sequence``); the slot becomes active at
    ``length``.  ``col0`` (traced) makes the import partial — wire columns
    land at ``[col0, col0 + n_cols)`` and the row below ``col0`` is kept
    (the prefix-reuse path maps shared pages there first).  The caller must
    have validated capacity host-side — see ``cache.import_sequence``'s
    docstring for the loud-failure contract.
    """
    slot = jnp.asarray(slot, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    kv = state.kv
    if kv is not None:
        kv = jax.vmap(lambda pkv, w: cache_mod.import_sequence(
            pkv, slot, w, length, tp, col0))(kv, kv_wire)
    ssm = state.ssm
    if ssm is not None:
        ssm = jax.tree_util.tree_map(
            lambda a, v: a.at[:, slot].set(v.astype(a.dtype)), ssm, ssm_slot)
    return PagedState(
        kv=kv, ssm=ssm,
        lengths=state.lengths.at[slot].set(length),
        active=state.active.at[slot].set(True))


def release_slots(state: PagedState, mask: jax.Array,
                  free_mask: Optional[jax.Array] = None) -> PagedState:
    """Evict finished sequences: free their pages, clear their slots.

    With prefix sharing the host passes ``free_mask`` (n_pages,) — only
    pages whose refcount hit zero are freed; shared pages survive in other
    slots' page tables (see the ``PagedKV`` lifecycle note).
    """
    kv = state.kv
    if kv is not None:
        kv = jax.vmap(cache_mod.release_pages,
                      in_axes=(0, None, None))(kv, mask, free_mask)
    return PagedState(
        kv=kv, ssm=state.ssm,
        lengths=jnp.where(mask, 0, state.lengths),
        active=state.active & ~mask)
