"""Multi-process page transport: the ``PageTransport`` seam over real
sockets.  ``framing`` defines the length-prefixed frame layer + control
protocol, ``client`` the sender (``SocketTransport``) and the driver-side
decode proxy (``RemoteDecodeReplica``), ``server`` the decode-host session
handler (``PageHost``).  Process entry points live in
``repro.launch.disagg_host``; the wire payloads themselves are specified in
``repro.serve.transport`` / ``repro.models.cache.export_sequence``."""
from . import framing  # noqa: F401
from .client import RemoteDecodeReplica, SocketTransport  # noqa: F401
from .server import PageHost  # noqa: F401
