"""Sender side of the multi-process page transport.

:class:`SocketTransport` is the :class:`~repro.serve.transport.
PageTransport` that carries compressed page transfer over TCP: one
persistent connection per destination (a decode host running
``repro.launch.disagg_host``), hello/version/config negotiation up front,
then the same bytes ``LoopbackTransport`` would produce — streaming page
chunks and closing :class:`~repro.serve.transport.SequenceBlob` wire blobs
— inside length-prefixed frames (``repro.serve.net.framing``).

Dedup is receiver-owned: the sender fetches the receiver's digest-store
INVENTORY at connect, mirrors it locally (extending it with every inline
digest shipped, re-fetching when an ack reports evictions), and inlines
only digests the receiver lacks — eviction on the receiver simply surfaces
as a re-send (metered as ``pages_resent``), never as corruption.  Every
transfer is priced through
``repro.hw.noc.LinkModel`` exactly as loopback transfers are; only the
data plane (chunks + blobs) is metered, not the control frames.

:class:`RemoteDecodeReplica` is the driver-side proxy with the same
surface the disagg router uses on a local ``DecodeReplica`` (``free_slots``
/ ``idle`` / ``deliver`` / ``step_window`` / ``decode_stats``), each method
one request/response round trip.  Request latency is computed driver-side
(the two processes' clocks are unrelated).
"""

from __future__ import annotations

import socket
import struct
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.hw.noc import LinkModel, MeteredLink

from ..scheduler import RequestResult
from ..telemetry import ENGINE_LANE, Tracer
from ..transport import PageTransport, SequenceBlob, pack_chunk
from . import framing as fr


class SocketTransport(PageTransport):
    """TCP implementation of the page-transport seam (sender half).

    ``connect`` performs the hello handshake: protocol magic/version, blob
    wire version, and the 16-byte config fingerprint must all match the
    decode host's, else the session dies before any page moves.  ``hops``
    positions the link on the modeled chiplet mesh, as in loopback.
    """

    def __init__(self, dedup: bool = True, hops: int = 2,
                 link: Optional[LinkModel] = None, timeout: float = 600.0):
        super().__init__()
        self.dedup = dedup
        self.hops = hops
        self.link = link if link is not None else LinkModel()
        # actual data-plane traffic prices through the meter (link.bytes
        # / link.model_ns); the bare link stays for raw-bytes baselines
        self._meter = MeteredLink(self.link, self.registry)
        self.timeout = timeout
        self._socks: Dict[str, socket.socket] = {}
        # local mirror of each receiver's digest-store inventory: fetched
        # once at connect, extended with every inline digest we ship, and
        # re-fetched only when an ack reports evictions — the receiver's
        # store mutates only through THIS session, so the mirror stays
        # exact without an inventory round trip per chunk
        self._known: Dict[str, Set[bytes]] = {}

    # -- session ----------------------------------------------------------

    def connect(self, dst: str, host: str, port: int,
                fingerprint: bytes, connect_timeout: float = 30.0) -> None:
        if dst in self._socks:
            raise RuntimeError(f"destination {dst!r} already connected")
        sock = socket.create_connection((host, port),
                                        timeout=connect_timeout)
        sock.settimeout(self.timeout)
        try:
            fr.send_frame(sock, fr.MSG_HELLO, fr.pack_hello(fingerprint))
            msg, payload = fr.recv_frame(sock)
            if msg == fr.MSG_ERROR:
                raise RuntimeError(
                    f"decode host {host}:{port} rejected the session: "
                    f"{payload.decode(errors='replace')}")
            if msg != fr.MSG_HELLO_OK:
                raise fr.FrameError(f"expected HELLO_OK, got type {msg}")
            peer_fp = fr.unpack_hello(payload)
            if peer_fp != fingerprint:
                raise RuntimeError(
                    f"config fingerprint mismatch with {host}:{port}: the "
                    "decode host was launched with a different model/codec/"
                    "geometry/seed — token streams would diverge")
        except BaseException:
            sock.close()
            raise
        self._socks[dst] = sock
        self._known[dst] = self.inventory(dst)

    def close(self, dst: Optional[str] = None) -> None:
        """Orderly BYE to one destination (or all)."""
        for name in ([dst] if dst is not None else list(self._socks)):
            sock = self._socks.pop(name)
            try:
                fr.send_frame(sock, fr.MSG_BYE)
                fr.recv_frame(sock)
            except OSError:
                pass
            finally:
                sock.close()

    def _rpc(self, dst: str, msg_type: int, payload: bytes,
             expect: int) -> bytes:
        sock = self._socks[dst]
        fr.send_frame(sock, msg_type, payload)
        msg, reply = fr.recv_frame(sock)
        if msg == fr.MSG_ERROR:
            raise RuntimeError(f"decode host {dst!r}: "
                               f"{reply.decode(errors='replace')}")
        if msg != expect:
            raise fr.FrameError(
                f"expected message type {expect} from {dst!r}, got {msg}")
        return reply

    # -- the PageTransport surface ----------------------------------------

    def inventory(self, dst: str) -> Set[bytes]:
        return fr.unpack_inventory(
            self._rpc(dst, fr.MSG_INVENTORY_REQ, b"", fr.MSG_INVENTORY))

    def stream_pages(self, dst, seq_id, entries) -> None:
        known = self._known[dst] if self.dedup else None
        data, inline, refs = pack_chunk(seq_id, entries, known)
        if self.dedup:
            self._count_resent(dst, inline)
        self._rpc(dst, fr.MSG_PAGE_CHUNK, data, fr.MSG_CHUNK_OK)
        self._known[dst].update(d for d, _ in inline)
        reg = self.registry
        reg.counter("transport.stream_chunk_bytes").inc(len(data))
        reg.counter("transport.wire_bytes").inc(len(data))
        reg.counter("transport.pages_streamed").inc(len(inline))
        reg.counter("transport.pages_inline").inc(len(inline))
        reg.counter("transport.pages_ref").inc(len(refs))
        self._meter.transfer_ns(len(data), self.hops)

    def fetch(self, dst: str,
              digests: Sequence[bytes]) -> Dict[bytes, bytes]:
        """Pull pages back OUT of the host's digest store by content
        digest — the remote tier of the tiered PageCache.  Returns the
        subset held (a missing digest is not an error); transfer is
        priced through the LinkModel like every data-plane move."""
        pages = fr.unpack_pages(self._rpc(
            dst, fr.MSG_FETCH, fr.pack_inventory(set(digests)),
            fr.MSG_FETCH_OK))
        nbytes = sum(len(p) for p in pages.values())
        reg = self.registry
        reg.counter("transport.pages_fetched").inc(len(pages))
        reg.counter("transport.fetch_bytes").inc(nbytes)
        self._meter.transfer_ns(nbytes, self.hops)
        return pages

    def abort_stream(self, dst, seq_id) -> None:
        reply = fr.unpack_json(self._rpc(
            dst, fr.MSG_ABORT, struct.pack("<I", seq_id), fr.MSG_ABORT_OK))
        evicted = int(reply.get("evicted", 0))
        self.registry.counter("transport.store_evicted").inc(evicted)
        if evicted:
            self._known[dst] = self.inventory(dst)   # resync the mirror

    def deliver(self, h, dst: str) -> int:
        """Ship handoff ``h`` (request metadata + closing blob) and have
        the decode host import it; returns the remote slot id.  The
        counterpart of ``DecodeReplica.deliver`` for a remote replica —
        serialization, dedup against the remote inventory, and LinkModel
        metering all happen here, import happens in the host process."""
        blob: SequenceBlob = h.blob
        known = self._known[dst] if self.dedup else None
        data, inline, refs = blob.to_wire(known)
        if self.dedup:
            self._count_resent(dst, inline)
        req = h.req
        meta = {
            "uid": int(req.uid),
            "prompt": [int(t) for t in req.prompt],
            "max_new_tokens": int(req.max_new_tokens),
            "eos_id": None if req.eos_id is None else int(req.eos_id),
            "stop_seqs": (None if req.stop_seqs is None else
                          [[int(t) for t in s] for s in req.stop_seqs]),
            "seq_id": h.seq_id,
        }
        reply = fr.unpack_json(self._rpc(
            dst, fr.MSG_SEQ, fr.pack_seq(meta, data), fr.MSG_SEQ_OK))
        self._known[dst].update(d for d, _ in inline)
        evicted = int(reply.get("evicted", 0))
        if evicted:
            self._known[dst] = self.inventory(dst)   # resync the mirror
        reg = self.registry
        reg.counter("transport.transfers").inc()
        reg.counter("transport.wire_bytes").inc(len(data))
        reg.counter("transport.wire_bytes_nodedup").inc(
            len(data) + len(refs) * blob._payload_size())
        reg.counter("transport.raw_bytes").inc(blob.raw_bytes)
        reg.counter("transport.pages_inline").inc(len(inline))
        reg.counter("transport.pages_ref").inc(len(refs))
        reg.counter("transport.store_evicted").inc(evicted)
        self._meter.transfer_ns(len(data), self.hops)
        reg.counter("link.model_ns_raw").inc(
            self.link.transfer_ns(blob.raw_bytes, self.hops))
        return int(reply["slot"])

    # the in-process serialize/parse surface is loopback-only: a socket
    # transport's recv half lives in the decode host process
    def send(self, blob, dst, seq_id=None) -> bytes:
        raise RuntimeError("SocketTransport ships sequences via deliver(); "
                           "send/recv is the in-process loopback surface")

    def recv(self, data, dst, seq_id=None) -> SequenceBlob:
        raise RuntimeError("SocketTransport ships sequences via deliver(); "
                           "send/recv is the in-process loopback surface")

    # -- decode-replica control rpcs --------------------------------------

    def status(self, dst: str) -> Dict[str, int]:
        return fr.unpack_json(
            self._rpc(dst, fr.MSG_STATUS_REQ, b"", fr.MSG_STATUS))

    def metrics(self, dst: str) -> Dict:
        """Versioned metrics-registry snapshot of the remote replica's
        engine (``repro.serve.telemetry.MetricsRegistry.snapshot``);
        fold per-replica snapshots with ``MetricsRegistry.merge``."""
        return fr.unpack_json(
            self._rpc(dst, fr.MSG_METRICS_REQ, b"", fr.MSG_METRICS))

    def step(self, dst: str) -> List[Dict]:
        return fr.unpack_json(self._rpc(dst, fr.MSG_STEP, b"",
                                        fr.MSG_RESULTS))


class RemoteDecodeReplica:
    """Driver-side proxy for a decode replica living in another OS process
    (behind a :class:`SocketTransport` destination).  Presents the same
    surface the disagg router drives on a local ``DecodeReplica``."""

    def __init__(self, transport: SocketTransport, dst: str,
                 tracer: Optional[Tracer] = None, name: str = "remote"):
        self.transport = transport
        self.dst = dst
        # driver-side span recording: the host process's clock is
        # unrelated, so wire/decode spans for remote replicas are stamped
        # here, around the RPCs
        self.tracer = tracer if tracer is not None else Tracer(False)
        self.name = name
        self._admit_t: Dict[int, float] = {}

    def free_slots(self) -> int:
        return int(self.transport.status(self.dst)["free_slots"])

    def idle(self) -> bool:
        return int(self.transport.status(self.dst)["live"]) == 0

    def decode_stats(self) -> Dict[str, int]:
        st = self.transport.status(self.dst)
        return {k: int(st.get(k, 0))
                for k in ("steps", "dispatches", "shared_hits",
                          "cache_hot_hits", "cache_spilled_pages",
                          "cache_spilled_bytes", "cache_fetched_pages",
                          "cache_fetched_bytes", "cache_reprefill_cols")}

    def deliver(self, h, transport, dst) -> None:
        uid = int(h.req.uid)
        self._admit_t[uid] = h.admit_t
        tr, reg = self.tracer, self.transport.registry
        wb0 = reg.value("transport.wire_bytes")
        t0 = tr.now()
        w0 = time.perf_counter()
        self.transport.deliver(h, self.dst)
        reg.histogram("latency.transfer_s").observe(
            time.perf_counter() - w0)
        tr.request_span(uid, "wire", t0=t0, t1=tr.now(),
                        args={"wire_bytes":
                              reg.value("transport.wire_bytes") - wb0,
                              "raw_bytes": h.blob.raw_bytes,
                              "dst": self.dst})

    def step_window(self) -> List[RequestResult]:
        tr = self.tracer
        t0 = tr.now()
        replies = self.transport.step(self.dst)
        t1 = tr.now()
        tr.emit("decode_rpc", cat="dispatch", pid=self.name,
                tid=ENGINE_LANE, t0=t0, t1=t1,
                args={"dst": self.dst, "finished": len(replies)})
        now = time.perf_counter()
        out = []
        for r in replies:
            # the host's clock is unrelated to ours: latency is measured
            # driver-side, admission -> result arrival
            uid = int(r["uid"])
            admit_t = self._admit_t.pop(uid)
            tokens = [int(t) for t in r["tokens"]]
            tr.request_end(uid, args={"stop_reason": str(r["stop_reason"]),
                                      "tokens": len(tokens)})
            out.append(RequestResult(
                uid=uid, prompt_len=int(r["prompt_len"]),
                tokens=tokens,
                latency_s=now - admit_t,
                stop_reason=str(r["stop_reason"])))
        return out

    def metrics_snapshot(self) -> Dict:
        return self.transport.metrics(self.dst)
