"""Socket framing + control protocol for multi-process page transport.

Everything that crosses a :class:`~repro.serve.net.client.SocketTransport`
connection is a length-prefixed FRAME:

    length  u32   bytes of (type + payload); bounded by ``MAX_FRAME``
    type    u8    one of the ``MSG_*`` constants below
    payload       type-specific bytes

Truncation is LOUD: a socket that closes mid-frame (or a length field
pointing past ``MAX_FRAME``) raises :class:`FrameError` — never a partial
parse.  The data plane rides three payload formats defined elsewhere
(``repro.serve.transport``): streaming page chunks (``pack_chunk``), the
closing :class:`~repro.serve.transport.SequenceBlob` wire bytes, and raw
digest lists; control payloads are small JSON objects.

Control protocol (client = the prefill/driver side, server = the decode
host; every request frame gets exactly one response frame):

    HELLO         → HELLO_OK | ERROR     version + config negotiation: the
                                         hello carries the protocol magic/
                                         version, the blob WIRE version,
                                         and a 16-byte config fingerprint
                                         (``config_fingerprint``); any
                                         mismatch kills the session before
                                         a single page moves.
    INVENTORY_REQ → INVENTORY            the receiver's digest-store
                                         inventory; the sender ships only
                                         digests the receiver lacks.
    PAGE_CHUNK    → CHUNK_OK | ERROR     streamed full pages, landing in
                                         the receiver's digest store and
                                         pinned to their transfer id.
    ABORT         → ABORT_OK             a streamed transfer whose sequence
                                         finished at admission: unpin.
    SEQ           → SEQ_OK | ERROR       request metadata + the closing
                                         blob; the server imports it into
                                         a decode slot (all failures leave
                                         the pool untouched).
    STEP          → RESULTS              run one fused decode window,
                                         return newly finished requests.
    STATUS_REQ    → STATUS               free slots / live slots / store
                                         occupancy + capacity / decode and
                                         PageCache counters (routing +
                                         stats).
    FETCH         → FETCH_OK             pull pages back OUT of the host's
                                         digest store by content digest —
                                         the remote tier of the tiered
                                         PageCache (a replica restores a
                                         spilled prefix column from a peer
                                         instead of re-prefilling).
                                         Request: a digest list
                                         (``pack_inventory``); reply: the
                                         subset held, digest + payload
                                         (``pack_pages``) — a missing
                                         digest is not an error.
    METRICS_REQ   → METRICS              versioned metrics-registry
                                         snapshot of the host replica's
                                         engine (JSON;
                                         ``repro.serve.telemetry.
                                         MetricsRegistry.snapshot`` —
                                         the driver folds per-replica
                                         snapshots into fleet totals
                                         with ``MetricsRegistry.merge``).
    BYE           → BYE_OK               orderly session end.
"""

from __future__ import annotations

import hashlib
import json
import socket
import struct
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from ..transport import VERSION as WIRE_VERSION
from ..transport import _DIGEST_BYTES

PROTO_MAGIC = b"LXNT"
PROTO_VERSION = 1
MAX_FRAME = 1 << 28              # 256 MiB: far above any real blob here
_FINGERPRINT_BYTES = 16

_FRAME_HDR = struct.Struct("<IB")           # length (type+payload), type
_HELLO = struct.Struct("<4sHB16s")          # magic, proto, wire, fingerprint

(MSG_HELLO, MSG_HELLO_OK, MSG_ERROR, MSG_INVENTORY_REQ, MSG_INVENTORY,
 MSG_PAGE_CHUNK, MSG_CHUNK_OK, MSG_ABORT, MSG_ABORT_OK, MSG_SEQ,
 MSG_SEQ_OK, MSG_STEP, MSG_RESULTS, MSG_STATUS_REQ, MSG_STATUS,
 MSG_BYE, MSG_BYE_OK, MSG_FETCH, MSG_FETCH_OK,
 MSG_METRICS_REQ, MSG_METRICS) = range(1, 22)


class FrameError(ConnectionError):
    """A frame could not be read/validated: truncation mid-frame, an
    oversized or negative length, or an unexpected message type."""


def send_frame(sock: socket.socket, msg_type: int,
               payload: bytes = b"") -> None:
    if len(payload) + 1 > MAX_FRAME:
        raise FrameError(f"frame of {len(payload)} bytes exceeds "
                         f"MAX_FRAME={MAX_FRAME}")
    sock.sendall(_FRAME_HDR.pack(len(payload) + 1, msg_type) + payload)


def recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes; loud on EOF mid-read."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            raise FrameError(
                f"connection closed mid-frame ({got}/{n} bytes read)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> Tuple[int, bytes]:
    hdr = recv_exact(sock, _FRAME_HDR.size)
    length, msg_type = _FRAME_HDR.unpack(hdr)
    if length < 1 or length > MAX_FRAME:
        raise FrameError(f"bad frame length {length} (corrupted stream?)")
    payload = recv_exact(sock, length - 1)
    return msg_type, payload


# -- hello / negotiation ----------------------------------------------------


def config_fingerprint(cfg, codec, tp: int, n_slots: int, max_len: int,
                       seed: int, eos_id: Optional[int] = None,
                       stop_seqs=None) -> bytes:
    """16-byte digest of everything both processes must agree on for
    byte-identical streams: the model config, the codec config, the
    parallel/pool geometry, the param seed, the engine-level termination
    defaults (eos / stop sequences — per-request overrides travel in the
    SEQ metadata instead), and the blob wire version.  Dataclass ``repr``
    is deterministic, so both sides compute this from their own
    constructed objects."""
    stops = (tuple(tuple(int(t) for t in s) for s in stop_seqs)
             if stop_seqs else ())
    canon = (f"{cfg!r}|{codec!r}|tp={tp}|slots={n_slots}"
             f"|max_len={max_len}|seed={seed}|eos={eos_id}|stops={stops!r}"
             f"|wire={WIRE_VERSION}")
    return hashlib.sha256(canon.encode()).digest()[:_FINGERPRINT_BYTES]


def pack_hello(fingerprint: bytes) -> bytes:
    return _HELLO.pack(PROTO_MAGIC, PROTO_VERSION, WIRE_VERSION,
                       fingerprint)


def unpack_hello(payload: bytes) -> bytes:
    """Validate a hello payload; returns the peer's config fingerprint.
    Magic / protocol-version / wire-version mismatches raise — the caller
    compares the fingerprint itself (so the error can say which side)."""
    if len(payload) != _HELLO.size:
        raise FrameError(f"hello payload is {len(payload)} bytes, "
                         f"expected {_HELLO.size}")
    magic, proto, wire, fingerprint = _HELLO.unpack(payload)
    if magic != PROTO_MAGIC:
        raise FrameError(f"bad protocol magic {magic!r}")
    if proto != PROTO_VERSION:
        raise FrameError(f"peer speaks protocol v{proto}, "
                         f"this side v{PROTO_VERSION}")
    if wire != WIRE_VERSION:
        raise FrameError(f"peer ships wire-format v{wire}, "
                         f"this side v{WIRE_VERSION}")
    return fingerprint


# -- control payloads -------------------------------------------------------


def pack_inventory(digests: Set[bytes]) -> bytes:
    return struct.pack("<I", len(digests)) + b"".join(sorted(digests))


def unpack_inventory(payload: bytes) -> Set[bytes]:
    (n,) = struct.unpack_from("<I", payload, 0)
    if len(payload) != 4 + n * _DIGEST_BYTES:
        raise FrameError(f"inventory of {n} digests is "
                         f"{len(payload) - 4} bytes")
    return {payload[4 + i * _DIGEST_BYTES:4 + (i + 1) * _DIGEST_BYTES]
            for i in range(n)}


def pack_pages(pages: Dict[bytes, bytes]) -> bytes:
    """FETCH_OK payload: the subset of requested pages the store holds —
    u32 count, then per page (sorted by digest) the digest, a u32 payload
    length and the payload bytes."""
    out = [struct.pack("<I", len(pages))]
    for digest in sorted(pages):
        body = pages[digest]
        out.append(digest + struct.pack("<I", len(body)) + body)
    return b"".join(out)


def unpack_pages(payload: bytes) -> Dict[bytes, bytes]:
    (n,) = struct.unpack_from("<I", payload, 0)
    off = 4
    pages: Dict[bytes, bytes] = {}
    for _ in range(n):
        if off + _DIGEST_BYTES + 4 > len(payload):
            raise FrameError("page list overruns the frame")
        digest = payload[off:off + _DIGEST_BYTES]
        (ln,) = struct.unpack_from("<I", payload, off + _DIGEST_BYTES)
        off += _DIGEST_BYTES + 4
        if off + ln > len(payload):
            raise FrameError(f"page payload of {ln} bytes overruns "
                             "the frame")
        pages[digest] = payload[off:off + ln]
        off += ln
    if off != len(payload):
        raise FrameError(f"{len(payload) - off} trailing bytes after "
                         f"{n} pages")
    return pages


def pack_json(obj: Any) -> bytes:
    return json.dumps(obj).encode()


def unpack_json(payload: bytes) -> Any:
    return json.loads(payload.decode())


def pack_seq(meta: Dict[str, Any], blob_bytes: bytes) -> bytes:
    meta_b = pack_json(meta)
    return struct.pack("<I", len(meta_b)) + meta_b + blob_bytes


def unpack_seq(payload: bytes) -> Tuple[Dict[str, Any], bytes]:
    (n,) = struct.unpack_from("<I", payload, 0)
    if 4 + n > len(payload):
        raise FrameError(f"seq metadata length {n} overruns the frame")
    return unpack_json(payload[4:4 + n]), payload[4 + n:]
