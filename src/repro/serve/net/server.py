"""Receiver side of the multi-process page transport.

:class:`PageHost` runs in the decode-replica process
(``repro.launch.disagg_host --role decode``): it owns the replica, the
content-addressed :class:`~repro.serve.transport.DigestStore` that backs
cross-process page dedup, and the per-transfer pins of in-flight streamed
chunks.  One driver connection at a time; every request frame gets exactly
one response frame (``repro.serve.net.framing`` documents the protocol).

Failure containment: a bad frame, a corrupted chunk, a geometry-mismatched
blob, or an oversubscribed import all answer with an ERROR frame and leave
the replica's pool untouched (imports validate host-side before any device
dispatch; chunk payloads are digest-verified at ingest).  A connection that
dies mid-stream releases its pins and trims the store — staged pages
simply become ordinary LRU content, and the sequence they belonged to was
never imported.
"""

from __future__ import annotations

import socket
import struct
import time
from typing import Optional, Set

import numpy as np

from ..disagg import DecodeReplica, Handoff
from ..scheduler import Request
from ..transport import (DigestStore, SequenceBlob, _page_digest,
                         unpack_chunk)
from . import framing as fr


class PageHost:
    """Session handler wrapping one decode replica for remote drivers."""

    def __init__(self, replica: DecodeReplica, fingerprint: bytes,
                 max_store_pages: int = 4096):
        self.replica = replica
        self.fingerprint = fingerprint
        self.store = DigestStore(max_store_pages)
        # remote tier of the replica's tiered PageCache: a warm prefix
        # column whose payload fell out of the engine-side store restores
        # from the transport store (streamed/deduped pages land here and
        # often outlive the engine's own spill window)
        self.replica.engine.cache.remote_fetch = self._fetch_pages

    def _fetch_pages(self, digests):
        return {d: self.store[d] for d in digests if d in self.store}

    # -- lifecycle ---------------------------------------------------------

    def serve_forever(self, listener: socket.socket,
                      once: bool = False) -> None:
        """Accept driver sessions one at a time; ``once`` returns after the
        first session ends (orderly BYE or dropped connection)."""
        while True:
            conn, _ = listener.accept()
            try:
                self.serve_connection(conn)
            except OSError:
                pass                 # driver died mid-reply: session over
            finally:
                conn.close()
            if once:
                return

    def serve_connection(self, conn: socket.socket) -> None:
        open_seqs: Set[int] = set()
        try:
            if not self._handshake(conn):
                return
            while True:
                try:
                    msg, payload = fr.recv_frame(conn)
                except fr.FrameError:
                    return          # driver gone (possibly mid-stream)
                if msg == fr.MSG_BYE:
                    fr.send_frame(conn, fr.MSG_BYE_OK)
                    return
                try:
                    reply_type, reply = self._handle(msg, payload,
                                                     open_seqs)
                except Exception as e:
                    # the import/parse contract keeps the pool untouched;
                    # report and keep the session alive (struct.error,
                    # KeyError on malformed metadata, ... — any payload
                    # problem answers ERROR, never kills the host)
                    reply_type, reply = (fr.MSG_ERROR,
                                         f"{type(e).__name__}: {e}"
                                         .encode())
                fr.send_frame(conn, reply_type, reply)
        finally:
            # a dead session must not pin its half-streamed transfers
            # forever: release them (the chunks stay in the store as
            # ordinary LRU content) and trim.  Likewise its imported-but-
            # unfinished sequences can never be stepped or collected again
            # — evict them so the NEXT driver session starts with a clean
            # replica (an orderly session finished everything: no-op).
            for seq_id in open_seqs:
                self.store.release(seq_id)
            self.store.trim()
            self.replica.drop_live()

    def _handshake(self, conn: socket.socket) -> bool:
        try:
            msg, payload = fr.recv_frame(conn)
            if msg != fr.MSG_HELLO:
                raise fr.FrameError(f"expected HELLO, got type {msg}")
            peer_fp = fr.unpack_hello(payload)
        except fr.FrameError as e:
            try:
                fr.send_frame(conn, fr.MSG_ERROR, str(e).encode())
            except OSError:
                pass
            return False
        if peer_fp != self.fingerprint:
            fr.send_frame(conn, fr.MSG_ERROR,
                          b"config fingerprint mismatch: this decode host "
                          b"was launched with a different model/codec/"
                          b"geometry/seed")
            return False
        fr.send_frame(conn, fr.MSG_HELLO_OK,
                      fr.pack_hello(self.fingerprint))
        return True

    # -- request handling --------------------------------------------------

    def _handle(self, msg: int, payload: bytes, open_seqs: Set[int]):
        if msg == fr.MSG_INVENTORY_REQ:
            return fr.MSG_INVENTORY, fr.pack_inventory(self.store.digests())
        if msg == fr.MSG_PAGE_CHUNK:
            return fr.MSG_CHUNK_OK, self._ingest_chunk(payload, open_seqs)
        if msg == fr.MSG_ABORT:
            (seq_id,) = struct.unpack("<I", payload)
            self.store.release(seq_id)
            open_seqs.discard(seq_id)
            return fr.MSG_ABORT_OK, fr.pack_json(
                {"evicted": self.store.trim()})
        if msg == fr.MSG_SEQ:
            return fr.MSG_SEQ_OK, self._import_seq(payload, open_seqs)
        if msg == fr.MSG_STEP:
            results = self.replica.step_window()
            return fr.MSG_RESULTS, fr.pack_json(
                [{"uid": r.uid, "prompt_len": r.prompt_len,
                  "tokens": r.tokens, "stop_reason": r.stop_reason}
                 for r in results])
        if msg == fr.MSG_STATUS_REQ:
            return fr.MSG_STATUS, fr.pack_json(dict(
                free_slots=self.replica.free_slots(),
                live=len(self.replica.ls.live_slots()),
                store_pages=len(self.store),
                store_capacity=self.store.max_pages,
                **self.replica.decode_stats()))
        if msg == fr.MSG_METRICS_REQ:
            return fr.MSG_METRICS, fr.pack_json(
                self.replica.metrics_snapshot())
        if msg == fr.MSG_FETCH:
            digests = fr.unpack_inventory(payload)
            return fr.MSG_FETCH_OK, fr.pack_pages(self._fetch_pages(digests))
        raise ValueError(f"unknown message type {msg}")

    def _ingest_chunk(self, payload: bytes, open_seqs: Set[int]) -> bytes:
        seq_id, entries = unpack_chunk(payload)
        # validate everything BEFORE mutating the store: a corrupted chunk
        # must not leave half its pages behind
        for t, l, c, tag, digest, body in entries:
            if tag == 1 and digest not in self.store:
                raise ValueError(
                    f"chunk references unknown digest {digest.hex()} "
                    f"(shard {t}, layer {l}, col {c})")
            if tag == 0 and _page_digest(body) != digest:
                raise ValueError(
                    f"chunk payload does not hash to its digest "
                    f"{digest.hex()} (shard {t}, layer {l}, col {c})")
        # track the transfer BEFORE pinning so session teardown always
        # releases, even if an insert below fails unexpectedly
        open_seqs.add(seq_id)
        for _, _, _, tag, digest, body in entries:
            if tag == 0:
                self.store[digest] = body   # digest-verified at ingest
            self.store.pin(seq_id, digest)
        return fr.pack_json({"pinned": len(entries)})

    def _import_seq(self, payload: bytes, open_seqs: Set[int]) -> bytes:
        meta, blob_bytes = fr.unpack_seq(payload)
        blob = SequenceBlob.from_wire(blob_bytes, self.store)
        req = Request(
            uid=int(meta["uid"]),
            prompt=np.asarray(meta["prompt"], np.int32),
            max_new_tokens=int(meta["max_new_tokens"]),
            eos_id=(None if meta.get("eos_id") is None
                    else int(meta["eos_id"])),
            stop_seqs=(None if meta.get("stop_seqs") is None else
                       tuple(tuple(int(t) for t in s)
                             for s in meta["stop_seqs"])))
        # host-clock admit time: latency is recomputed driver-side
        slot = self.replica.import_handoff(
            Handoff(req=req, blob=blob, admit_t=time.perf_counter()))
        seq_id = meta.get("seq_id")
        if seq_id is not None:
            self.store.release(int(seq_id))
            open_seqs.discard(int(seq_id))
        return fr.pack_json({"slot": slot, "evicted": self.store.trim()})
