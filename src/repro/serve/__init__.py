"""Serving: fixed-batch prefill+decode, continuous batching over the paged
LEXI-compressed cache (``engine`` device code, ``scheduler`` loop), and
disaggregated prefill→decode replicas over compressed page transfer
(``disagg`` routing, ``transport`` wire format + digest stores,
``pagecache`` tiered content-addressed page retention, ``net`` socket
transport between OS processes, ``telemetry`` request-lifecycle tracing
+ the unified metrics registry) — see docs/ARCHITECTURE.md for the
end-to-end walkthrough."""
from . import engine  # noqa: F401
from .scheduler import (Request, RequestResult, RequestScheduler,  # noqa: F401
                        ServeEngine, ServeStats)
from .pagecache import PageCache  # noqa: F401
from .disagg import (DecodeReplica, DisaggEngine, DisaggStats,  # noqa: F401
                     PrefillReplica)
from .transport import (DigestStore, LoopbackTransport,  # noqa: F401
                        PageTransport, SequenceBlob, TransportStats)
from .net import (PageHost, RemoteDecodeReplica,  # noqa: F401
                  SocketTransport)
from .telemetry import (MetricsRegistry, Tracer,  # noqa: F401
                        summarize_latencies)
