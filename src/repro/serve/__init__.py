"""Serving: fixed-batch prefill+decode and continuous batching over the
paged LEXI-compressed cache (``engine`` device code, ``scheduler`` loop)."""
from . import engine  # noqa: F401
from .scheduler import (Request, RequestResult, RequestScheduler,  # noqa: F401
                        ServeEngine, ServeStats)
