"""Batched serving: prefill + decode with LEXI-compressed caches/weights."""
from . import engine  # noqa: F401
