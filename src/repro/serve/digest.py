"""Shared SHA-256 content addressing for pages and prefixes.

One module owns both hash conventions the serving stack keys on, so the
transport's digest store, the scheduler's prefix index, and the tiered
``PageCache`` all speak the same keys:

* **Page digests** — ``sha256(payload)[:DIGEST_BYTES]`` of one immutable
  page payload (the LEXI-FW compressed bytes, or the raw bf16 page when
  the codec is off).  Pages are content-deterministic — the same prefix
  always compresses to the same bytes — so a truncated SHA-256 is a
  collision-safe identity for dedup, spill, and remote fetch.
* **Prefix keys** — chained full-width SHA-256 over the token prompt, one
  32-byte key per FULL page column (``blk_tokens = cache_block * tp``
  tokens).  Chaining makes key ``c`` a digest of the whole prefix
  ``prompt[: (c+1) * blk_tokens]`` at O(len) total cost, and two prompts
  share key ``c`` iff they share that prefix exactly.
"""

from __future__ import annotations

import hashlib
from typing import List

import numpy as np

DIGEST_BYTES = 12


def page_digest(payload: bytes) -> bytes:
    """Truncated content digest of one immutable page payload."""
    return hashlib.sha256(payload).digest()[:DIGEST_BYTES]


def chain_keys(prompt: np.ndarray, n_cols: int,
               blk_tokens: int) -> List[bytes]:
    """Chained prefix keys for the first ``n_cols`` full page columns of
    ``prompt``; ``keys[c]`` identifies ``prompt[: (c+1) * blk_tokens]``."""
    keys: List[bytes] = []
    h = b""
    for c in range(n_cols):
        blk = np.ascontiguousarray(
            prompt[c * blk_tokens:(c + 1) * blk_tokens],
            dtype=np.int32).tobytes()
        h = hashlib.sha256(h + blk).digest()
        keys.append(h)
    return keys
