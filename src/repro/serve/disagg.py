"""Disaggregated prefill serving: prefill replicas feeding decode replicas
through compressed page transfer.

The monolithic ``ServeEngine`` interleaves admission (prefill-heavy, bursty)
and decode (latency-sensitive, steady) on one set of slots.  Disaggregation
splits them onto separate replicas — the standard production topology — and
this module keeps the split EXACT: a decode replica's token streams are
byte-identical to the monolithic engine's, because

  * the prefill replica runs the *same* admission machinery
    (``ServeEngine._admit_phase``: batched bucketed prefill, prefix-cache
    hits, fused tail replay — exact numerics at every position), and
  * the handoff copies the slot's cache state byte-for-byte: LEXI-FW
    compressed full pages travel as stored (no decompress/recompress round
    trip), plus the partial-tail ring, the per-slot length, and the
    SSM-state slot for hybrids (``repro.serve.transport.SequenceBlob``),
  * slots are independent in the paged decode path, so the decode replica
    stepping an imported slot computes exactly what the monolithic engine
    would have.

Dataflow (see docs/ARCHITECTURE.md for the full picture):

    requests ──► RequestRouter ──► PrefillReplica[0..N) ──┐ admit+replay;
                      │                                   │ full pages can
                      │        page chunks + SequenceBlob │ STREAM out as
                      │              bytes ◄──────────────┘ they fill
                      │                 │  PageTransport (meters wire vs
                      │                 ▼   raw bytes through hw.noc's
                      └──────────► DecodeReplica[0..M)      LinkModel)
                                        │ import_slot into its OWN pool,
                 results ◄──────────────┘ fused decode windows

The router owns per-replica slot accounting: requests go to the
least-backlogged prefill replica; handoffs land on the decode replica with
the most free slots (a STREAMED sequence is routed when its first chunk
ships and sticks to that destination).  A handoff waits whenever its
destination has no free slot; unrouted handoffs may overtake it to another
replica.

The transport seam is process-agnostic: ``LoopbackTransport`` keeps both
replica kinds in one process, ``repro.serve.net.client.SocketTransport``
(with ``decode_addrs=``) drives decode replicas living in OTHER OS
processes (``repro.launch.disagg_host``) over TCP — same wire bytes, same
streams.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.core import packing
from repro.kernels import ops as kernel_ops
from repro.models import cache as cache_mod
from repro.models.ssm import SSMState
from .scheduler import (Request, RequestResult, ServeEngine, _LoopState)
from .telemetry import (MetricsRegistry, Tracer, sum_counters,
                        summarize_latencies)
from .transport import (LoopbackTransport, PageTransport, SequenceBlob,
                        TransportStats, page_payload)


@dataclasses.dataclass
class Handoff:
    """One admitted sequence in flight between replicas (host envelope:
    the request routing metadata stays host-side; only the cache state in
    ``blob`` crosses the modeled link).  ``dst``/``seq_id`` are set when
    the sequence's full pages already STREAMED to a destination during
    admission — the router must then deliver the tail to that same
    destination (the chunks live in its digest store)."""
    req: Request
    blob: SequenceBlob
    admit_t: float
    dst: Optional[str] = None
    seq_id: Optional[int] = None


@dataclasses.dataclass
class DisaggStats:
    """Aggregate stats of a disaggregated serving run."""
    n_requests: int
    n_tokens: int
    decode_steps: int
    n_dispatches: int              # decode dispatches, all decode replicas
    n_admit_dispatches: int        # batched prefills, all prefill replicas
    n_replay_dispatches: int
    n_prefill_replicas: int
    n_decode_replicas: int
    n_transfers: int               # sequences shipped prefill -> decode
    wire_bytes: int                # bytes that crossed the modeled link
    wire_bytes_nodedup: int        # same transfers without page dedup
    wire_raw_bytes: int            # bf16-dense bytes of the same payloads
    dedup_page_refs: int           # pages that shipped as 13B references
    pages_streamed: int            # pages that crossed DURING admission
    stream_chunk_bytes: int        # bytes of the streaming chunk frames
    pages_resent: int              # inline re-sends after receiver eviction
    store_evicted: int             # receiver-store pages evicted (LRU cap)
    decode_prefix_hits: int        # page columns reused across imports
    cache_hot_hits: int            # retained zero-ref columns re-acquired
    cache_spilled_pages: int       # payloads spilled to decode warm stores
    cache_spilled_bytes: int
    cache_fetched_pages: int       # payloads restored from warm/remote
    cache_fetched_bytes: int
    cache_reprefill_cols: int      # warm columns lost on every tier
    link_model_ms: float           # LinkModel latency of the wire bytes
    link_model_ms_raw: float       # ... of the bf16-dense baseline
    wall_s: float
    requests_per_s: float
    tokens_per_s: float
    mean_latency_s: float
    latency_p50_s: float
    latency_p95_s: float
    decode_backend: str
    ttft_mean_s: float = 0.0       # submit -> first token (prefill-side)
    ttft_p50_s: float = 0.0
    ttft_p95_s: float = 0.0
    transfer_mean_s: float = 0.0   # host wall time of one deliver()

    @property
    def link_reduction(self) -> float:
        """Fractional link-byte reduction vs shipping the cache bf16-dense
        — the serving-stack analogue of the paper's Table 3 column."""
        return 1.0 - self.wire_bytes / max(self.wire_raw_bytes, 1)


def _blob_geometry(eng: ServeEngine):
    """(blk, w, k, esc_cap, npad) of one page in ``eng``'s pool."""
    codec = eng.run_cfg.codec
    blk = codec.cache_block
    w = cache_mod.kv_width(eng.cfg) if eng.cfg.n_heads > 0 else 0
    n = blk * w
    if n == 0:
        return blk, 0, codec.k, 0, 0
    return blk, w, codec.k, codec.esc_capacity(n), packing.pad_to_lanes(n)


class PrefillReplica:
    """One admission-only replica: runs the engine's batched/bucketed
    admission (+ tail replay) on its own pool, then exports every admitted
    sequence instead of decoding it.  Requests that finish AT admission
    (budget of 1, EOS or stop on the first token) complete here and never
    transfer.

    **Streaming prefill export** (``streaming=True``): the replica hooks
    the engine's ``admit_progress_cb`` and ships full page columns through
    the transport AS THEY FILL — after the batched trunk insert and after
    every fused replay dispatch — so the link works while the prompt tail
    is still replaying.  The destination is picked at first-chunk time
    (``pick_dst``) and pinned into the handoff; the closing blob then
    references the streamed pages by digest (13 B each) instead of
    re-shipping them.  A streamed sequence that finishes at admission
    aborts its stream (the receiver unpins and may evict the chunks).
    """

    def __init__(self, engine: ServeEngine,
                 transport: Optional[PageTransport] = None,
                 pick_dst: Optional[Callable[[], str]] = None,
                 streaming: bool = False):
        self.engine = engine
        self.ls: _LoopState = engine._new_loop()
        self.transport = transport
        self.pick_dst = pick_dst
        self.streaming = bool(streaming and engine.cfg.n_heads > 0)
        self._streams: Dict[int, dict] = {}   # slot -> seq_id/dst/sent cols
        if self.streaming:
            if transport is None or pick_dst is None:
                raise ValueError("streaming prefill export needs a "
                                 "transport and a destination picker")
            engine.admit_progress_cb = self._stream_progress

    @property
    def backlog(self) -> int:
        return len(self.engine.scheduler) + len(self.ls.live_slots())

    def submit(self, req: Request) -> None:
        self.engine.scheduler.submit(req)   # validates length/budget

    def idle(self) -> bool:
        return not len(self.engine.scheduler) and not self.ls.live_slots()

    def _stream_progress(self, ls: _LoopState) -> None:
        """Mid-admission hook: export and ship every freshly completed
        page column of every live slot (one windowed gather per slot,
        window sizes rounded to powers of two so the export jit cache
        stays at O(log maxp) entries)."""
        eng = self.engine
        blk = eng.run_cfg.codec.cache_block
        codec_on = bool(eng.run_cfg.codec.cache)
        for s in ls.live_slots():
            if ls.done[s]:
                continue               # finishing at admission: no transfer
            length = ls.slot_len[s]
            valid = [max((length - 1 - t) // eng.tp + 1, 0) // blk
                     for t in range(eng.tp)]
            st = self._streams.get(s)
            sent = st["sent"] if st is not None else [0] * eng.tp
            if all(v <= s0 for v, s0 in zip(valid, sent)):
                continue
            col0 = min(sent)
            span = max(valid) - col0
            n = 1
            while n < span:
                n *= 2
            n = min(n, eng._maxp - col0)
            kvw, _, _ = eng._export_for(n)(
                eng.state, jnp.asarray(s, jnp.int32),
                jnp.asarray(col0, jnp.int32))
            fields = (("signman", "planes", "dict_syms", "esc_pos",
                       "esc_raw") if codec_on else ("raw_pages",))
            kv = {f: np.asarray(getattr(kvw, f)) for f in fields}
            entries = []
            for t in range(eng.tp):
                for l in range(eng.cfg.n_layers):
                    for c in range(max(sent[t], col0), valid[t]):
                        entries.append(
                            (t, l, c,
                             page_payload(kv, codec_on, t, l, c - col0)))
            if not entries:
                continue
            if st is None:
                st = {"seq_id": self.transport.new_stream(),
                      "dst": self.pick_dst(), "sent": sent}
                self._streams[s] = st
            tr, reg = eng.tracer, self.transport.registry
            wb0 = reg.value("transport.wire_bytes")
            t0 = tr.now()
            self.transport.stream_pages(st["dst"], st["seq_id"], entries)
            if tr.enabled:
                tr.request_span(
                    ls.slot_req[s].uid, "wire_chunk", t0=t0, t1=tr.now(),
                    args={"wire_bytes":
                          reg.value("transport.wire_bytes") - wb0,
                          "pages": len(entries), "dst": st["dst"]})
            st["sent"] = [max(v, s0) for v, s0 in zip(valid, sent)]

    def admit_step(self) -> Tuple[List[RequestResult], List[Handoff]]:
        """One admission round: admit into every free slot, replay prompt
        tails (streaming full pages out as they fill, when enabled), then
        export + release every live slot as a handoff."""
        eng, ls = self.engine, self.ls
        eng._admit_phase(ls)
        eng._track_peak(ls)
        finished = eng._finish_ready(ls)    # done at admission: no transfer
        for s in list(self._streams):       # their streams never complete
            if ls.slot_req[s] is None:
                st = self._streams.pop(s)
                self.transport.abort_stream(st["dst"], st["seq_id"])
        handoffs: List[Handoff] = []
        exported = []
        tr = eng.tracer
        for s in list(ls.live_slots()):
            req = ls.slot_req[s]
            st = self._streams.pop(s, None)
            # TTFT closes HERE for transferred requests: the first token
            # was produced at admission/replay on this replica, and the
            # decode replica's clocks never saw the submit (driver-side
            # clock throughout, so remote decode composes too)
            ft = ls.first_tok_t.pop(req.uid, None)
            sub = eng.scheduler.submit_t.pop(req.uid, None)
            if ft is not None:
                ls.ttft_s[req.uid] = ft - (sub if sub is not None
                                           else ls.admit_t[req.uid])
            t0 = tr.now()
            blob = self._export_blob(s)
            if tr.enabled:
                tr.request_span(req.uid, "export", t0=t0, t1=tr.now(),
                                args={"raw_bytes": blob.raw_bytes,
                                      "length": blob.length,
                                      "n_cols": blob.n_cols})
            handoffs.append(Handoff(
                req=req, blob=blob,
                admit_t=ls.admit_t[req.uid],
                dst=st["dst"] if st is not None else None,
                seq_id=st["seq_id"] if st is not None else None))
            ls.slot_req[s] = None
            ls.slot_len[s] = 0
            exported.append(s)
        if exported:
            eng._free_slots(exported)       # one release dispatch
        return finished, handoffs

    def _export_blob(self, s: int) -> SequenceBlob:
        eng, ls = self.engine, self.ls
        req = ls.slot_req[s]
        length = ls.slot_len[s]
        blk, w, k, esc_cap, npad = _blob_geometry(eng)
        n_cols = (cache_mod.export_n_cols(length, blk, eng.tp)
                  if eng.cfg.n_heads > 0 else 0)
        kvw, ssm, dev_len = eng._export_for(n_cols)(
            eng.state, jnp.asarray(s, jnp.int32), jnp.asarray(0, jnp.int32))
        assert int(np.asarray(dev_len)) == length, (s, length)
        codec_on = bool(eng.run_cfg.codec.cache)
        kv = None
        if kvw is not None:
            if codec_on:
                kv = {f: np.asarray(getattr(kvw, f)) for f in
                      ("signman", "planes", "dict_syms", "esc_pos",
                       "esc_raw")}
            else:
                kv = {"raw_pages": np.asarray(kvw.raw_pages)}
            kv["ring"] = np.asarray(kvw.ring)
        ssm_t = None
        if ssm is not None:
            ssm_t = (np.asarray(ssm.h), np.asarray(ssm.conv_x),
                     np.asarray(ssm.conv_bc))
        return SequenceBlob(
            codec_on=codec_on, tp=eng.tp, n_layers=eng.cfg.n_layers,
            n_cols=n_cols, blk=blk, w=w, k=k, esc_cap=esc_cap, npad=npad,
            length=length, cur_token=int(ls.cur[s, 0]),
            emitted=list(ls.emitted[req.uid]), kv=kv, ssm=ssm_t)


class DecodeReplica:
    """One decode-only replica: sequences arrive as wire blobs, scatter
    into its own pool (fresh pages from ITS free list), and step through
    the engine's fused decode windows until termination.

    When the engine allows prefix sharing, imported sequences register
    their full page columns in the replica's tiered PageCache, so a LATER
    import with the same prompt prefix maps the resident pages instead of
    allocating duplicates — and columns released since stay retained
    (hot) or restorable (warm / remote-fetch by digest), so the reuse
    survives gaps in residency.  Cross-replica prefix reuse composes with
    the transport's wire-level dedup (the repeated pages already crossed
    as 13 B references; this keeps them from occupying pool pages
    twice)."""

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self.ls: _LoopState = engine._new_loop()

    def free_slots(self) -> int:
        return len(self.engine._free_slot_ids(self.ls))

    def idle(self) -> bool:
        return not self.ls.live_slots()

    def decode_stats(self) -> Dict[str, int]:
        c = self.engine.cache
        return {"steps": self.ls.steps, "dispatches": self.ls.dispatches,
                "shared_hits": self.ls.shared_hits,
                "cache_hot_hits": c.hot_hits,
                "cache_spilled_pages": c.spilled_pages,
                "cache_spilled_bytes": c.spilled_bytes,
                "cache_fetched_pages": c.fetched_pages,
                "cache_fetched_bytes": c.fetched_bytes,
                "cache_reprefill_cols": c.reprefill_cols}

    def drop_live(self) -> int:
        """Evict every live slot and forget its request: a remote driver
        session that died mid-run can never step or collect its sequences,
        so a persistent host drops them at session teardown instead of
        poisoning the next session with stuck slots.  Returns the count."""
        ls = self.ls
        live = ls.live_slots()
        for s in live:
            req = ls.slot_req[s]
            ls.slot_req[s] = None
            ls.done[s], ls.reason[s] = False, ""
            ls.emitted.pop(req.uid, None)
            ls.admit_t.pop(req.uid, None)
            ls.slot_len[s] = 0
        if live:
            self.engine._free_slots(live)
        return len(live)

    def metrics_snapshot(self) -> Dict:
        """Versioned registry snapshot of this replica's engine — the
        local counterpart of the socket METRICS RPC
        (``repro.serve.net.server.PageHost`` answers with exactly this
        on its own replica)."""
        return self.engine.sync_metrics(self.ls).snapshot()

    def deliver(self, h: Handoff, transport: PageTransport,
                dst: str) -> None:
        """Carry ``h`` across the transport and import it: serialize (and
        meter) the blob, reconstruct it on the receiving side, scatter it
        into a slot.  The remote counterpart lives in
        ``repro.serve.net.client.RemoteDecodeReplica.deliver``."""
        tr, reg = self.engine.tracer, transport.registry
        wb0 = reg.value("transport.wire_bytes")
        t0 = tr.now()
        w0 = time.perf_counter()
        data = transport.send(h.blob, dst, seq_id=h.seq_id)
        blob = transport.recv(data, dst, seq_id=h.seq_id)
        reg.histogram("latency.transfer_s").observe(
            time.perf_counter() - w0)
        if tr.enabled:
            tr.request_span(
                h.req.uid, "wire", t0=t0, t1=tr.now(),
                args={"wire_bytes": reg.value("transport.wire_bytes") - wb0,
                      "raw_bytes": h.blob.raw_bytes, "dst": dst})
        t0 = tr.now()
        self.import_handoff(dataclasses.replace(h, blob=blob))
        if tr.enabled:
            tr.request_span(h.req.uid, "import", t0=t0, t1=tr.now(),
                            args={"n_cols": blob.n_cols,
                                  "length": blob.length})

    def import_handoff(self, h: Handoff) -> int:
        """Scatter a transferred sequence into a free slot; returns the
        slot id.  All validation happens BEFORE any device dispatch, so a
        rejected import leaves the pool untouched:

          * geometry (tp / layers / page shape / codec flag) must match,
          * a free slot must exist,
          * the sequence must fit a page-table row (``n_cols <= maxp``),
          * every shard/layer pool must hold enough FREE pages for the
            columns not covered by a prefix-index hit — in-graph
            allocation cannot fail loudly, so oversubscription is rejected
            here (device truth read at this admission boundary only).
        """
        eng, ls, blob = self.engine, self.ls, h.blob
        blk, w, k, esc_cap, npad = _blob_geometry(eng)
        want = (eng.tp, eng.cfg.n_layers, blk, w, k, esc_cap, npad,
                bool(eng.run_cfg.codec.cache), eng.cfg.ssm is not None)
        got = (blob.tp, blob.n_layers, blob.blk, blob.w, blob.k,
               blob.esc_cap, blob.npad, blob.codec_on, blob.ssm is not None)
        if want != got:
            raise ValueError(f"wire blob geometry {got} does not match "
                             f"this decode replica {want}")
        free = eng._free_slot_ids(ls)
        if not free:
            raise RuntimeError("no free decode slot (the router must hold "
                               "handoffs until a slot frees)")
        s = free[0]
        req = h.req
        kvw = None
        m = 0
        mkeys: List[bytes] = []
        if eng.state.kv is not None:
            if blob.n_cols > eng._maxp:
                raise ValueError(
                    f"import needs {blob.n_cols} page columns > "
                    f"max {eng._maxp} per slot (decode replica max_len "
                    f"{eng.max_len} too small for length {blob.length})")
            if eng.prefix_sharing and len(req.prompt) >= blob.length:
                # cross-replica prefix reuse: the longest run of this
                # prompt's full page columns already resident in the index
                keys = eng._prefix_keys(np.asarray(req.prompt),
                                        blob.length // eng.blk_tokens)
                while m < len(keys) and keys[m] in eng.cache.index:
                    m += 1
                mkeys = keys[:m]
            ids = np.zeros((eng.tp, eng._maxp), np.int32)
            for c, key in enumerate(mkeys):
                # acquire (pin) the matched columns BEFORE the pressure
                # valve runs — a retained zero-ref column this import is
                # about to map must not be evicted to make room for it
                ids[:, c] = eng.cache.acquire(key)
                eng._slot_keys[s].append(key)
            eng._ensure_free_pages(max(blob.valid_cols(t) - m
                                       for t in range(eng.tp)))
            used = np.asarray(eng.state.kv.page_used)     # (tp, L, P)
            free_pages = used.shape[-1] - used.sum(axis=-1)
            need = np.array([max(blob.valid_cols(t) - m, 0)
                             for t in range(eng.tp)])[:, None]
            if (free_pages < need).any():
                for key in mkeys:       # undo the pins: nothing dispatched
                    eng.cache.release(key)
                eng._slot_keys[s] = []
                raise RuntimeError(
                    "decode-replica page pool oversubscribed: import needs "
                    f"{need.max()} pages but a shard/layer has only "
                    f"{int(free_pages.min())} free")
            kv = blob.kv

            def cut(a):
                return jnp.asarray(np.ascontiguousarray(a[:, :, m:]))

            if blob.codec_on:
                kvw = cache_mod.PageWire(
                    signman=cut(kv["signman"]), planes=cut(kv["planes"]),
                    dict_syms=cut(kv["dict_syms"]),
                    esc_pos=cut(kv["esc_pos"]), esc_raw=cut(kv["esc_raw"]),
                    raw_pages=None, ring=jnp.asarray(kv["ring"]))
            else:
                kvw = cache_mod.PageWire(
                    signman=None, planes=None, dict_syms=None,
                    esc_pos=None, esc_raw=None,
                    raw_pages=cut(kv["raw_pages"]),
                    ring=jnp.asarray(kv["ring"]))
        ssm = None
        if eng.state.ssm is not None:
            h_, cx, cbc = blob.ssm
            ssm = SSMState(h=jnp.asarray(h_), conv_x=jnp.asarray(cx),
                           conv_bc=jnp.asarray(cbc))
        if m:                       # map resident shared columns first
            eng.state = eng._map_shared_for()(
                eng.state, jnp.asarray(s, jnp.int32), jnp.asarray(ids),
                jnp.asarray(m, jnp.int32),
                jnp.asarray(m * eng.blk_tokens, jnp.int32))
            ls.shared_hits += m
        eng.state = eng._import_for(blob.n_cols - m)(
            eng.state, jnp.asarray(s, jnp.int32), kvw, ssm,
            jnp.asarray(blob.length, jnp.int32), jnp.asarray(m, jnp.int32))
        ls.slot_req[s] = req
        eng._slot_busy[s] = True
        ls.slot_len[s] = blob.length
        ls.emitted[req.uid] = list(blob.emitted)
        ls.cur[s] = blob.cur_token
        ls.admit_t[req.uid] = h.admit_t
        eng._register_prefixes([(s, np.asarray(req.prompt), blob.length)])
        eng._track_peak(ls)
        return s

    def step_window(self) -> List[RequestResult]:
        eng, ls = self.engine, self.ls
        eng._decode_window(ls)
        return eng._finish_ready(ls)


class DisaggEngine:
    """N prefill replicas feeding M decode replicas over a
    :class:`PageTransport` — the routing layer of the disaggregated stack.

    Construction mirrors ``ServeEngine`` (one set of model params is shared
    by every replica); ``n_slots`` is PER REPLICA.  Prefill replicas run
    without in-engine prefix sharing (the export-and-free flow never has
    overlapping residency), so cross-request page reuse happens on the wire
    (transport dedup) and across imports in the decode replicas' prefix
    indexes.  Token streams are byte-identical to the monolithic engine for
    the same requests (tests/test_disagg.py), and ``DisaggStats`` adds the
    link accounting: wire vs bf16-dense bytes per transfer, dedup hits,
    streamed chunk bytes, and the ``hw.noc.LinkModel`` latency of both —
    the serving measurement of the paper's headline claim that compressed
    exponent streams cut inter-chiplet traffic.

    ``streaming=True`` turns on streaming prefill export (full pages cross
    the link as admission fills them — see :class:`PrefillReplica`).
    ``decode_addrs`` replaces the in-process decode replicas with REMOTE
    ones reached over the given ``host:port`` list; ``transport`` must then
    be a connected-capable ``repro.serve.net.client.SocketTransport`` and
    each address must run ``repro.launch.disagg_host`` with a matching
    model/config fingerprint.
    """

    def __init__(self, cfg: ModelConfig, run: RunConfig, *, tp: int = 1,
                 n_prefill: int = 1, n_decode: int = 1, n_slots: int = 4,
                 max_len: int = 256, params=None, seed: int = 0,
                 eos_id: Optional[int] = None,
                 stop_seqs: Optional[Sequence[Sequence[int]]] = None,
                 max_fuse_steps: int = 32,
                 transport: Optional[PageTransport] = None,
                 streaming: bool = False,
                 decode_addrs: Optional[Sequence[str]] = None,
                 store_pages: int = 4096, compress_weights: bool = False,
                 tracer: Optional[Tracer] = None):
        if n_prefill < 1 or (n_decode < 1 and decode_addrs is None):
            raise ValueError("need at least one replica of each kind")
        self.cfg, self.run_cfg = cfg, run
        # one tracer is shared by every replica: the root span opened at
        # a prefill submit closes when the decode side finishes the uid
        self.tracer = tracer if tracer is not None else Tracer(False)
        self.transport = transport if transport is not None \
            else LoopbackTransport(max_store_pages=store_pages)
        # compress_weights reaches BOTH replica kinds via mk; packing is
        # idempotent, so the shared param tree is packed once by the first
        # prefill replica and passed through by the rest
        mk = dict(tp=tp, n_slots=n_slots, max_len=max_len, seed=seed,
                  eos_id=eos_id, stop_seqs=stop_seqs,
                  max_fuse_steps=max_fuse_steps,
                  compress_weights=compress_weights)
        self.decodes: List = []
        self._names: List[str] = []
        if decode_addrs is not None:
            from .net.client import RemoteDecodeReplica, SocketTransport
            from .net.framing import config_fingerprint
            if not isinstance(self.transport, SocketTransport):
                raise ValueError("decode_addrs needs a SocketTransport")
            fp = config_fingerprint(cfg, run.codec, tp, n_slots, max_len,
                                    seed, eos_id=eos_id,
                                    stop_seqs=stop_seqs)
            for i, addr in enumerate(decode_addrs):
                host, _, port = str(addr).rpartition(":")
                dst = f"decode{i}"
                self.transport.connect(dst, host or "127.0.0.1", int(port),
                                       fp)
                self.decodes.append(RemoteDecodeReplica(
                    self.transport, dst, tracer=self.tracer, name=dst))
                self._names.append(dst)
        self.prefills: List[PrefillReplica] = []

        def pick_dst() -> str:
            i = max(range(len(self.decodes)),
                    key=lambda j: self.decodes[j].free_slots())
            return self._names[i]

        for i in range(n_prefill):
            # In-engine prefix sharing needs overlapping slot residency,
            # and a prefill replica exports + frees every slot at the end
            # of each admission round — its prefix index could never hit.
            # Cross-request prefix reuse lives in the TRANSPORT (content-
            # addressed page dedup on the wire) and in the decode replicas'
            # prefix indexes (shared pages across imports) instead.
            eng = ServeEngine(cfg, run, params=params,
                              prefix_sharing=False, tracer=self.tracer,
                              name=f"prefill{i}", **mk)
            params = eng.params          # share one param set everywhere
            self.prefills.append(PrefillReplica(
                eng, transport=self.transport, pick_dst=pick_dst,
                streaming=streaming))
        if decode_addrs is None:
            for i in range(n_decode):
                # decode replicas DO have overlapping residency: imported
                # sequences register in the tiered PageCache (auto-disabled
                # for MoE/MLA per the usual rules inside ServeEngine)
                eng = ServeEngine(cfg, run, params=params,
                                  store_pages=store_pages,
                                  tracer=self.tracer, name=f"decode{i}",
                                  **mk)
                self.decodes.append(DecodeReplica(eng))
                self._names.append(f"decode{i}")
            for i, d in enumerate(self.decodes):
                # remote tier: a decode replica whose warm store lost a
                # payload pulls it back by digest — its own transport-side
                # store first (pages that crossed the link land there),
                # then its peers' (PageTransport.fetch = the FETCH message
                # when the transport is socket-backed)
                d.engine.cache.remote_fetch = self._make_fetch(
                    self._names[i])
        self.params = params

    def _make_fetch(self, own: str):
        def fetch(digests):
            out: Dict[bytes, bytes] = {}
            rest = list(digests)
            for dst in [own] + [n for n in self._names if n != own]:
                if not rest:
                    break
                out.update(self.transport.fetch(dst, rest))
                rest = [d for d in rest if d not in out]
            return out
        return fetch

    def run(self, requests: List[Request]
            ) -> Tuple[List[RequestResult], DisaggStats]:
        """Serve a request list to completion across the replica fleet."""
        uids = [r.uid for r in requests]
        if len(set(uids)) != len(uids):
            raise ValueError("request uids must be unique (token streams "
                             "are keyed by uid)")
        results: Dict[int, RequestResult] = {}
        queue = deque(requests)
        pending: deque[Handoff] = deque()   # admitted, awaiting a slot
        t0 = time.perf_counter()

        def route_submissions():
            while queue:
                pr = min(self.prefills, key=lambda p: p.backlog)
                pr.submit(queue.popleft())

        def route_handoffs():
            # streamed handoffs stick to the destination their chunks went
            # to; unrouted ones take the freest replica.  One rotation per
            # round so a full destination never starves the others.  Free
            # counts are fetched ONCE per rotation and decremented on
            # delivery — one STATUS round trip per remote replica, not one
            # per pending handoff.
            progress = True
            while pending and progress:
                progress = False
                free = [d.free_slots() for d in self.decodes]
                for _ in range(len(pending)):
                    h = pending.popleft()
                    i = (self._names.index(h.dst) if h.dst is not None
                         else max(range(len(free)), key=free.__getitem__))
                    if free[i] == 0:
                        pending.append(h)
                        continue
                    self.decodes[i].deliver(h, self.transport,
                                            self._names[i])
                    free[i] -= 1
                    progress = True

        route_submissions()
        while (pending or not all(p.idle() for p in self.prefills)
               or not all(d.idle() for d in self.decodes)):
            for pr in self.prefills:
                fin, hoffs = pr.admit_step()
                for r in fin:
                    results[r.uid] = r
                pending.extend(hoffs)
            route_handoffs()
            for dr in self.decodes:
                for r in dr.step_window():
                    results[r.uid] = r
            route_handoffs()    # freed slots admit waiting transfers now
        wall = time.perf_counter() - t0
        # transferred requests earn their first token on the PREFILL side;
        # the decode replica that finished them never saw the submit, so
        # its results carry ttft 0.0 — patch from the prefill ledgers
        ttfts: Dict[int, float] = {}
        for p in self.prefills:
            ttfts.update(p.ls.ttft_s)
        for uid, r in results.items():
            if r.ttft_s == 0.0 and uid in ttfts:
                results[uid] = dataclasses.replace(r, ttft_s=ttfts[uid])
        stats = self._stats(results, wall)
        return [results[r.uid] for r in requests], stats

    def metrics_snapshot(self) -> Dict:
        """Fleet totals: every replica's registry snapshot (local replicas
        synced in place, remote ones fetched over the METRICS RPC) merged
        with the transport's own registry.  The launch CLIs write this as
        ``--metrics-json``."""
        snaps = [p.engine.sync_metrics(p.ls).snapshot()
                 for p in self.prefills]
        snaps += [d.metrics_snapshot() for d in self.decodes]
        snaps.append(self.transport.registry.snapshot())
        return MetricsRegistry.merge(snaps)

    def _stats(self, results, wall: float) -> DisaggStats:
        ts: TransportStats = self.transport.stats
        pls = [p.ls for p in self.prefills]
        dst = sum_counters(d.decode_stats() for d in self.decodes)
        n_tok = sum(len(r.tokens) for r in results.values())
        lat = summarize_latencies(
            [r.latency_s for r in results.values()])
        ttft = summarize_latencies(
            [t for l in pls for t in l.ttft_s.values()])
        xfer = self.transport.registry.values_of("latency.transfer_s")
        return DisaggStats(
            n_requests=len(results), n_tokens=n_tok,
            decode_steps=dst["steps"],
            n_dispatches=dst["dispatches"],
            n_admit_dispatches=sum(l.admit_dispatches for l in pls),
            n_replay_dispatches=sum(l.replay_dispatches for l in pls),
            n_prefill_replicas=len(self.prefills),
            n_decode_replicas=len(self.decodes),
            n_transfers=ts.n_transfers,
            wire_bytes=ts.wire_bytes,
            wire_bytes_nodedup=ts.wire_bytes_nodedup,
            wire_raw_bytes=ts.raw_bytes,
            dedup_page_refs=ts.pages_ref,
            pages_streamed=ts.pages_streamed,
            stream_chunk_bytes=ts.stream_chunk_bytes,
            pages_resent=ts.pages_resent,
            store_evicted=ts.store_evicted,
            decode_prefix_hits=dst["shared_hits"],
            cache_hot_hits=dst["cache_hot_hits"],
            cache_spilled_pages=dst["cache_spilled_pages"],
            cache_spilled_bytes=dst["cache_spilled_bytes"],
            cache_fetched_pages=dst["cache_fetched_pages"],
            cache_fetched_bytes=dst["cache_fetched_bytes"],
            cache_reprefill_cols=dst["cache_reprefill_cols"],
            link_model_ms=ts.model_ns * 1e-6,
            link_model_ms_raw=ts.model_ns_raw * 1e-6,
            wall_s=wall,
            requests_per_s=len(results) / max(wall, 1e-9),
            tokens_per_s=n_tok / max(wall, 1e-9),
            mean_latency_s=lat["mean"],
            latency_p50_s=lat["p50"], latency_p95_s=lat["p95"],
            ttft_mean_s=ttft["mean"], ttft_p50_s=ttft["p50"],
            ttft_p95_s=ttft["p95"],
            transfer_mean_s=(sum(xfer) / len(xfer)) if xfer else 0.0,
            decode_backend=kernel_ops.resolve_decode_backend(
                self.run_cfg.codec))


def format_disagg_stats(st: DisaggStats) -> str:
    """Human summary of a disaggregated run (demo output)."""
    return (f"{st.n_requests} reqs through {st.n_prefill_replicas} prefill "
            f"-> {st.n_decode_replicas} decode replicas "
            f"({st.decode_backend} backend): {st.tokens_per_s:.1f} tok/s, "
            f"{st.decode_steps} steps / {st.n_dispatches} dispatches\n"
            f"link: {st.n_transfers} transfers, "
            f"{st.wire_bytes / 1e3:.1f} kB wire vs "
            f"{st.wire_raw_bytes / 1e3:.1f} kB raw bf16 "
            f"({st.link_reduction * 100:.1f}% reduction; "
            f"{st.wire_bytes_nodedup / 1e3:.1f} kB codec-only, "
            f"{st.dedup_page_refs} pages deduped, "
            f"{st.pages_streamed} streamed in "
            f"{st.stream_chunk_bytes / 1e3:.1f} kB of chunks, "
            f"{st.decode_prefix_hits} import prefix hits), modeled "
            f"{st.link_model_ms:.3f} ms vs {st.link_model_ms_raw:.3f} ms")
