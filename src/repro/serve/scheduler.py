"""Continuous-batching request scheduler over the paged LEXI-compressed
cache (the serving half of the ROADMAP north star).

``ServeEngine`` owns a model-parallel mesh, the jitted device functions and
one ``PagedState``; ``RequestScheduler`` is the admission queue.  The loop:

    while work:
        admit   — a *batched, prefix-deduplicated fast path*:
                  (a) queued requests whose prompt prefix matches full
                      pages already in the cache map those pages into
                      their page-table row (``map_shared_slot``) with ZERO
                      prefill FLOPs and zero extra page memory — only the
                      unmatched suffix replays;
                  (b) remaining ("cold") requests are drained per length
                      bucket and prefilled in ONE jitted dispatch — a
                      vmapped B=1 ``engine.prefill`` over the bucket trunk
                      feeding ``insert_sequences`` (per-sequence LEXI
                      block compression is preserved bit-for-bit, so the
                      blocks scatter straight into pages);
                  (c) every admitted slot's leftover prompt tokens (trunk
                      bucket tail or unmatched prefix suffix) replay
                      per-slot through fused ``paged_replay_steps`` —
                      exact numerics at every position.
        step    — ONE dispatch runs K fused ``paged_decode_step``s as a
                  ``lax.scan`` (K bounded by the earliest budget-finish
                  event, so streams are byte-identical to stepping one
                  token at a time), one greedy token per active slot/step
        evict   — slots that hit their token budget, emit ``eos_id``, or
                  complete a stop sequence (host-side rolling suffix match
                  over the emitted tokens) release their pages
                  (``release_slots``) at the window boundary; with prefix
                  sharing the release routes through the tiered
                  ``PageCache``: a column whose refcount hits zero is
                  RETAINED on the device (hot tier) after its immutable
                  payload spilled to host RAM (warm tier), so a later
                  identical prefix re-maps or re-imports it with zero
                  prefill FLOPs

The same machinery also runs SPLIT across replicas: ``repro.serve.disagg``
drives ``_admit_phase`` on prefill replicas and ``_decode_window`` on
decode replicas, with admitted sequences crossing between them as
compressed page-transfer blobs (``repro.serve.transport``) — see
``docs/ARCHITECTURE.md`` for the full dataflow.

Admission compile count is bounded: admit functions are keyed by
(trunk bucket, batch size) where trunk buckets are power-of-two multiples
of tp — NOT by raw prompt length — so serving arbitrary length mixes
compiles O(log(max_len/tp) * n_slots) admit functions total
(``ServeStats.n_admit_compiles`` tracks it).  Exception: MoE / SSM / MLA
architectures keep the maximal floor-of-tp trunk (see ``_bucket_of`` —
their decode float path is not bit-equal to prefill, so in-prompt replay
must stay under tp tokens to preserve the legacy-exact split).

**Prefix sharing bookkeeping (host-side).**  Full pages are immutable
once LEXI-FW-compressed, so sharing is pure page-table indirection.  The
host owns a tiered content-addressed ``repro.serve.pagecache.PageCache``
keyed by the chained prefix digests of ``repro.serve.digest.chain_keys``
(32-byte SHA-256 chain links, O(len) to build): the **hot** tier maps a
key to its per-shard page-id vector (ids are tracked per shard because
unaligned releases can permanently permute the free-list order between
shards), retains zero-ref columns under an LRU, and evicts them only
under pool pressure (``_ensure_free_pages``); the **warm** tier holds
the columns' compressed payloads in host RAM (spilled at last release,
restored by a device import — no prefill); the **remote** tier pulls
spilled payloads back from a peer replica's digest store by content
digest (the ``FETCH`` message of ``repro.serve.net``).  Page ids are
read back from the device page table at admit/release boundaries only
(no per-token sync).  MoE/MLA decode is not bit-equal to prefill for
the suffix replay, so those architectures auto-disable sharing (streams
are unchanged either way; hits are simply zero).  Hybrids (SSM +
attention) cannot replay a suffix bit-exactly either, but they DO share
whole page-aligned prompts: admission captures the recurrent state at
the prompt boundary (``_capture_snapshots``) and a later identical
prompt maps/imports every page column and restores that snapshot —
replay-free, hence bit-exact (``_snapshot_match``).

Device state crosses jit boundaries as global arrays with one leading
"model"-sharded axis per leaf (each shard's page pool / page table / ring
is independent state, so the global view is simply the stack of per-shard
views).  The wrapper functions squeeze/unsqueeze that axis at the
shard_map boundary.

Constraints (documented, validated in ``submit``):
  * decoder-only families (dense / MoE / SSM / hybrid); no enc-dec.
  * prompt lengths >= the model-parallel degree (any length admits via
    bucketing; the sequence-sharded trunk needs one slot per shard).
  * prompt_len + max_new_tokens <= max_len (page-pool capacity).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig, RunConfig
from repro.core import collectives as cl
from repro.core import packing
from repro.core import weights as weights_mod
from repro.kernels import ops as kernel_ops
from repro.models import cache as cache_mod
from repro.models import lm, params as PM
from repro.models.ssm import SSMState
from . import engine
from . import transport
from .digest import chain_keys
from .pagecache import PageCache
from .telemetry import (ENGINE_LANE, MetricsRegistry, Tracer,
                        summarize_latencies)


@dataclasses.dataclass
class Request:
    """One generation request (greedy decoding, token budget + optional
    EOS / stop sequences).  ``eos_id`` and ``stop_seqs`` override the
    engine-level defaults when set (``stop_seqs=()`` disables stopping for
    this request even when the engine has defaults)."""
    uid: int
    prompt: np.ndarray               # (S,) int32, S >= tp (any length)
    max_new_tokens: int
    eos_id: Optional[int] = None
    stop_seqs: Optional[Sequence[Sequence[int]]] = None


@dataclasses.dataclass
class RequestResult:
    uid: int
    prompt_len: int
    tokens: List[int]                # generated (incl. EOS/stop seq if hit)
    latency_s: float                 # admit (incl. own prefill) -> finish
    stop_reason: str = "budget"      # budget | eos | stop_string
    ttft_s: float = 0.0              # submit -> first token (0.0 when the
                                     # first token was produced in another
                                     # process, e.g. remote disagg decode)


@dataclasses.dataclass
class ServeStats:
    n_requests: int
    n_tokens: int
    decode_steps: int                # total decode steps executed
    n_dispatches: int                # device dispatches issuing those steps
    n_admit_dispatches: int          # batched-prefill admit dispatches
    n_replay_dispatches: int         # fused prompt-tail replay dispatches
    n_admit_compiles: int            # distinct admit fns compiled (lifetime)
    shared_page_hits: int            # prefix-index page columns mapped
    wall_s: float
    requests_per_s: float
    tokens_per_s: float
    peak_pages: int                  # pages in use, summed over shards/layers
    peak_cache_bytes: int            # stored bytes of those pages
    peak_cache_raw_bytes: int        # bf16 bytes of the same pages
    mean_latency_s: float
    latency_p50_s: float
    latency_p95_s: float
    decode_backend: str              # resolved pallas | interpret | jax
    # tiered PageCache lifecycle counters (engine lifetime, like
    # n_admit_compiles — see repro.serve.pagecache)
    cache_hot_hits: int = 0          # retained zero-ref columns re-acquired
    cache_spilled_pages: int = 0     # page payloads written to the warm store
    cache_spilled_bytes: int = 0
    cache_fetched_pages: int = 0     # payloads restored from warm/remote
    cache_fetched_bytes: int = 0
    cache_reprefill_cols: int = 0    # warm columns lost on every tier
    cache_evicted_cols: int = 0      # hot columns evicted under pool pressure
    # serving weight plane (compressed-at-rest params, core.weights): HBM
    # bytes a decode step streams for weights — analytic, like
    # models/cache.py:page_bytes meters KV bytes
    weights_compressed: bool = False
    weight_backend: str = "jax"      # resolved pallas | interpret | jax
    weight_bytes_per_step: int = 0   # stored (packed + raw-leaf) bytes
    weight_raw_bytes_per_step: int = 0   # same store, all-bf16
    # span-derived latency summaries (telemetry registry histograms;
    # 0.0 when the stage never ran)
    ttft_mean_s: float = 0.0         # submit -> first token
    ttft_p50_s: float = 0.0
    ttft_p95_s: float = 0.0
    admit_window_mean_s: float = 0.0   # batched prefill/replay dispatches
    decode_window_mean_s: float = 0.0  # fused decode dispatches
    inter_token_mean_s: float = 0.0    # decode-window time per step

    @property
    def cache_ratio(self) -> float:
        return self.peak_cache_raw_bytes / max(self.peak_cache_bytes, 1)

    @property
    def weight_ratio(self) -> float:
        """Packed/raw weight HBM traffic per decode step (≤1; 1.0 = raw)."""
        return self.weight_bytes_per_step / max(self.weight_raw_bytes_per_step,
                                                1)


def _norm_stops(stop_seqs) -> Tuple[Tuple[int, ...], ...]:
    """Normalize stop sequences to a tuple of int tuples; empty sequences
    are rejected (they would stop every request at its first token)."""
    if stop_seqs is None:
        return ()
    out = tuple(tuple(int(t) for t in s) for s in stop_seqs)
    if any(not s for s in out):
        raise ValueError("stop sequences must be non-empty")
    return out


@dataclasses.dataclass
class _LoopState:
    """Host-side mutable state of one serving loop.

    Extracted from ``ServeEngine.run`` so the same admission / decode /
    termination machinery can be driven in pieces by the disaggregated
    replicas (``repro.serve.disagg``): a prefill replica runs only
    ``_admit_phase`` on its loop state, a decode replica only
    ``_decode_window`` — with request occupancy seeded by a transfer
    instead of an admission.
    """
    slot_req: List[Optional["Request"]]
    done: List[bool]                  # finished, awaiting eviction
    reason: List[str]
    emitted: Dict[int, List[int]]
    admit_t: Dict[int, float]
    results: Dict[int, "RequestResult"]
    cur: np.ndarray                   # (n_slots, 1) i32 next input tokens
    slot_len: List[int]               # host mirror of cache lengths
    steps: int = 0
    dispatches: int = 0
    admit_dispatches: int = 0
    replay_dispatches: int = 0
    shared_hits: int = 0
    peak_pages: int = 0
    # telemetry timestamps: first-token wall clocks (popped at finish /
    # export), computed TTFTs, and per-dispatch window durations — all
    # O(requests) / O(dispatches), never O(tokens)
    first_tok_t: Dict[int, float] = dataclasses.field(default_factory=dict)
    ttft_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    admit_window_s: List[float] = dataclasses.field(default_factory=list)
    decode_window_s: List[float] = dataclasses.field(default_factory=list)

    def live_slots(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req) if r is not None]


class RequestScheduler:
    """FIFO admission queue with capacity validation.

    Prompt lengths need not be multiples of tp: admission buckets each
    prompt to a power-of-two-multiple-of-tp trunk and replays the leftover
    tokens through exact paged decode steps, so any length >= tp is
    accepted.  Same-bucket requests may admit ahead of a different-bucket
    request queued earlier in the same admission round (bounded FIFO
    deviation in exchange for one prefill dispatch per bucket).
    """

    def __init__(self, tp: int, max_len: int):
        self.tp = tp
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        # wired by the owning engine: the root request span opens at
        # submit, and submit_t feeds TTFT (first token - submit)
        self.tracer: Tracer = Tracer(False)
        self.pid = "serve"
        self.submit_t: Dict[int, float] = {}

    def submit(self, req: Request) -> None:
        s = len(req.prompt)
        if s < self.tp:
            raise ValueError(
                f"prompt length {s} must be >= tp={self.tp} "
                "(the sequence-sharded trunk needs one slot per shard)")
        if s + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request needs {s + req.max_new_tokens} tokens > "
                f"max_len={self.max_len}")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # validate stop sequences HERE, before the request can occupy a
        # slot — a malformed override raising mid-loop (first _check_done)
        # would abort run() with the slot's pages still allocated
        _norm_stops(req.stop_seqs)
        self.submit_t[req.uid] = time.perf_counter()
        self.tracer.request_begin(
            req.uid, pid=self.pid,
            args={"prompt_len": s,
                  "max_new_tokens": int(req.max_new_tokens)})
        self.tracer.stage(req.uid, "queue")
        self.queue.append(req)

    def pop(self) -> Optional[Request]:
        return self.queue.popleft() if self.queue else None

    def __len__(self) -> int:
        return len(self.queue)


class ServeEngine:
    """Continuous-batching inference engine (one replica, model-parallel)."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, *, tp: int = 1,
                 n_slots: int = 4, max_len: int = 256, params=None,
                 seed: int = 0, eos_id: Optional[int] = None,
                 stop_seqs: Optional[Sequence[Sequence[int]]] = None,
                 max_fuse_steps: int = 32, prefix_sharing: bool = True,
                 store_pages: int = 4096, remote_fetch=None,
                 compress_weights: bool = False,
                 tracer: Optional[Tracer] = None, name: str = "serve"):
        if cfg.encdec or cfg.frontend != "none":
            raise ValueError("continuous batching covers decoder-only, "
                             "text-frontend architectures")
        if max_fuse_steps < 1:
            raise ValueError("max_fuse_steps must be >= 1")
        self.cfg, self.run_cfg, self.tp = cfg, run, tp
        self.n_slots, self.max_len = n_slots, max_len
        self.eos_id = eos_id
        self.stop_seqs = _norm_stops(stop_seqs)
        self.max_fuse_steps = max_fuse_steps
        # sharing needs KV pages (attention) and a decode path that is
        # bit-equal to prefill at in-prompt positions (the matched prefix
        # skips prefill; the suffix replays through decode steps) — which
        # rules out MoE / MLA, see _bucket_of.  Hybrids (SSM + attention)
        # cannot suffix-replay either, but share whole page-aligned
        # prompts through boundary SSM snapshots (_snapshot_match), so
        # they stay enabled.
        self.prefix_sharing = bool(prefix_sharing and cfg.n_heads > 0
                                   and cfg.moe is None and cfg.mla is None)
        mesh_cfg = MeshConfig(data=1, model=tp, pod=1)
        self.mesh = jax.make_mesh((1, tp), ("data", "model"))
        self.table = lm.lm_table(cfg, mesh_cfg, run)
        self.dims = lm.lm_fsdp_dims(self.table)
        self.params = (params if params is not None
                       else PM.init_params(self.table, jax.random.key(seed)))
        self._pspecs = PM.param_pspecs(self.table)
        # serving weight plane: pack bulk 2-D leaves into the LEXI-FW
        # at-rest layout (idempotent — disagg replicas share one tree) and
        # swap the matching pspec nodes; every jitted fn below closes over
        # self._pspecs, so the packed store flows into all dispatch paths.
        self.compress_weights = bool(compress_weights)
        self.weight_backend = kernel_ops.resolve_weight_backend(run.codec)
        if self.compress_weights:
            self.params, self._pspecs = weights_mod.pack_serving_params(
                self.params, self._pspecs, backend=self.weight_backend,
                tp=tp)
        self._weight_bytes = weights_mod.weight_plane_bytes(self.params)
        # telemetry: the tracer is shared (a disagg fleet hands every
        # replica one tracer, distinguished by engine ``name`` = span
        # pid); the metrics registry is per-engine and always on — its
        # counters are plain host ints refreshed by ``sync_metrics``
        self.name = name
        self.tracer = tracer if tracer is not None else Tracer(False)
        self.registry = MetricsRegistry()
        self.scheduler = RequestScheduler(tp, max_len)
        self.scheduler.tracer = self.tracer
        self.scheduler.pid = name

        shard = engine.empty_paged_state(cfg, run, n_slots, max_len, tp)
        self._sspec = jax.tree_util.tree_map(lambda a: P("model"), shard)
        # global view: one leading model-sharded axis, per-shard copies
        self.state = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (tp,) + a.shape), shard)

        # tokens covered by one full page column (all shards' owned slots)
        self.blk_tokens = run.codec.cache_block * tp
        self._n_pages = (shard.kv.page_used.shape[-1]
                         if shard.kv is not None else 0)
        self._maxp = (shard.kv.page_table.shape[-1]
                      if shard.kv is not None else 0)

        # host-side page-lifecycle bookkeeping (see module docstring):
        # the tiered content-addressed PageCache owns the prefix index,
        # refcounts, retention LRU, warm spill store and SSM snapshots;
        # _slot_keys mirrors which prefix keys each slot holds refs on
        self.cache = PageCache(max_store_pages=store_pages,
                               remote_fetch=remote_fetch)
        self._slot_keys: List[List[bytes]] = [[] for _ in range(n_slots)]
        self._slot_busy = np.zeros((n_slots,), bool)

        # streaming-prefill hook: the disagg prefill replica sets this to
        # export freshly completed page columns MID-ADMISSION (after the
        # batched trunk insert and after every fused replay dispatch), so
        # full pages can cross the transfer link while the prompt tail is
        # still replaying.  Called with the loop state; None = no-op.
        self.admit_progress_cb = None

        self.n_admit_compiles = 0
        self._admit_cache: Dict[Tuple[int, int], object] = {}
        self._decode_cache: Dict[int, object] = {}
        self._replay_cache: Dict[int, object] = {}
        self._export_cache: Dict[int, object] = {}
        self._import_cache: Dict[int, object] = {}
        self._release = jax.jit(cl.shmap(
            self._release_fn, self.mesh, (self._sspec, P(None)),
            self._sspec))
        self._release_shared = None
        self._map_shared = None
        self._restore_ssm = None

    # legacy aliases: the prefix index/refcounts now live in the PageCache
    # (kept as views — the disagg import path and tests poke them directly)

    @property
    def _prefix_index(self) -> Dict[bytes, np.ndarray]:
        return self.cache.index

    @property
    def _prefix_ref(self) -> Dict[bytes, int]:
        return self.cache.ref

    # -- shard_map bodies --------------------------------------------------

    @staticmethod
    def _squeeze(st_g):
        return jax.tree_util.tree_map(lambda a: a[0], st_g)

    @staticmethod
    def _unsqueeze(st):
        return jax.tree_util.tree_map(lambda a: a[None], st)

    def _release_fn(self, st_g, mask):
        return self._unsqueeze(engine.release_slots(self._squeeze(st_g),
                                                    mask))

    def _release_shared_for(self):
        """(state, slot_mask, free_mask (tp, P)) -> state; frees exactly
        the pages the host refcounts said hit zero (per-shard masks)."""
        if self._release_shared is None:
            def rel(st_g, mask, free_g):
                st = engine.release_slots(self._squeeze(st_g), mask,
                                          free_mask=free_g[0])
                return self._unsqueeze(st)

            self._release_shared = jax.jit(cl.shmap(
                rel, self.mesh, (self._sspec, P(None), P("model", None)),
                self._sspec))
        return self._release_shared

    def _map_shared_for(self):
        """(state, slot, ids (tp, maxp), n_cols, base_len) -> state."""
        if self._map_shared is None:
            def mp(st_g, slot, ids_g, n_cols, base_len):
                st = engine.map_shared_slot(self._squeeze(st_g), slot,
                                            ids_g[0], n_cols, base_len)
                return self._unsqueeze(st)

            self._map_shared = jax.jit(cl.shmap(
                mp, self.mesh,
                (self._sspec, P(), P("model", None), P(), P()),
                self._sspec))
        return self._map_shared

    def _restore_ssm_for(self):
        """(state, slot, ssm slot leaves (tp, L, ...)) -> state: scatter a
        boundary SSM snapshot into one slot.  The hybrid half of a
        snapshot hit whose page columns were ALL still hot — no import
        dispatch runs, so the recurrent state needs its own scatter."""
        if self._restore_ssm is None:
            def rs(st_g, slot, ssm_g):
                st = self._squeeze(st_g)
                ssm = jax.tree_util.tree_map(
                    lambda a, v: a.at[:, slot].set(v.astype(a.dtype)),
                    st.ssm, self._squeeze(ssm_g))
                return self._unsqueeze(st._replace(ssm=ssm))

            self._restore_ssm = jax.jit(cl.shmap(
                rs, self.mesh, (self._sspec, P(), P("model")),
                self._sspec))
        return self._restore_ssm

    def _export_for(self, n_cols: int):
        """(state, slot, col0) -> (kv wire (tp, L, ...) leaves, ssm slot
        leaves, length) — one jitted export per page-column count
        (``n_cols`` is static; at most max-pages-per-slot distinct values
        exist).  ``col0`` (traced) windows the gather to page columns
        ``[col0, col0 + n_cols)`` — 0 for a whole-sequence export, the
        streamed-so-far watermark for chunked prefill export."""
        fn = self._export_cache.get(n_cols)
        if fn is None:
            def ex(st_g, slot, col0):
                kvw, ssm, length = engine.export_slot(
                    self._squeeze(st_g), slot, n_cols, self.tp, col0)
                return (self._unsqueeze(kvw), self._unsqueeze(ssm), length)

            fn = jax.jit(cl.shmap(
                ex, self.mesh, (self._sspec, P(), P()),
                (P("model"), P("model"), P())))
            self._export_cache[n_cols] = fn
        return fn

    def _import_for(self, n_cols: int):
        """(state, slot, kv wire, ssm slot, length, col0) -> state — the
        decode-replica half of a handoff (pages allocated from THIS pool's
        free list; see ``cache.import_sequence``).  ``col0`` (traced) > 0
        imports only the wire columns ``[col0, col0 + n_cols)``, keeping
        the row below ``col0`` (prefix-reuse maps shared pages there)."""
        fn = self._import_cache.get(n_cols)
        if fn is None:
            def im(st_g, slot, kvw_g, ssm_g, length, col0):
                st = engine.import_slot(
                    self._squeeze(st_g), slot, self._squeeze(kvw_g),
                    self._squeeze(ssm_g), length, self.tp, col0)
                return self._unsqueeze(st)

            fn = jax.jit(cl.shmap(
                im, self.mesh,
                (self._sspec, P(), P("model"), P("model"), P(), P()),
                self._sspec))
            self._import_cache[n_cols] = fn
        return fn

    def _decode_for(self, n_steps: int):
        """One jitted K-step fused decode per distinct K.

        The K decode steps run as one ``lax.scan`` inside one dispatch, so
        host overhead amortizes over K tokens; the scanned body is exactly
        ``paged_decode_step`` + greedy, so the emitted (K, S, 1) token block
        is byte-identical to K single-step dispatches.
        """
        fn = self._decode_cache.get(n_steps)
        if fn is not None:
            return fn

        def decode(pp, st_g, toks):
            st = self._squeeze(st_g)

            def body(carry, _):
                st_c, tok = carry
                logits, st_c = engine.paged_decode_step(
                    self.cfg, self.run_cfg, pp, self.dims, st_c, tok,
                    self.tp)
                tok = engine.greedy_token(self.cfg, logits, self.tp)
                return (st_c, tok), tok

            (st, _), seq = jax.lax.scan(body, (st, toks), None,
                                        length=n_steps)
            return seq, self._unsqueeze(st)

        fn = jax.jit(cl.shmap(
            decode, self.mesh,
            (self._pspecs, self._sspec, P(None, None)),
            (P(None, None, None), self._sspec)))
        self._decode_cache[n_steps] = fn
        return fn

    def _replay_for(self, n_steps: int):
        """One jitted K-step fused prompt replay per distinct K (powers of
        two, so the cache stays at O(log max prompt length) entries).
        Feeds known tokens through ``paged_replay_steps`` with a per-step
        per-slot feed mask — heterogeneous tail lengths replay together."""
        fn = self._replay_cache.get(n_steps)
        if fn is not None:
            return fn

        def replay(pp, st_g, toks, feed):
            seq, st = engine.paged_replay_steps(
                self.cfg, self.run_cfg, pp, self.dims, self._squeeze(st_g),
                toks, feed, self.tp)
            return seq, self._unsqueeze(st)

        fn = jax.jit(cl.shmap(
            replay, self.mesh,
            (self._pspecs, self._sspec, P(None, None, None), P(None, None)),
            (P(None, None, None), self._sspec)))
        self._replay_cache[n_steps] = fn
        return fn

    def _fuse_steps(self, bound: int) -> int:
        """Decode steps to fuse into the next dispatch: the largest power
        of two <= the earliest slot-finish event (so eviction/admission
        still happen at window boundaries and the jit cache stays at
        O(log max_new_tokens) entries), capped by ``max_fuse_steps``."""
        k = 1 << (max(bound, 1).bit_length() - 1)
        return min(k, self.max_fuse_steps)

    def _bucket_of(self, prompt_len: int) -> int:
        """Trunk bucket: the largest power-of-two multiple of tp that fits
        the prompt, for pure-attention architectures — leftover tokens
        replay through paged decode steps that are bit-identical to the
        prefill at the same positions, so bucketing never changes streams
        while bounding the admit compile count at O(log(max_len/tp)).

        Routed / recurrent layers (MoE, SSM, MLA absorbed-form decode)
        combine shard partials on a different float path at decode than at
        batched prefill (e.g. MoE decode psums bf16 per-shard partials
        where prefill a2a-combines expert outputs in f32), so for them an
        in-prompt replay step is NOT bit-equal to prefilling that position.
        Those families keep the maximal floor-of-tp trunk (tail < tp, the
        exact legacy admission split) — their admit compile count grows
        with distinct aligned lengths, which is the price of exactness."""
        c = self.cfg
        exact = (prompt_len // self.tp) * self.tp
        if c.moe is not None or c.ssm is not None or c.mla is not None:
            return exact
        b = self.tp
        while b * 2 <= prompt_len:
            b *= 2
        return b

    def _admit_for(self, trunk_len: int, n_batch: int):
        """One jitted admit per (trunk bucket, batch size): a vmapped B=1
        ``engine.prefill`` over the batch (per-sequence numerics AND
        per-sequence LEXI block compression are bit-identical to separate
        B=1 prefills — a true B>1 prefill would jointly compress blocks
        across sequences and couple MoE capacity between them) feeding one
        vectorized ``insert_sequences`` scatter."""
        key = (trunk_len, n_batch)
        fn = self._admit_cache.get(key)
        if fn is not None:
            return fn

        def admit(pp, st_g, prompts, slots):
            st = self._squeeze(st_g)

            def one(prompt):
                logits, d = engine.prefill(
                    self.cfg, self.run_cfg, pp, self.dims, prompt[None],
                    self.max_len, self.tp)
                return engine.greedy_token(self.cfg, logits, self.tp), d

            toks, ds = jax.vmap(one)(prompts)
            st = engine.insert_sequences(self.cfg, self.run_cfg, st, ds,
                                         slots, trunk_len, self.tp)
            return toks[:, 0], self._unsqueeze(st)

        fn = jax.jit(cl.shmap(
            admit, self.mesh,
            (self._pspecs, self._sspec, P(None, None), P(None)),
            (P(None, None), self._sspec)))
        self._admit_cache[key] = fn
        self.n_admit_compiles += 1
        return fn

    # -- prefix index ------------------------------------------------------

    def _prefix_keys(self, prompt: np.ndarray, n_cols: int) -> List[bytes]:
        """Chained content keys, one per full page column — shared with
        the transport's dedup layer, see ``repro.serve.digest``."""
        return chain_keys(prompt, n_cols, self.blk_tokens)

    def _prefix_match_cols(self, prompt: np.ndarray
                           ) -> Tuple[int, List[bytes], List[List[bytes]]]:
        """(matched column count, their keys, warm payload columns).

        The longest run of leading full page columns restorable from the
        cache — hot columns (mapped for free) extended by warm columns
        (payloads fetched from the host-RAM store or a peer, imported
        without prefill FLOPs).  Capped so at least one suffix token
        remains to replay (the first generated token needs logits from
        the last prompt position) — and gated on replay cost: a match is
        only worth taking when the unmatched suffix replay is no longer
        than the cold path's own bucket-tail replay (plus at most one
        column), otherwise a shallow hit on a long prompt (e.g. a shared
        short preamble) would trade one batched prefill dispatch for a
        long per-token replay.  The gate is monotone in the match depth,
        so it is checked against the deepest candidate BEFORE any warm
        bytes are fetched.  Hybrids never take this path (suffix replay
        is not bit-equal for the recurrence) — see ``_snapshot_match``."""
        if not self.prefix_sharing or self.cfg.ssm is not None:
            return 0, [], []
        bt = self.blk_tokens
        keys = self._prefix_keys(prompt, (len(prompt) - 1) // bt)
        h = 0
        while h < len(keys) and keys[h] in self.cache.index:
            h += 1
        m_cand = h
        while m_cand < len(keys) and self.cache.has_warm(keys[m_cand]):
            m_cand += 1

        def ok(mm: int) -> bool:
            if mm < 1:
                return False
            suffix = len(prompt) - mm * bt
            cold_tail = len(prompt) - self._bucket_of(len(prompt))
            return suffix <= max(cold_tail, bt)

        if not ok(m_cand):
            return 0, [], []
        warm: List[List[bytes]] = []
        m = h
        with self._cache_fetch_span():
            for j in range(h, m_cand):
                payloads = self.cache.fetch_warm(keys[j])
                if payloads is None:    # gone on every tier: truncate
                    break
                warm.append(payloads)
                m += 1
        if not ok(m):
            return 0, [], []
        return m, keys[:m], warm

    def _snapshot_match(self, prompt: np.ndarray):
        """Hybrid replay-free hit: ``(keys, hot cols, warm payload
        columns, snapshot)`` when EVERY full column of this page-aligned
        prompt is restorable (hot or warm) AND its boundary SSM snapshot
        exists; ``None`` otherwise.  Partial matches stay cold — replaying
        a suffix through the recurrence is not bit-equal to prefill, so
        the only exact hybrid hit is the whole prompt plus the captured
        state at its boundary."""
        bt = self.blk_tokens
        if len(prompt) < bt or len(prompt) % bt != 0:
            return None
        n = len(prompt) // bt
        keys = self._prefix_keys(prompt, n)
        snap = self.cache.get_snapshot(keys[-1])
        if snap is None:
            return None
        h = 0
        while h < n and keys[h] in self.cache.index:
            h += 1
        if any(not self.cache.has_warm(keys[j]) for j in range(h, n)):
            return None
        warm: List[List[bytes]] = []
        with self._cache_fetch_span():
            for j in range(h, n):
                payloads = self.cache.fetch_warm(keys[j])
                if payloads is None:
                    return None
                warm.append(payloads)
        return keys, h, warm, snap

    def _register_prefixes(self, slots_prompts) -> None:
        """Index the freshly admitted slots' full page columns.

        One small device read of the page tables per admission round (rows
        are read per shard — ids may differ across shards, see module
        docstring).  Already-indexed keys were mapped shared and counted at
        map time; new keys start at refcount 1 (their owner slot).
        """
        if not self.prefix_sharing or not slots_prompts:
            return
        rows = np.asarray(self.state.kv.page_table)[:, 0]  # (tp, S, maxp)
        for slot, prompt, length in slots_prompts:
            keys = self._prefix_keys(prompt, length // self.blk_tokens)
            for c, key in enumerate(keys):
                if key in self.cache.index:
                    continue
                ids = rows[:, slot, c].copy()
                assert (ids >= 0).all(), (slot, c, ids)
                self.cache.insert(key, ids)
                self._slot_keys[slot].append(key)

    # -- slot release (tiered retention) -----------------------------------

    def _page_geometry(self) -> Tuple[int, int, int, int, int]:
        """(blk, w, k, esc_cap, npad) of one page in this pool — the
        payload geometry shared with the transport wire format."""
        codec = self.run_cfg.codec
        blk = codec.cache_block
        w = cache_mod.kv_width(self.cfg) if self.cfg.n_heads > 0 else 0
        n = blk * w
        if n == 0:
            return blk, 0, codec.k, 0, 0
        return blk, w, codec.k, codec.esc_capacity(n), packing.pad_to_lanes(n)

    @contextlib.contextmanager
    def _cache_fetch_span(self):
        """Engine-lane span over a warm/remote fetch burst.  Byte args
        are deltas of the PageCache counters, so summed trace bytes
        equal the ``cache.*`` stats counters by construction."""
        tr = self.tracer
        if not tr.enabled:
            yield
            return
        c = self.cache
        t0 = tr.now()
        p0, b0 = c.fetched_pages, c.fetched_bytes
        rp0, rb0 = c.remote_pages, c.remote_bytes
        try:
            yield
        finally:
            if c.fetched_pages != p0 or c.remote_pages != rp0:
                tr.emit("cache_fetch", cat="cache", pid=self.name,
                        tid=ENGINE_LANE, t0=t0, t1=tr.now(),
                        args={"pages": c.fetched_pages - p0,
                              "bytes": c.fetched_bytes - b0,
                              "remote_pages": c.remote_pages - rp0,
                              "remote_bytes": c.remote_bytes - rb0})

    def _spill_slots(self, slots: List[int], rows: np.ndarray) -> None:
        """Export and spill every page column whose LAST reference is
        being released — the hot -> warm handoff, run BEFORE the refcount
        drop while the releasing slot's page-table row still addresses
        the pages (an evicted column is in no row, so spilling later
        would be impossible).  Columns already warm skip the export."""
        tr = self.tracer
        t0 = tr.now()
        p0, b0 = self.cache.spilled_pages, self.cache.spilled_bytes
        holds: Dict[bytes, int] = {}
        for s in slots:
            for key in self._slot_keys[s]:
                holds[key] = holds.get(key, 0) + 1
        codec_on = bool(self.run_cfg.codec.cache)
        fields = (("signman", "planes", "dict_syms", "esc_pos", "esc_raw")
                  if codec_on else ("raw_pages",))
        done = set()
        for s in slots:
            colof = {int(rows[0, s, c]): c for c in range(self._maxp)
                     if rows[0, s, c] >= 0}
            pend = []
            for key in self._slot_keys[s]:
                if (key in done or self.cache.has_warm(key)
                        or self.cache.ref.get(key, 0) != holds[key]):
                    continue          # other refs remain: stays hot there
                ids = self.cache.index.get(key)
                c = None if ids is None else colof.get(int(ids[0]))
                if c is None:
                    continue          # duplicate column owned elsewhere
                pend.append((key, c))
            if not pend:
                continue
            span = max(c for _, c in pend) + 1
            n = 1
            while n < span:           # power-of-two export windows keep
                n *= 2                # the jit cache at O(log maxp)
            n = min(n, self._maxp)
            kvw, _, _ = self._export_for(n)(
                self.state, jnp.asarray(s, jnp.int32),
                jnp.asarray(0, jnp.int32))
            kv = {f: np.asarray(getattr(kvw, f)) for f in fields}
            for key, c in pend:
                payloads = [transport.page_payload(kv, codec_on, t, l, c)
                            for t in range(self.tp)
                            for l in range(self.cfg.n_layers)]
                self.cache.spill(key, payloads)
                done.add(key)
        if tr.enabled and self.cache.spilled_pages != p0:
            tr.emit("cache_spill", cat="cache", pid=self.name,
                    tid=ENGINE_LANE, t0=t0, t1=tr.now(),
                    args={"pages": self.cache.spilled_pages - p0,
                          "bytes": self.cache.spilled_bytes - b0})

    def _free_slots(self, slots: List[int]) -> None:
        """Evict ``slots`` through the tiered PageCache: spill last-copy
        columns to the warm store, drop the slots' references (columns at
        zero are RETAINED on the device under the cache's LRU — the
        tentpole change from free-at-zero), and free only the pages no
        index entry claims (decode-grown columns, duplicates; all pages
        when sharing is off).  Double release is rejected loudly —
        freeing a slot that is not occupied would hand its (possibly
        shared) pages back to the allocator while another sequence still
        reads them."""
        slots = [int(s) for s in slots]
        for s in slots:
            if not self._slot_busy[s]:
                raise RuntimeError(
                    f"double release: slot {s} is not occupied")
        mask = np.zeros((self.n_slots,), bool)
        mask[slots] = True
        if not self.prefix_sharing or self.state.kv is None:
            self.state = self._release(self.state, jnp.asarray(mask))
        else:
            rows = np.asarray(self.state.kv.page_table)[:, 0]  # (tp,S,maxp)
            self._spill_slots(slots, rows)        # 1) hot -> warm handoff
            for s in slots:                       # 2) drop references
                for key in self._slot_keys[s]:
                    self.cache.release(key)       # zero-ref -> retained
            free = np.zeros((self.tp, self._n_pages), bool)
            for s in slots:                       # 3) free unindexed pages
                for t in range(self.tp):
                    keep = {int(self.cache.index[key][t])
                            for key in self._slot_keys[s]
                            if key in self.cache.index}
                    for p in rows[t, s]:
                        if p >= 0 and int(p) not in keep:
                            free[t, int(p)] = True
                self._slot_keys[s] = []
            self.state = self._release_shared_for()(
                self.state, jnp.asarray(mask), jnp.asarray(free))
        self._slot_busy[mask] = False

    def _lfp(self, length: int, t: int) -> int:
        """Full page columns shard ``t`` holds at sequence ``length`` —
        host arithmetic mirroring the device flush rule."""
        if length <= 0:
            return 0
        blk = self.run_cfg.codec.cache_block
        return max((length - 1 - t) // self.tp + 1, 0) // blk

    def _page_growth(self, l0: int, l1: int) -> int:
        """Worst-per-shard new full pages when a slot grows l0 -> l1."""
        return max(self._lfp(l1, t) - self._lfp(l0, t)
                   for t in range(self.tp))

    def _ensure_free_pages(self, need: int) -> None:
        """Make room for ``need`` fresh pages per shard/layer pool by
        evicting retained zero-ref columns (LRU order) from the hot tier.
        Retention must never cause an allocation failure the free-at-zero
        engine could not have had — this is the pool-pressure valve,
        called before every page-allocating dispatch.  Spilling happened
        at release time, so eviction is pure ``page_used`` clearing."""
        if need <= 0 or not self.cache.lru or self.state.kv is None:
            return
        used = np.asarray(self.state.kv.page_used)      # (tp, L, P)
        free = self._n_pages - int(used.sum(axis=-1).max())
        if free >= need:
            return
        fmask = np.zeros((self.tp, self._n_pages), bool)
        n = 0
        while free + n < need and self.cache.lru:
            _, ids = self.cache.evict_lru()
            for t in range(self.tp):
                fmask[t, int(ids[t])] = True
            n += 1
        self.state = self._release_shared_for()(
            self.state, jnp.asarray(np.zeros((self.n_slots,), bool)),
            jnp.asarray(fmask))

    def drop_cache(self) -> int:
        """Evict every RETAINED (zero-ref) column and clear the warm +
        snapshot tiers — the explicit teardown free-at-zero used to do
        implicitly at the last release.  Live slots are untouched.
        Returns the number of hot columns dropped."""
        if not self.prefix_sharing or self.state.kv is None:
            return 0
        ids = self.cache.drop_retained()
        if ids:
            fmask = np.zeros((self.tp, self._n_pages), bool)
            for v in ids:
                for t in range(self.tp):
                    fmask[t, int(v[t])] = True
            self.state = self._release_shared_for()(
                self.state, jnp.asarray(np.zeros((self.n_slots,), bool)),
                jnp.asarray(fmask))
        return len(ids)

    # -- metrics -----------------------------------------------------------

    def _pages_for_length(self, length: int) -> int:
        """Pages one sequence of ``length`` tokens occupies (all layers,
        summed over shards) — pure host arithmetic, mirroring the device's
        flush rule (a page exists exactly per full block of owned slots),
        so the serving loop never syncs device state for its metrics."""
        if self.cfg.n_heads == 0 or length <= 0:
            return 0
        blk = self.run_cfg.codec.cache_block
        per_shard = sum(
            max((length - 1 - t) // self.tp + 1, 0) // blk
            for t in range(self.tp))
        return per_shard * self.cfg.n_layers

    def _shared_page_overcount(self) -> int:
        """Pages counted multiple times by the per-slot sum because they
        are prefix-shared: (ref - 1) per indexed column, in physical pages
        (x tp shards x n_layers)."""
        over = sum(max(r - 1, 0) for r in self._prefix_ref.values())
        return over * self.tp * self.cfg.n_layers

    def _pages_in_use(self) -> int:
        """Device-truth page count (syncs; for tests/inspection only)."""
        if self.state.kv is None:
            return 0
        return int(np.asarray(self.state.kv.page_used).sum())

    # -- the serving loop --------------------------------------------------
    #
    # The loop is factored into methods over an explicit ``_LoopState`` so
    # the disaggregated replicas (repro.serve.disagg) can drive admission
    # and decode separately; ``run`` below composes them into the original
    # monolithic engine (token streams are unchanged by the refactor —
    # the identity tests in tests/test_serve_engine.py are the proof).

    def _req_eos(self, req: Request) -> Optional[int]:
        return req.eos_id if req.eos_id is not None else self.eos_id

    def _req_stops(self, req: Request) -> Tuple[Tuple[int, ...], ...]:
        return (_norm_stops(req.stop_seqs) if req.stop_seqs is not None
                else self.stop_seqs)

    def _new_loop(self) -> _LoopState:
        return _LoopState(
            slot_req=[None] * self.n_slots,
            done=[False] * self.n_slots,
            reason=[""] * self.n_slots,
            emitted={}, admit_t={}, results={},
            cur=np.zeros((self.n_slots, 1), np.int32),
            slot_len=[0] * self.n_slots)

    def _track_peak(self, ls: _LoopState) -> None:
        pages = sum(self._pages_for_length(ls.slot_len[s])
                    for s, r in enumerate(ls.slot_req) if r is not None)
        if self.prefix_sharing:
            pages -= self._shared_page_overcount()
        ls.peak_pages = max(ls.peak_pages, pages)

    def _check_done(self, ls: _LoopState, s: int, req: Request) -> None:
        """Host-side termination check after each emitted token.  Priority
        when several fire on the same token: eos > stop_string > budget.
        Stop sequences are a rolling suffix match over the emitted tokens
        (evaluated as the host walks each fused window's token block, so a
        stop inside a window finishes the request at the match position and
        the slot idles to the window boundary — same convention as EOS)."""
        toks = ls.emitted[req.uid]
        eos = self._req_eos(req)
        if eos is not None and toks and toks[-1] == eos:
            ls.done[s], ls.reason[s] = True, "eos"
            return
        for ss in self._req_stops(req):
            if len(toks) >= len(ss) and toks[-len(ss):] == list(ss):
                ls.done[s], ls.reason[s] = True, "stop_string"
                return
        if len(toks) >= req.max_new_tokens:
            ls.done[s], ls.reason[s] = True, "budget"

    def _finish_ready(self, ls: _LoopState) -> List[RequestResult]:
        """Harvest done slots into results and evict them; returns the
        newly finished results (the disagg router forwards them)."""
        freed, fresh = [], []
        for s, req in enumerate(ls.slot_req):
            if req is None or not ls.done[s]:
                continue
            now = time.perf_counter()
            ft = ls.first_tok_t.pop(req.uid, None)
            sub = self.scheduler.submit_t.pop(req.uid, None)
            ttft = 0.0
            if ft is not None:
                ttft = ft - (sub if sub is not None
                             else ls.admit_t[req.uid])
                ls.ttft_s[req.uid] = ttft
            res = RequestResult(
                uid=req.uid, prompt_len=len(req.prompt),
                tokens=ls.emitted[req.uid][:req.max_new_tokens],
                latency_s=now - ls.admit_t[req.uid],
                stop_reason=ls.reason[s], ttft_s=ttft)
            self.tracer.request_end(
                req.uid, args={"stop_reason": res.stop_reason,
                               "tokens": len(res.tokens)})
            ls.results[req.uid] = res
            fresh.append(res)
            ls.slot_req[s] = None
            ls.done[s], ls.reason[s] = False, ""
            freed.append(s)
        if freed:
            self._free_slots(freed)
        return fresh

    def _free_slot_ids(self, ls: _LoopState) -> List[int]:
        return [s for s in range(self.n_slots) if ls.slot_req[s] is None]

    def _warm_wire(self, warm: List[List[bytes]]):
        """Assemble fetched warm payload columns into one import-ready
        ``PageWire`` (global view, leading shard axis; zero ring — warm
        restores are page-aligned by construction, so the partial-block
        ring is never read before it is overwritten)."""
        blk, w, k, esc_cap, npad = self._page_geometry()
        codec_on = bool(self.run_cfg.codec.cache)
        tp, L = self.tp, self.cfg.n_layers
        kv = transport.empty_page_fields(codec_on, tp, L, len(warm),
                                         blk, w, k, esc_cap, npad)
        for c, payloads in enumerate(warm):
            i = 0
            for t in range(tp):        # shard-major, the spill order
                for l in range(L):
                    transport.scatter_page_payload(
                        kv, codec_on, t, l, c, payloads[i], blk=blk,
                        w=w, k=k, esc_cap=esc_cap, npad=npad)
                    i += 1
        ring = jnp.zeros((tp, L, blk, w), jnp.bfloat16)
        if codec_on:
            return cache_mod.PageWire(
                signman=jnp.asarray(kv["signman"]),
                planes=jnp.asarray(kv["planes"]),
                dict_syms=jnp.asarray(kv["dict_syms"]),
                esc_pos=jnp.asarray(kv["esc_pos"]),
                esc_raw=jnp.asarray(kv["esc_raw"]),
                raw_pages=None, ring=ring)
        return cache_mod.PageWire(
            signman=None, planes=None, dict_syms=None, esc_pos=None,
            esc_raw=None, raw_pages=jnp.asarray(kv["raw_pages"]),
            ring=ring)

    def _admit_shared(self, ls: _LoopState, s: int, req: Request, m: int,
                      keys: List[bytes],
                      warm: List[List[bytes]]) -> None:
        """Prefix-cache hit: map the hot columns, import the warm ones
        (fetched payloads, no prefill FLOPs), replay the suffix.  Hot
        keys are acquired BEFORE the pool-pressure valve runs so a
        retained column this admission is about to map cannot be evicted
        to make room for its own warm import."""
        h = m - len(warm)
        ls.admit_t.setdefault(req.uid, time.perf_counter())
        self.tracer.stage(req.uid, "admit",
                          args={"mode": "warm" if warm else "shared",
                                "cols": m, "warm_cols": len(warm)})
        ids = np.zeros((self.tp, self._maxp), np.int32)
        for c in range(h):
            ids[:, c] = self.cache.acquire(keys[c])
            self._slot_keys[s].append(keys[c])
        if warm:
            self._ensure_free_pages(len(warm))
        if h:
            base_cols = h if warm else m    # import (below) sets the
            self.state = self._map_shared_for()(  # final length otherwise
                self.state, jnp.asarray(s, jnp.int32), jnp.asarray(ids),
                jnp.asarray(h, jnp.int32),
                jnp.asarray(base_cols * self.blk_tokens, jnp.int32))
        if warm:
            self.state = self._import_for(len(warm))(
                self.state, jnp.asarray(s, jnp.int32),
                self._warm_wire(warm), None,
                jnp.asarray(m * self.blk_tokens, jnp.int32),
                jnp.asarray(h, jnp.int32))
        ls.shared_hits += m
        ls.slot_req[s] = req
        self._slot_busy[s] = True
        ls.slot_len[s] = m * self.blk_tokens
        ls.emitted[req.uid] = []

    def _admit_snapshot(self, ls: _LoopState, s: int, req: Request,
                        keys: List[bytes], h: int,
                        warm: List[List[bytes]], snap) -> None:
        """Hybrid snapshot hit: map/import ALL page columns and restore
        the boundary SSM state — zero prefill FLOPs, zero replay.  The
        first greedy token comes from the snapshot, computed by the
        original admission at the same boundary, so the stream is
        bit-exact by construction."""
        n, nr = len(keys), len(warm)
        ls.admit_t.setdefault(req.uid, time.perf_counter())
        self.tracer.stage(req.uid, "admit",
                          args={"mode": "snapshot", "cols": n,
                                "warm_cols": nr})
        ids = np.zeros((self.tp, self._maxp), np.int32)
        for c in range(h):
            ids[:, c] = self.cache.acquire(keys[c])
            self._slot_keys[s].append(keys[c])
        if nr:
            self._ensure_free_pages(nr)
        if h:
            base_cols = h if nr else n
            self.state = self._map_shared_for()(
                self.state, jnp.asarray(s, jnp.int32), jnp.asarray(ids),
                jnp.asarray(h, jnp.int32),
                jnp.asarray(base_cols * self.blk_tokens, jnp.int32))
        ssm_dev = SSMState(*(jnp.asarray(a) for a in snap["ssm"]))
        if nr:
            self.state = self._import_for(nr)(
                self.state, jnp.asarray(s, jnp.int32),
                self._warm_wire(warm), ssm_dev,
                jnp.asarray(n * self.blk_tokens, jnp.int32),
                jnp.asarray(h, jnp.int32))
        else:
            self.state = self._restore_ssm_for()(
                self.state, jnp.asarray(s, jnp.int32), ssm_dev)
        t = int(snap["g0"])
        ls.shared_hits += n
        ls.slot_req[s] = req
        self._slot_busy[s] = True
        ls.slot_len[s] = n * self.blk_tokens
        ls.emitted[req.uid] = [t]
        ls.first_tok_t[req.uid] = time.perf_counter()
        self.tracer.stage_end(req.uid)
        ls.cur[s] = t
        self._check_done(ls, s, req)

    def _admit_cold_batch(self, ls: _LoopState, batch: List[Request],
                          slots: List[int], trunk: int, replays) -> None:
        """One vmapped-prefill dispatch admits the whole bucket."""
        fn = self._admit_for(trunk, len(batch))
        prompts = np.stack([r.prompt[:trunk] for r in batch])
        tr = self.tracer
        w0 = time.perf_counter()
        for r in batch:
            ls.admit_t.setdefault(r.uid, w0)
            tr.stage(r.uid, "admit", args={"mode": "cold",
                                           "bucket": trunk})
        blk = self.run_cfg.codec.cache_block
        self._ensure_free_pages(len(batch) * ((trunk // self.tp) // blk))
        t0 = tr.now()
        toks, self.state = fn(self.params, self.state,
                              jnp.asarray(prompts, jnp.int32),
                              jnp.asarray(slots, jnp.int32))
        ls.admit_dispatches += 1
        toks = np.asarray(toks)
        now = time.perf_counter()
        ls.admit_window_s.append(now - w0)
        tr.emit("admit_batch", cat="dispatch", pid=self.name,
                tid=ENGINE_LANE, t0=t0, t1=tr.now(),
                args={"bucket": trunk, "batch": len(batch)})
        for j, (req, s) in enumerate(zip(batch, slots)):
            ls.slot_req[s] = req
            self._slot_busy[s] = True
            ls.slot_len[s] = trunk
            tail = req.prompt[trunk:]
            if len(tail):
                ls.emitted[req.uid] = []
                replays.append((s, np.asarray(tail, np.int32)))
            else:
                t = int(toks[j, 0])
                ls.emitted[req.uid] = [t]
                ls.first_tok_t[req.uid] = now
                tr.stage_end(req.uid)
                ls.cur[s] = t
                self._check_done(ls, s, req)
        if self.admit_progress_cb is not None:
            self.admit_progress_cb(ls)   # trunk pages exist: stream them

    def _run_replays(self, ls: _LoopState, replays) -> None:
        """Feed all admitted slots' leftover prompt tokens through
        fused paged replay dispatches (heterogeneous lengths share the
        dispatch via the feed mask); each slot's first generated token
        comes from the step consuming its last prompt token."""
        rem = {s: tail for s, tail in replays}
        off = {s: 0 for s in rem}
        tr = self.tracer
        for s in rem:
            tr.stage(ls.slot_req[s].uid, "replay",
                     args={"tail_tokens": len(rem[s])})
        while rem:
            longest = max(len(rem[s]) - off[s] for s in rem)
            k = self._fuse_steps(longest)   # same policy as decode
            toks = np.zeros((k, self.n_slots, 1), np.int32)
            feed = np.zeros((k, self.n_slots), bool)
            for s in rem:
                t_s = rem[s][off[s]:off[s] + k]
                toks[:len(t_s), s, 0] = t_s
                feed[:len(t_s), s] = True
            if self.cache.lru:              # pool-pressure valve
                self._ensure_free_pages(sum(
                    self._page_growth(
                        ls.slot_len[s],
                        ls.slot_len[s] + min(k, len(rem[s]) - off[s]))
                    for s in rem))
            t0 = tr.now()
            w0 = time.perf_counter()
            seq, self.state = self._replay_for(k)(
                self.params, self.state, jnp.asarray(toks),
                jnp.asarray(feed))
            ls.replay_dispatches += 1
            seq = np.asarray(seq)
            now = time.perf_counter()
            ls.admit_window_s.append(now - w0)
            tr.emit("replay_window", cat="dispatch", pid=self.name,
                    tid=ENGINE_LANE, t0=t0, t1=tr.now(),
                    args={"steps": k, "slots": len(rem)})
            for s in list(rem):
                n_fed = min(k, len(rem[s]) - off[s])
                off[s] += n_fed
                ls.slot_len[s] += n_fed
                if off[s] == len(rem[s]):
                    req = ls.slot_req[s]
                    t = int(seq[n_fed - 1, s, 0])
                    ls.emitted[req.uid] = [t]
                    ls.first_tok_t[req.uid] = now
                    tr.stage_end(req.uid)
                    ls.cur[s] = t
                    self._check_done(ls, s, req)
                    del rem[s]
            self._track_peak(ls)
            if self.admit_progress_cb is not None:
                self.admit_progress_cb(ls)   # ring flushes filled pages

    def _admit_phase(self, ls: _LoopState) -> None:
        """Admit until slots or admissible requests run out: shared
        prefix hits first (queue order), then one batched cold
        dispatch per length bucket; finally replay leftover prompt
        tokens and index the new slots' full columns."""
        replays = []
        new_slots = []
        blocked = set()       # first-column keys cold-admitted now
        progress = True
        while progress:
            progress = False
            free = self._free_slot_ids(ls)
            if not free or not len(self.scheduler):
                break
            if self.prefix_sharing:       # pass A: prefix-cache hits
                hybrid = self.cfg.ssm is not None
                rest = deque()
                q = self.scheduler.queue
                while q and free:
                    req = q.popleft()
                    if hybrid:            # whole-prompt snapshot hits only
                        hit = self._snapshot_match(req.prompt)
                        if hit is not None:
                            s = free.pop(0)
                            self._admit_snapshot(ls, s, req, *hit)
                            new_slots.append(s)
                            progress = True
                        else:
                            rest.append(req)
                        continue
                    m, mkeys, warm = self._prefix_match_cols(req.prompt)
                    if m >= 1:
                        s = free.pop(0)
                        self._admit_shared(ls, s, req, m, mkeys, warm)
                        replays.append(
                            (s, np.asarray(req.prompt[m * self.blk_tokens:],
                                           np.int32)))
                        new_slots.append(s)
                        progress = True
                    else:
                        rest.append(req)
                while rest:
                    q.appendleft(rest.pop())
            free = self._free_slot_ids(ls)
            if free and len(self.scheduler):  # pass B: one cold bucket
                batch: List[Request] = []
                rest = deque()
                bucket = None
                q = self.scheduler.queue
                while q:
                    req = q.popleft()
                    b = self._bucket_of(len(req.prompt))
                    fk = (self._prefix_keys(req.prompt, 1)[0]
                          if self.prefix_sharing and
                          len(req.prompt) > self.blk_tokens else None)
                    ok = len(batch) < len(free)
                    if ok and fk is not None and fk in blocked:
                        ok = False    # dedupe: hits the index next round
                    if ok and bucket is not None and b != bucket:
                        ok = False
                    if ok:
                        bucket = b
                        batch.append(req)
                        if fk is not None:
                            blocked.add(fk)
                    else:
                        rest.append(req)
                while rest:
                    q.appendleft(rest.pop())
                if batch:
                    slots = free[:len(batch)]
                    self._admit_cold_batch(ls, batch, slots, bucket,
                                           replays)
                    new_slots.extend(slots)
                    progress = True
        self._run_replays(ls, replays)
        self._register_prefixes(
            [(s, ls.slot_req[s].prompt, ls.slot_len[s]) for s in new_slots])
        if self.prefix_sharing and self.cfg.ssm is not None:
            self._capture_snapshots(ls, new_slots)

    def _capture_snapshots(self, ls: _LoopState,
                           new_slots: List[int]) -> None:
        """Capture boundary SSM snapshots for tail-less page-aligned
        admissions (hybrids only): the recurrent state after consuming
        exactly the prompt, plus the first greedy token — the unit that
        makes a later identical prompt replay-free.  One device read of
        the SSM leaves per admission round, only when needed."""
        todo = []
        for s in new_slots:
            req = ls.slot_req[s]
            if req is None:
                continue
            ln = ls.slot_len[s]
            if (ln != len(req.prompt) or ln % self.blk_tokens != 0
                    or not ls.emitted.get(req.uid)):
                continue
            keys = self._prefix_keys(req.prompt, ln // self.blk_tokens)
            if self.cache.get_snapshot(keys[-1]) is not None:
                continue
            todo.append((s, keys[-1], int(ls.emitted[req.uid][0])))
        if not todo:
            return
        leaves = [np.asarray(a) for a in self.state.ssm]
        for s, key, g0 in todo:
            snap = SSMState(*(a[:, :, s].copy() for a in leaves))
            self.cache.put_snapshot(key, {"ssm": snap, "g0": g0})

    def _decode_window(self, ls: _LoopState) -> None:
        """One fused decode dispatch: K steps as one scan, K bounded by the
        earliest slot-finish event computed host-side from the known token
        budgets — so eviction and admission still happen at window
        boundaries and token streams are byte-identical to the
        one-dispatch-per-token loop.  An EOS / stop-string inside a window
        finishes that request at its match position (its slot idles until
        the window ends; other slots are independent, so no stream
        changes — only the eviction happens at the boundary)."""
        live = ls.live_slots()
        if not live:
            return
        bound = min(ls.slot_req[s].max_new_tokens - len(ls.emitted[
            ls.slot_req[s].uid]) for s in live)
        n_steps = self._fuse_steps(bound)
        if self.cache.lru:                  # pool-pressure valve
            self._ensure_free_pages(sum(
                self._page_growth(ls.slot_len[s], ls.slot_len[s] + n_steps)
                for s in live))
        tr = self.tracer
        t0 = tr.now()
        w0 = time.perf_counter()
        seq, self.state = self._decode_for(n_steps)(
            self.params, self.state, jnp.asarray(ls.cur))
        ls.steps += n_steps
        ls.dispatches += 1
        seq = np.asarray(seq)                     # (K, n_slots, 1)
        ls.decode_window_s.append(time.perf_counter() - w0)
        t1 = tr.now()
        tr.emit("decode_window", cat="dispatch", pid=self.name,
                tid=ENGINE_LANE, t0=t0, t1=t1,
                args={"steps": n_steps, "slots": len(live),
                      "weight_bytes": n_steps * self._weight_bytes[0]})
        if tr.enabled:
            for s in live:
                tr.request_span(ls.slot_req[s].uid, "decode", t0=t0, t1=t1,
                                args={"steps": n_steps})
        for t_i in range(n_steps):
            for s in live:
                req = ls.slot_req[s]
                ls.slot_len[s] += 1  # device appends even past host-done
                if ls.done[s]:
                    continue
                t = int(seq[t_i, s, 0])
                ls.emitted[req.uid].append(t)
                ls.cur[s] = t
                self._check_done(ls, s, req)
            self._track_peak(ls)

    def sync_metrics(self, ls: _LoopState,
                     wall: Optional[float] = None) -> MetricsRegistry:
        """Refresh this engine's metrics registry from the loop state —
        absolute values, safe to call repeatedly (the METRICS RPC calls
        it on every snapshot; ``_stats`` reads through it, which is what
        makes ``ServeStats`` a view over the registry)."""
        reg = self.registry
        c = reg.counter
        n_tok = sum(len(r.tokens) for r in ls.results.values())
        c("serve.requests").set(len(ls.results))
        c("serve.tokens").set(n_tok)
        c("serve.decode_steps").set(ls.steps)
        c("serve.decode_dispatches").set(ls.dispatches)
        c("serve.admit_dispatches").set(ls.admit_dispatches)
        c("serve.replay_dispatches").set(ls.replay_dispatches)
        c("serve.admit_compiles").set(self.n_admit_compiles)
        c("serve.shared_page_hits").set(ls.shared_hits)
        reg.gauge("serve.peak_pages", agg="max").set(ls.peak_pages)
        reg.gauge("serve.pool_bytes").set(
            engine.paged_state_nbytes(self.state))
        if wall is not None:
            reg.gauge("serve.wall_s", agg="max").set(wall)
        for k, v in self.cache.counters().items():
            c(f"cache.{k}").set(v)
        reg.gauge("weights.bytes_per_step", agg="max").set(
            self._weight_bytes[0])
        reg.gauge("weights.raw_bytes_per_step", agg="max").set(
            self._weight_bytes[1])
        reg.gauge("weights.compressed", agg="max").set(
            int(self.compress_weights))
        c("weights.hbm_bytes").set(ls.steps * self._weight_bytes[0])
        reg.histogram("latency.request_s").set_values(
            [r.latency_s for r in ls.results.values()])
        reg.histogram("latency.ttft_s").set_values(list(ls.ttft_s.values()))
        reg.histogram("latency.admit_window_s").set_values(
            ls.admit_window_s)
        reg.histogram("latency.decode_window_s").set_values(
            ls.decode_window_s)
        return reg

    def _stats(self, ls: _LoopState, wall: float) -> ServeStats:
        stored_pb, raw_pb = cache_mod.page_bytes(self.cfg, self.run_cfg)
        reg = self.sync_metrics(ls, wall)
        v = reg.value
        lat = summarize_latencies(reg.values_of("latency.request_s"))
        ttft = summarize_latencies(reg.values_of("latency.ttft_s"))
        admitw = summarize_latencies(reg.values_of("latency.admit_window_s"))
        decw = summarize_latencies(reg.values_of("latency.decode_window_s"))
        n_req, n_tok = v("serve.requests"), v("serve.tokens")
        steps = v("serve.decode_steps")
        return ServeStats(
            n_requests=n_req, n_tokens=n_tok,
            decode_steps=steps,
            n_dispatches=v("serve.decode_dispatches"),
            n_admit_dispatches=v("serve.admit_dispatches"),
            n_replay_dispatches=v("serve.replay_dispatches"),
            n_admit_compiles=v("serve.admit_compiles"),
            shared_page_hits=v("serve.shared_page_hits"),
            wall_s=wall,
            requests_per_s=n_req / max(wall, 1e-9),
            tokens_per_s=n_tok / max(wall, 1e-9),
            peak_pages=v("serve.peak_pages"),
            peak_cache_bytes=v("serve.peak_pages") * stored_pb,
            peak_cache_raw_bytes=v("serve.peak_pages") * raw_pb,
            mean_latency_s=lat["mean"],
            latency_p50_s=lat["p50"], latency_p95_s=lat["p95"],
            decode_backend=kernel_ops.resolve_decode_backend(
                self.run_cfg.codec),
            cache_hot_hits=v("cache.hot_hits"),
            cache_spilled_pages=v("cache.spilled_pages"),
            cache_spilled_bytes=v("cache.spilled_bytes"),
            cache_fetched_pages=v("cache.fetched_pages"),
            cache_fetched_bytes=v("cache.fetched_bytes"),
            cache_reprefill_cols=v("cache.reprefill_cols"),
            cache_evicted_cols=v("cache.evicted_cols"),
            weights_compressed=self.compress_weights,
            weight_backend=self.weight_backend,
            weight_bytes_per_step=v("weights.bytes_per_step"),
            weight_raw_bytes_per_step=v("weights.raw_bytes_per_step"),
            ttft_mean_s=ttft["mean"], ttft_p50_s=ttft["p50"],
            ttft_p95_s=ttft["p95"],
            admit_window_mean_s=admitw["mean"],
            decode_window_mean_s=decw["mean"],
            inter_token_mean_s=(sum(ls.decode_window_s) / steps
                                if steps else 0.0))

    def run(self, requests: List[Request]
            ) -> Tuple[List[RequestResult], ServeStats]:
        """Serve a request list to completion; returns results in input
        order plus engine-level stats.  See ``_decode_window`` for the
        fused-dispatch / window-boundary semantics."""
        uids = [r.uid for r in requests]
        if len(set(uids)) != len(uids):
            raise ValueError("request uids must be unique (token streams "
                             "are keyed by uid)")
        for r in requests:
            self.scheduler.submit(r)
        ls = self._new_loop()
        t0 = time.perf_counter()
        while len(self.scheduler) or ls.live_slots():
            self._admit_phase(ls)
            self._track_peak(ls)
            self._finish_ready(ls)
            self._decode_window(ls)
            self._finish_ready(ls)
        wall = time.perf_counter() - t0
        stats = self._stats(ls, wall)
        return [ls.results[r.uid] for r in requests], stats


# ---------------------------------------------------------------------------
# demo helpers (shared by launch/serve.py, examples/serve_lm.py)
# ---------------------------------------------------------------------------

def demo_serving_setup(run: RunConfig, vocab_size: int, tp: int,
                       prompt_len: int, new_tokens: int, n_requests: int,
                       seed: int = 0):
    """(run', max_len, requests) for a demo request stream.

    Shrinks the cache block so the paged pool is exercised at demo prompt
    sizes and generates a mixed-length queue with SHARED PREFIXES: two base
    prompts cycle, repeats of a base reuse its exact tokens, and budgets
    are staggered (long-prompt requests run longer).  Zero-ref prefix
    columns stay RETAINED in the tiered PageCache, so even repeats that
    admit after the original released still hit the hot tier (watch
    ``shared_page_hits`` and ``cache_hot_hits``).
    """
    rng = np.random.default_rng(seed)
    blk = max(4, (prompt_len // tp) // 4)
    run = dataclasses.replace(
        run, codec=dataclasses.replace(run.codec, cache_block=blk))
    max_len = prompt_len + 2 * new_tokens + blk * tp
    lens = [prompt_len, max(tp, prompt_len // 2 // tp * tp)]
    bases = [rng.integers(0, vocab_size, (n,)).astype(np.int32)
             for n in lens]
    reqs = [Request(uid=i, prompt=bases[i % len(bases)],
                    max_new_tokens=new_tokens * (2 if i % 2 == 0 else 1))
            for i in range(n_requests)]
    return run, max_len, reqs


def format_stats(st: ServeStats) -> str:
    """Four-line human summary of a serving run (demo output)."""
    return (f"{st.n_requests} reqs, {st.decode_steps} decode steps in "
            f"{st.n_dispatches} dispatches ({st.decode_backend} backend), "
            f"{st.requests_per_s:.2f} req/s, {st.tokens_per_s:.1f} tok/s "
            f"(incl. compile)\n"
            f"admission: {st.n_admit_dispatches} batched prefill dispatches "
            f"+ {st.n_replay_dispatches} fused replay dispatches "
            f"({st.n_admit_compiles} admit compiles), "
            f"{st.shared_page_hits} shared-prefix page hits\n"
            f"paged cache peak {st.peak_pages} pages: "
            f"{st.peak_cache_bytes / 1e3:.1f} kB stored / "
            f"{st.peak_cache_raw_bytes / 1e3:.1f} kB raw "
            f"({st.cache_ratio:.2f}x); mean request latency "
            f"{st.mean_latency_s * 1e3:.0f} ms (incl. each bucket's "
            f"first-use compile)\n"
            f"retention: {st.cache_hot_hits} hot-tier re-acquires, "
            f"{st.cache_spilled_pages} pages spilled "
            f"({st.cache_spilled_bytes / 1e3:.1f} kB), "
            f"{st.cache_fetched_pages} fetched back "
            f"({st.cache_fetched_bytes / 1e3:.1f} kB), "
            f"{st.cache_evicted_cols} columns evicted, "
            f"{st.cache_reprefill_cols} re-prefills\n"
            f"weights: "
            f"{'packed' if st.weights_compressed else 'raw bf16'} "
            f"({st.weight_backend} backend), "
            f"{st.weight_bytes_per_step / 1e3:.1f} kB HBM per decode step / "
            f"{st.weight_raw_bytes_per_step / 1e3:.1f} kB raw "
            f"({st.weight_ratio:.2f}x)")
