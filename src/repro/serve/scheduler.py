"""Continuous-batching request scheduler over the paged LEXI-compressed
cache (the serving half of the ROADMAP north star).

``ServeEngine`` owns a model-parallel mesh, the jitted device functions and
one ``PagedState``; ``RequestScheduler`` is the admission queue.  The loop:

    while work:
        admit   — pop queued requests into free slots: jitted prefill(B=1)
                  on the floor-of-tp prompt trunk + exact decode-step
                  replay of the (< tp) tail (prompt bucketing: any length
                  >= tp admits) → ``insert_sequence`` (compressed blocks
                  copy into pages)
        step    — ONE dispatch runs K fused ``paged_decode_step``s as a
                  ``lax.scan`` (K bounded by the earliest budget-finish
                  event, so streams are byte-identical to stepping one
                  token at a time), one greedy token per active slot/step
        evict   — slots that hit their token budget or emit ``eos_id``
                  release their pages (``release_slots``) at the window
                  boundary and free up for the next admission

Device state crosses jit boundaries as global arrays with one leading
"model"-sharded axis per leaf (each shard's page pool / page table / ring
is independent state, so the global view is simply the stack of per-shard
views).  The wrapper functions squeeze/unsqueeze that axis at the
shard_map boundary.

Constraints (documented, validated in ``submit``):
  * decoder-only families (dense / MoE / SSM / hybrid); no enc-dec.
  * prompt lengths >= the model-parallel degree (any length admits via
    bucketing; the sequence-sharded trunk needs one slot per shard).
  * prompt_len + max_new_tokens <= max_len (page-pool capacity).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig, RunConfig
from repro.core import collectives as cl
from repro.kernels import ops as kernel_ops
from repro.models import cache as cache_mod
from repro.models import lm, params as PM
from . import engine


@dataclasses.dataclass
class Request:
    """One generation request (greedy decoding, token budget + optional
    EOS).  ``eos_id`` overrides the engine-level default when set."""
    uid: int
    prompt: np.ndarray               # (S,) int32, S >= tp (any length)
    max_new_tokens: int
    eos_id: Optional[int] = None


@dataclasses.dataclass
class RequestResult:
    uid: int
    prompt_len: int
    tokens: List[int]                # generated tokens (incl. EOS if hit)
    latency_s: float                 # admit (incl. own prefill) -> finish
    stop_reason: str = "budget"      # budget | eos


@dataclasses.dataclass
class ServeStats:
    n_requests: int
    n_tokens: int
    decode_steps: int                # total decode steps executed
    n_dispatches: int                # device dispatches issuing those steps
    wall_s: float
    requests_per_s: float
    tokens_per_s: float
    peak_pages: int                  # pages in use, summed over shards/layers
    peak_cache_bytes: int            # stored bytes of those pages
    peak_cache_raw_bytes: int        # bf16 bytes of the same pages
    mean_latency_s: float
    latency_p50_s: float
    latency_p95_s: float
    decode_backend: str              # resolved pallas | interpret | jax

    @property
    def cache_ratio(self) -> float:
        return self.peak_cache_raw_bytes / max(self.peak_cache_bytes, 1)


class RequestScheduler:
    """FIFO admission queue with capacity validation.

    Prompt lengths need not be multiples of tp: admission buckets each
    prompt to its floor multiple of tp for the sequence-sharded trunk and
    replays the (< tp) leftover tokens through exact single-token decode
    steps, so any length >= tp is accepted.
    """

    def __init__(self, tp: int, max_len: int):
        self.tp = tp
        self.max_len = max_len
        self.queue: deque[Request] = deque()

    def submit(self, req: Request) -> None:
        s = len(req.prompt)
        if s < self.tp:
            raise ValueError(
                f"prompt length {s} must be >= tp={self.tp} "
                "(the sequence-sharded trunk needs one slot per shard)")
        if s + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request needs {s + req.max_new_tokens} tokens > "
                f"max_len={self.max_len}")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.queue.append(req)

    def pop(self) -> Optional[Request]:
        return self.queue.popleft() if self.queue else None

    def __len__(self) -> int:
        return len(self.queue)


class ServeEngine:
    """Continuous-batching inference engine (one replica, model-parallel)."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, *, tp: int = 1,
                 n_slots: int = 4, max_len: int = 256, params=None,
                 seed: int = 0, eos_id: Optional[int] = None,
                 max_fuse_steps: int = 32):
        if cfg.encdec or cfg.frontend != "none":
            raise ValueError("continuous batching covers decoder-only, "
                             "text-frontend architectures")
        if max_fuse_steps < 1:
            raise ValueError("max_fuse_steps must be >= 1")
        self.cfg, self.run_cfg, self.tp = cfg, run, tp
        self.n_slots, self.max_len = n_slots, max_len
        self.eos_id = eos_id
        self.max_fuse_steps = max_fuse_steps
        mesh_cfg = MeshConfig(data=1, model=tp, pod=1)
        self.mesh = jax.make_mesh((1, tp), ("data", "model"))
        self.table = lm.lm_table(cfg, mesh_cfg, run)
        self.dims = lm.lm_fsdp_dims(self.table)
        self.params = (params if params is not None
                       else PM.init_params(self.table, jax.random.key(seed)))
        self._pspecs = PM.param_pspecs(self.table)
        self.scheduler = RequestScheduler(tp, max_len)

        shard = engine.empty_paged_state(cfg, run, n_slots, max_len, tp)
        self._sspec = jax.tree_util.tree_map(lambda a: P("model"), shard)
        # global view: one leading model-sharded axis, per-shard copies
        self.state = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (tp,) + a.shape), shard)

        self._admit_cache: Dict[int, object] = {}
        self._decode_cache: Dict[int, object] = {}
        self._release = jax.jit(cl.shmap(
            self._release_fn, self.mesh, (self._sspec, P(None)),
            self._sspec))

    # -- shard_map bodies --------------------------------------------------

    @staticmethod
    def _squeeze(st_g):
        return jax.tree_util.tree_map(lambda a: a[0], st_g)

    @staticmethod
    def _unsqueeze(st):
        return jax.tree_util.tree_map(lambda a: a[None], st)

    def _release_fn(self, st_g, mask):
        return self._unsqueeze(engine.release_slots(self._squeeze(st_g),
                                                    mask))

    def _decode_for(self, n_steps: int):
        """One jitted K-step fused decode per distinct K.

        The K decode steps run as one ``lax.scan`` inside one dispatch, so
        host overhead amortizes over K tokens; the scanned body is exactly
        ``paged_decode_step`` + greedy, so the emitted (K, S, 1) token block
        is byte-identical to K single-step dispatches.
        """
        fn = self._decode_cache.get(n_steps)
        if fn is not None:
            return fn

        def decode(pp, st_g, toks):
            st = self._squeeze(st_g)

            def body(carry, _):
                st_c, tok = carry
                logits, st_c = engine.paged_decode_step(
                    self.cfg, self.run_cfg, pp, self.dims, st_c, tok,
                    self.tp)
                tok = engine.greedy_token(self.cfg, logits, self.tp)
                return (st_c, tok), tok

            (st, _), seq = jax.lax.scan(body, (st, toks), None,
                                        length=n_steps)
            return seq, self._unsqueeze(st)

        fn = jax.jit(cl.shmap(
            decode, self.mesh,
            (self._pspecs, self._sspec, P(None, None)),
            (P(None, None, None), self._sspec)))
        self._decode_cache[n_steps] = fn
        return fn

    def _fuse_steps(self, bound: int) -> int:
        """Decode steps to fuse into the next dispatch: the largest power
        of two <= the earliest slot-finish event (so eviction/admission
        still happen at window boundaries and the jit cache stays at
        O(log max_new_tokens) entries), capped by ``max_fuse_steps``."""
        k = 1 << (max(bound, 1).bit_length() - 1)
        return min(k, self.max_fuse_steps)

    def _admit_for(self, prompt_len: int):
        """One jitted admit per distinct prompt length (static shapes).

        Prompt bucketing: the sequence-sharded trunk runs on the floor
        multiple of tp; the (< tp) leftover prompt tokens replay through
        exact fixed-batch decode steps before the sequence is inserted —
        identical numerics to an aligned prefill at every position, for
        every architecture (attention, SSM, MoE), with no masking."""
        fn = self._admit_cache.get(prompt_len)
        if fn is not None:
            return fn
        s0 = (prompt_len // self.tp) * self.tp
        tail = prompt_len - s0

        def admit(pp, st_g, prompt, slot):
            st = self._squeeze(st_g)
            logits, d = engine.prefill(self.cfg, self.run_cfg, pp, self.dims,
                                       prompt[:, :s0], self.max_len, self.tp)
            for j in range(tail):                    # static, < tp
                logits, d = engine.decode_step(
                    self.cfg, self.run_cfg, pp, self.dims, d,
                    prompt[:, s0 + j:s0 + j + 1], self.tp)
            tok = engine.greedy_token(self.cfg, logits, self.tp)
            st = engine.insert_sequence(self.cfg, self.run_cfg, st, d, slot,
                                        prompt_len, self.tp)
            return tok, self._unsqueeze(st)

        fn = jax.jit(cl.shmap(
            admit, self.mesh,
            (self._pspecs, self._sspec, P(None, None), P()),
            (P(None, None), self._sspec)))
        self._admit_cache[prompt_len] = fn
        return fn

    # -- metrics -----------------------------------------------------------

    def _pages_for_length(self, length: int) -> int:
        """Pages one sequence of ``length`` tokens occupies (all layers,
        summed over shards) — pure host arithmetic, mirroring the device's
        flush rule (a page exists exactly per full block of owned slots),
        so the serving loop never syncs device state for its metrics."""
        if self.cfg.n_heads == 0 or length <= 0:
            return 0
        blk = self.run_cfg.codec.cache_block
        per_shard = sum(
            max((length - 1 - t) // self.tp + 1, 0) // blk
            for t in range(self.tp))
        return per_shard * self.cfg.n_layers

    def _pages_in_use(self) -> int:
        """Device-truth page count (syncs; for tests/inspection only)."""
        if self.state.kv is None:
            return 0
        return int(np.asarray(self.state.kv.page_used).sum())

    # -- the serving loop --------------------------------------------------

    def _req_eos(self, req: Request) -> Optional[int]:
        return req.eos_id if req.eos_id is not None else self.eos_id

    def run(self, requests: List[Request]
            ) -> Tuple[List[RequestResult], ServeStats]:
        """Serve a request list to completion; returns results in input
        order plus engine-level stats.

        Decode steps are fused: each dispatch runs K steps as one scan,
        where K is bounded by the earliest slot-finish event computed
        host-side from the known token budgets — so eviction and admission
        still happen at window boundaries and token streams are
        byte-identical to the one-dispatch-per-token loop.  An EOS inside a
        window finishes that request at its EOS position (its slot idles
        until the window ends; other slots are independent, so no stream
        changes — only the eviction happens at the boundary).
        """
        uids = [r.uid for r in requests]
        if len(set(uids)) != len(uids):
            raise ValueError("request uids must be unique (token streams "
                             "are keyed by uid)")
        for r in requests:
            self.scheduler.submit(r)
        slot_req: List[Optional[Request]] = [None] * self.n_slots
        done = [False] * self.n_slots     # finished, awaiting eviction
        reason = [""] * self.n_slots
        emitted: Dict[int, List[int]] = {}
        admit_t: Dict[int, float] = {}
        results: Dict[int, RequestResult] = {}
        cur = np.zeros((self.n_slots, 1), np.int32)
        slot_len = [0] * self.n_slots     # host mirror of cache lengths
        steps = 0
        dispatches = 0
        peak_pages = 0
        stored_pb, raw_pb = cache_mod.page_bytes(self.cfg, self.run_cfg)
        t0 = time.perf_counter()

        def track_peak():
            nonlocal peak_pages
            pages = sum(self._pages_for_length(slot_len[s])
                        for s, r in enumerate(slot_req) if r is not None)
            peak_pages = max(peak_pages, pages)

        def check_done(s: int, req: Request) -> None:
            toks = emitted[req.uid]
            eos = self._req_eos(req)
            if eos is not None and toks and toks[-1] == eos:
                done[s], reason[s] = True, "eos"
            elif len(toks) >= req.max_new_tokens:
                done[s], reason[s] = True, "budget"

        def finish_ready():
            mask = np.zeros((self.n_slots,), bool)
            for s, req in enumerate(slot_req):
                if req is None or not done[s]:
                    continue
                now = time.perf_counter()
                results[req.uid] = RequestResult(
                    uid=req.uid, prompt_len=len(req.prompt),
                    tokens=emitted[req.uid][:req.max_new_tokens],
                    latency_s=now - admit_t[req.uid],
                    stop_reason=reason[s])
                slot_req[s] = None
                done[s], reason[s] = False, ""
                mask[s] = True
            if mask.any():
                self.state = self._release(self.state, jnp.asarray(mask))

        while len(self.scheduler) or any(r is not None for r in slot_req):
            # admit queued requests into free slots
            for s in range(self.n_slots):
                if slot_req[s] is not None or not len(self.scheduler):
                    continue
                req = self.scheduler.pop()
                fn = self._admit_for(len(req.prompt))
                prompt = jnp.asarray(req.prompt, jnp.int32)[None]
                admit_t[req.uid] = time.perf_counter()
                tok, self.state = fn(self.params, self.state, prompt,
                                     jnp.asarray(s, jnp.int32))
                t = int(np.asarray(tok)[0, 0])
                emitted[req.uid] = [t]
                cur[s] = t
                slot_req[s] = req
                slot_len[s] = len(req.prompt)
                check_done(s, req)    # budget-1 / instant-EOS end at admit
            track_peak()
            finish_ready()
            live = [s for s, r in enumerate(slot_req) if r is not None]
            if not live:
                continue

            # one dispatch covers K steps; K bounded by the earliest finish
            bound = min(slot_req[s].max_new_tokens - len(emitted[
                slot_req[s].uid]) for s in live)
            n_steps = self._fuse_steps(bound)
            seq, self.state = self._decode_for(n_steps)(
                self.params, self.state, jnp.asarray(cur))
            steps += n_steps
            dispatches += 1
            seq = np.asarray(seq)                     # (K, n_slots, 1)
            for t_i in range(n_steps):
                for s in live:
                    req = slot_req[s]
                    slot_len[s] += 1  # device appends even past host-done
                    if done[s]:
                        continue
                    t = int(seq[t_i, s, 0])
                    emitted[req.uid].append(t)
                    cur[s] = t
                    check_done(s, req)
                track_peak()
            finish_ready()

        wall = time.perf_counter() - t0
        n_tok = sum(len(r.tokens) for r in results.values())
        lats = sorted(r.latency_s for r in results.values())
        pct = (lambda q: float(np.percentile(lats, q)) if lats else 0.0)
        stats = ServeStats(
            n_requests=len(results), n_tokens=n_tok, decode_steps=steps,
            n_dispatches=dispatches, wall_s=wall,
            requests_per_s=len(results) / max(wall, 1e-9),
            tokens_per_s=n_tok / max(wall, 1e-9),
            peak_pages=peak_pages,
            peak_cache_bytes=peak_pages * stored_pb,
            peak_cache_raw_bytes=peak_pages * raw_pb,
            mean_latency_s=float(np.mean(lats)) if lats else 0.0,
            latency_p50_s=pct(50), latency_p95_s=pct(95),
            decode_backend=kernel_ops.resolve_decode_backend(
                self.run_cfg.codec))
        return [results[r.uid] for r in requests], stats


# ---------------------------------------------------------------------------
# demo helpers (shared by launch/serve.py, examples/serve_lm.py)
# ---------------------------------------------------------------------------

def demo_serving_setup(run: RunConfig, vocab_size: int, tp: int,
                       prompt_len: int, new_tokens: int, n_requests: int,
                       seed: int = 0):
    """(run', max_len, requests) for a demo request stream.

    Shrinks the cache block so the paged pool is exercised at demo prompt
    sizes and generates a mixed-length queue (two admitted prompt shapes).
    """
    rng = np.random.default_rng(seed)
    blk = max(4, (prompt_len // tp) // 4)
    run = dataclasses.replace(
        run, codec=dataclasses.replace(run.codec, cache_block=blk))
    max_len = prompt_len + new_tokens + blk * tp
    lens = [prompt_len, max(tp, prompt_len // 2 // tp * tp)]
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, vocab_size,
                                        (lens[i % len(lens)],)
                                        ).astype(np.int32),
                    max_new_tokens=new_tokens)
            for i in range(n_requests)]
    return run, max_len, reqs


def format_stats(st: ServeStats) -> str:
    """Two-line human summary of a serving run (demo output)."""
    return (f"{st.n_requests} reqs, {st.decode_steps} decode steps in "
            f"{st.n_dispatches} dispatches ({st.decode_backend} backend), "
            f"{st.requests_per_s:.2f} req/s, {st.tokens_per_s:.1f} tok/s "
            f"(incl. compile)\n"
            f"paged cache peak {st.peak_pages} pages: "
            f"{st.peak_cache_bytes / 1e3:.1f} kB stored / "
            f"{st.peak_cache_raw_bytes / 1e3:.1f} kB raw "
            f"({st.cache_ratio:.2f}x); mean request latency "
            f"{st.mean_latency_s * 1e3:.0f} ms (incl. each prompt "
            f"length's first-use compile)")
