"""Zero-device-sync tracing + unified metrics for the serving stack.

Two cooperating pieces, both pure host-side (no jax imports, no device
syncs — everything is stamped with monotonic clocks at dispatch
boundaries that already exist):

* :class:`MetricsRegistry` — a single namespace of :class:`Counter` /
  :class:`Gauge` / :class:`Histogram` instruments.  ``ServeStats`` /
  ``DisaggStats`` / ``TransportStats`` are *views* built over registries
  (``repro.serve.scheduler``, ``repro.serve.disagg``,
  ``repro.serve.transport``); byte-flow metering — LinkModel link bytes,
  weight-HBM bytes, PageCache tier traffic — lands in the same
  namespace.  ``snapshot()`` emits a versioned, JSON-serializable dict
  (the ``METRICS`` RPC payload of ``repro.serve.net``), and
  :meth:`MetricsRegistry.merge` folds per-replica snapshots into fleet
  totals (counters sum, gauges aggregate per their hint, histogram
  values concatenate).

* :class:`Tracer` — per-request lifecycle spans::

      submit -> queue -> admit(bucket, shared/cold/warm/snapshot)
             -> replay -> [export -> wire -> import]
             -> decode windows -> finish(stop_reason)

  recorded as *complete* events ("ph": "X") with ``perf_counter_ns``
  timestamps, exportable as Chrome trace-event JSON (Perfetto-loadable)
  via :meth:`Tracer.to_chrome_trace`.  A disabled tracer (the default)
  turns every call into an early-out no-op, so the decode hot loop pays
  nothing when telemetry is off.

Span addressing: ``pid`` is an engine name (``serve``, ``prefill0``,
``decode1`` — mapped to integer pids with ``process_name`` metadata on
export); ``tid`` 0 is the engine lane (admission batches, replay and
decode windows, cache-tier traffic), and request spans live on
``tid = uid + 1`` with the uid repeated in ``args``.
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence

import numpy as np

SNAPSHOT_VERSION = 1

# tid of the per-engine lane (dispatch-scoped spans); request spans use
# uid + 1 so uid 0 never collides with the lane
ENGINE_LANE = 0


# ---------------------------------------------------------------------------
# shared stats helpers (the dedup target: ServeStats / DisaggStats /
# TransportStats each hand-rolled these)
# ---------------------------------------------------------------------------


def summarize_latencies(values: Sequence[float]) -> Dict[str, float]:
    """mean/p50/p95 of a latency sample, 0.0 on empty — the one
    percentile helper behind every stats dataclass in the serving
    stack."""
    lats = sorted(float(v) for v in values)
    if not lats:
        return {"mean": 0.0, "p50": 0.0, "p95": 0.0}
    return {"mean": float(np.mean(lats)),
            "p50": float(np.percentile(lats, 50)),
            "p95": float(np.percentile(lats, 95))}


def sum_counters(dicts: Iterable[Dict[str, Any]],
                 keys: Optional[Sequence[str]] = None) -> Dict[str, Any]:
    """Fold per-replica counter dicts into totals (fleet aggregation)."""
    dicts = list(dicts)
    if keys is None:
        keys = sorted({k for d in dicts for k in d})
    out: Dict[str, Any] = {}
    for k in keys:
        out[k] = sum(d.get(k, 0) for d in dicts)
    return out


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic-ish numeric cell (int or float).  ``set`` exists so
    stats views can refresh absolute values from loop state."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def set(self, v) -> None:
        self.value = v


class Gauge:
    """Point-in-time value with a fleet-merge hint: ``sum`` (e.g. live
    slots), ``max`` (e.g. peak pages — every replica reports its own
    peak), or ``last``."""

    __slots__ = ("name", "value", "agg")

    def __init__(self, name: str, agg: str = "sum"):
        if agg not in ("sum", "max", "last"):
            raise ValueError(f"unknown gauge agg {agg!r}")
        self.name = name
        self.value = 0
        self.agg = agg

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Raw-sample histogram (latency distributions are small here:
    one value per request / dispatch, not per token)."""

    __slots__ = ("name", "values")

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    def set_values(self, values: Sequence[float]) -> None:
        self.values = [float(v) for v in values]

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def sum(self) -> float:
        return float(sum(self.values))

    def summary(self) -> Dict[str, float]:
        return summarize_latencies(self.values)

    def percentile(self, q: float) -> float:
        return (float(np.percentile(sorted(self.values), q))
                if self.values else 0.0)


class MetricsRegistry:
    """Get-or-create namespace of instruments.  Names are dotted
    (``serve.*``, ``cache.*``, ``weights.*``, ``transport.*``,
    ``link.*``, ``latency.*``); a name is bound to one instrument kind
    for the registry's lifetime."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, kind, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = kind(name, **kw)
        elif not isinstance(m, kind):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"requested {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str, agg: str = "sum") -> Gauge:
        return self._get(name, Gauge, agg=agg)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def value(self, name: str, default=0):
        m = self._metrics.get(name)
        return default if m is None or isinstance(m, Histogram) else m.value

    def values_of(self, name: str) -> List[float]:
        m = self._metrics.get(name)
        return list(m.values) if isinstance(m, Histogram) else []

    def snapshot(self) -> Dict[str, Any]:
        """Versioned, JSON-serializable dump — the METRICS RPC payload."""
        counters: Dict[str, Any] = {}
        gauges: Dict[str, Any] = {}
        hists: Dict[str, Any] = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                counters[name] = m.value
            elif isinstance(m, Gauge):
                gauges[name] = {"value": m.value, "agg": m.agg}
            else:
                hists[name] = {"values": list(m.values)}
        return {"version": SNAPSHOT_VERSION, "counters": counters,
                "gauges": gauges, "hists": hists}

    def load(self, snap: Dict[str, Any]) -> "MetricsRegistry":
        """Populate this registry from a snapshot dict (inverse of
        :meth:`snapshot`; used on merged fleet totals)."""
        if snap.get("version") != SNAPSHOT_VERSION:
            raise ValueError(f"metrics snapshot v{snap.get('version')}, "
                             f"this side v{SNAPSHOT_VERSION}")
        for name, v in snap.get("counters", {}).items():
            self.counter(name).set(v)
        for name, g in snap.get("gauges", {}).items():
            self.gauge(name, agg=g.get("agg", "sum")).set(g["value"])
        for name, h in snap.get("hists", {}).items():
            self.histogram(name).set_values(h["values"])
        return self

    @staticmethod
    def merge(snapshots: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
        """Fold per-replica snapshots into fleet totals: counters sum,
        gauges follow their agg hint, histogram samples concatenate."""
        out = {"version": SNAPSHOT_VERSION, "counters": {},
               "gauges": {}, "hists": {}}
        for snap in snapshots:
            if snap.get("version") != SNAPSHOT_VERSION:
                raise ValueError(
                    f"cannot merge metrics snapshot v{snap.get('version')} "
                    f"with v{SNAPSHOT_VERSION}")
            for name, v in snap.get("counters", {}).items():
                out["counters"][name] = out["counters"].get(name, 0) + v
            for name, g in snap.get("gauges", {}).items():
                cur = out["gauges"].get(name)
                if cur is None:
                    out["gauges"][name] = dict(g)
                elif g.get("agg", "sum") == "max":
                    cur["value"] = max(cur["value"], g["value"])
                elif g.get("agg", "sum") == "last":
                    cur["value"] = g["value"]
                else:
                    cur["value"] = cur["value"] + g["value"]
            for name, h in snap.get("hists", {}).items():
                cur = out["hists"].setdefault(name, {"values": []})
                cur["values"].extend(h["values"])
        return out


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


class Tracer:
    """Request-lifecycle span recorder.  All methods are no-ops when
    ``enabled`` is False; when on, each call is a dict append plus a
    ``perf_counter_ns`` read — never a device sync.

    Two layers of API:

    * ``emit`` / ``span_begin`` / ``span_end`` — raw complete-span
      plumbing for dispatch-scoped (engine-lane) events.
    * ``request_begin`` / ``stage`` / ``stage_end`` / ``request_end`` —
      per-uid lifecycle: one root ``request`` span per uid, with at most
      one open stage at a time (``stage`` auto-closes the previous one,
      ``request_end`` closes any straggler, so a request that finishes
      at admission still yields a well-nested tree).
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._t0 = time.perf_counter_ns()
        self.events: List[Dict[str, Any]] = []
        # uid -> (t_start_ns, pid, args) of the open root span
        self._open_req: Dict[int, Any] = {}
        # uid -> (name, t_start_ns, pid, args) of the open stage
        self._open_stage: Dict[int, Any] = {}

    # -- clock -------------------------------------------------------------

    def now(self) -> int:
        """ns since tracer start; 0 when disabled (callers may stamp
        t0/t1 unconditionally around a dispatch)."""
        if not self.enabled:
            return 0
        return time.perf_counter_ns() - self._t0

    # -- raw spans ---------------------------------------------------------

    def emit(self, name: str, *, cat: str, pid: str, tid: int,
             t0: int, t1: int,
             args: Optional[Dict[str, Any]] = None) -> None:
        """Record a complete span over [t0, t1] (ns, from :meth:`now`)."""
        if not self.enabled:
            return
        self.events.append({"name": name, "cat": cat, "pid": pid,
                            "tid": tid, "ts": t0, "dur": max(0, t1 - t0),
                            "args": dict(args or {})})

    def instant(self, name: str, *, cat: str, pid: str, tid: int,
                args: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        self.emit(name, cat=cat, pid=pid, tid=tid, t0=self.now(),
                  t1=self.now(), args=args)

    # -- request lifecycle -------------------------------------------------

    def request_begin(self, uid: int, *, pid: str,
                      args: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled or uid in self._open_req:
            return
        self._open_req[uid] = (self.now(), pid, dict(args or {}))

    def stage(self, uid: int, name: str, *, pid: Optional[str] = None,
              args: Optional[Dict[str, Any]] = None) -> None:
        """Open stage ``name`` for ``uid``, closing any previous stage
        at the same instant (stages are sequential per request)."""
        if not self.enabled or uid not in self._open_req:
            return
        now = self.now()
        self._close_stage(uid, now)
        if pid is None:
            pid = self._open_req[uid][1]
        self._open_stage[uid] = (name, now, pid, dict(args or {}))

    def stage_end(self, uid: int,
                  args: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        self._close_stage(uid, self.now(), args)

    def _close_stage(self, uid: int, t1: int,
                     args: Optional[Dict[str, Any]] = None) -> None:
        open_stage = self._open_stage.pop(uid, None)
        if open_stage is None:
            return
        name, t0, pid, st_args = open_stage
        if args:
            st_args.update(args)
        st_args.setdefault("uid", uid)
        self.emit(name, cat="stage", pid=pid, tid=uid + 1,
                  t0=t0, t1=t1, args=st_args)

    def request_span(self, uid: int, name: str, *, t0: int, t1: int,
                     args: Optional[Dict[str, Any]] = None) -> None:
        """Complete span on a request's lane (decode windows, wire
        transfers measured around a call)."""
        if not self.enabled or uid not in self._open_req:
            return
        pid = self._open_req[uid][1]
        a = dict(args or {})
        a.setdefault("uid", uid)
        self.emit(name, cat="stage", pid=pid, tid=uid + 1,
                  t0=t0, t1=t1, args=a)

    def request_end(self, uid: int,
                    args: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        open_req = self._open_req.pop(uid, None)
        if open_req is None:
            return
        now = self.now()
        self._close_stage(uid, now)
        t0, pid, req_args = open_req
        if args:
            req_args.update(args)
        req_args["uid"] = uid
        self.emit("request", cat="request", pid=pid, tid=uid + 1,
                  t0=t0, t1=now, args=req_args)

    def open_requests(self) -> List[int]:
        return sorted(self._open_req)

    # -- export ------------------------------------------------------------

    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (the ``{"traceEvents": [...]}``
        object form): complete events with µs timestamps, plus
        ``process_name`` / ``thread_name`` metadata so Perfetto shows
        engine names and request lanes."""
        pids: Dict[str, int] = {}
        out: List[Dict[str, Any]] = []
        seen_tids = set()
        for ev in self.events:
            pid = pids.setdefault(ev["pid"], len(pids) + 1)
            seen_tids.add((pid, ev["pid"], ev["tid"]))
            out.append({"name": ev["name"], "cat": ev["cat"], "ph": "X",
                        "ts": ev["ts"] / 1e3, "dur": ev["dur"] / 1e3,
                        "pid": pid, "tid": ev["tid"],
                        "args": ev["args"]})
        meta: List[Dict[str, Any]] = []
        for name, pid in pids.items():
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0, "args": {"name": name}})
        for pid, _, tid in sorted(seen_tids):
            label = ("engine" if tid == ENGINE_LANE
                     else f"req {tid - 1}")
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": tid, "args": {"name": label}})
        return {"traceEvents": meta + out,
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
