"""Tiered content-addressed page retention for the serving stack.

Before this module, three retention mechanisms coexisted without talking
to each other: the scheduler's prefix index freed pages the moment their
refcount hit zero, the transport's :class:`~repro.serve.transport.
DigestStore` LRU-retained the SAME immutable compressed bytes one layer
away, and the device pool knew nothing of either.  :class:`PageCache`
unifies them into one lifecycle over three tiers:

* **hot** — device pool pages.  A page column whose refcount drops to
  zero is RETAINED (moved to an LRU of zero-ref columns) instead of
  freed; a later request with the same prefix re-acquires it for a
  zero-FLOP, zero-copy hit.  Under pool pressure the scheduler evicts
  from the LRU tail (``evict_lru``) — eviction is pure ``page_used``
  clearing, because zero-ref columns are unmapped by construction.
* **warm** — host RAM.  At the LAST release of a column (while its pages
  are still addressable through the releasing slot's page-table row) the
  scheduler exports the column and ``spill``s its immutable payloads
  here, keyed by the same truncated SHA-256 page digests the transport
  computes (``repro.serve.digest``).  A prefix whose hot pages were
  evicted restores from these bytes with a device import — no prefill
  FLOPs, just a scatter.
* **remote** — a peer's store.  When the warm store itself evicted a
  payload, ``remote_fetch`` (wired by the disagg router to
  ``PageTransport.fetch``, i.e. the ``FETCH`` message of the socket
  protocol) pulls it back by digest from a peer replica before the
  caller falls back to re-prefill.

The cache is pure host bookkeeping: it never touches device state.  The
scheduler (``repro.serve.scheduler.ServeEngine``) drives the device side
— mapping hot columns, importing warm payloads, freeing evicted pages —
and reads/updates this ledger around each dispatch.  Keys are the
chained prefix digests of ``repro.serve.digest.chain_keys``; values in
``index`` are per-shard page-id vectors (free-list order permutes
per-shard, so one column owns ``tp`` physical page ids, the same id
across layers by lockstep allocation).

For hybrid (attention + SSM) models the cache additionally holds
**boundary snapshots**: the per-slot recurrent state captured right
after a tail-less, page-aligned admission, keyed by the prompt's LAST
chained prefix key.  A later identical prompt maps/restores pages AND
state and skips prefill entirely — the only replay-free (hence bit-
exact) way to prefix-share a recurrence.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .digest import page_digest
from .transport import DigestStore


class PageCache:
    """Host-side ledger of the hot / warm / remote page tiers.

    ``index``: prefix key -> per-shard page-id vector (the hot tier: both
    referenced and retained zero-ref columns).  ``ref``: key -> live
    reference count.  ``lru``: zero-ref keys in eviction order (oldest
    first).  ``warm``: key -> page digests (``tp * n_layers`` per column,
    shard-major) resolving into ``store``.  ``snapshots``: last-column
    key -> boundary SSM state + first greedy token.
    """

    def __init__(self, max_store_pages: int = 4096,
                 remote_fetch: Optional[
                     Callable[[List[bytes]], Dict[bytes, bytes]]] = None,
                 max_snapshots: int = 64):
        self.index: Dict[bytes, np.ndarray] = {}
        self.ref: Dict[bytes, int] = {}
        self.lru: "OrderedDict[bytes, None]" = OrderedDict()
        self.warm: Dict[bytes, List[bytes]] = {}
        self.store = DigestStore(max_store_pages)
        self.remote_fetch = remote_fetch
        self.snapshots: "OrderedDict[bytes, Dict[str, Any]]" = OrderedDict()
        self.max_snapshots = max_snapshots
        # lifetime counters (engine-scoped, snapshotted into ServeStats)
        self.hot_hits = 0        # zero-ref retained columns re-acquired
        self.spilled_pages = 0   # payloads written to the warm store
        self.spilled_bytes = 0
        self.fetched_pages = 0   # payloads restored from warm (incl. remote)
        self.fetched_bytes = 0
        self.remote_pages = 0    # subset of fetched that came from a peer
        self.remote_bytes = 0
        self.reprefill_cols = 0  # warm columns lost to store eviction
        self.evicted_cols = 0    # hot columns dropped under pool pressure

    def counters(self) -> Dict[str, int]:
        """Lifetime tier-traffic counters, keyed as they appear in the
        unified metrics namespace (``cache.<key>`` — see
        ``repro.serve.telemetry``); ``ServeEngine.sync_metrics`` and the
        disagg ``decode_stats`` view both read through here."""
        return {"hot_hits": self.hot_hits,
                "spilled_pages": self.spilled_pages,
                "spilled_bytes": self.spilled_bytes,
                "fetched_pages": self.fetched_pages,
                "fetched_bytes": self.fetched_bytes,
                "remote_pages": self.remote_pages,
                "remote_bytes": self.remote_bytes,
                "reprefill_cols": self.reprefill_cols,
                "evicted_cols": self.evicted_cols}

    # -- hot tier ----------------------------------------------------------

    def __contains__(self, key: bytes) -> bool:
        return key in self.index

    def insert(self, key: bytes, ids: np.ndarray) -> None:
        """Register a freshly filled column at refcount 1."""
        assert key not in self.index, "column registered twice"
        self.index[key] = ids
        self.ref[key] = 1

    def acquire(self, key: bytes) -> np.ndarray:
        """Take a reference on a hot column; reviving a retained zero-ref
        column counts as a hot-tier hit.  Returns its page ids."""
        r = self.ref[key]
        if r == 0:
            del self.lru[key]
            self.hot_hits += 1
        self.ref[key] = r + 1
        return self.index[key]

    def release(self, key: bytes) -> None:
        """Drop a reference.  At zero the column is RETAINED (joins the
        eviction LRU) — this is the tentpole change from free-at-zero."""
        r = self.ref.get(key, 0) - 1
        if r < 0:
            raise RuntimeError(
                f"prefix refcount underflow for key {key.hex()[:12]}")
        self.ref[key] = r
        if r == 0:
            self.lru[key] = None

    def evict_lru(self) -> Tuple[bytes, np.ndarray]:
        """Drop the least-recently-retained zero-ref column from the hot
        tier; returns ``(key, page ids)`` so the caller can free the
        device pages.  Its warm bytes (if spilled) survive."""
        key, _ = self.lru.popitem(last=False)
        ids = self.index.pop(key)
        del self.ref[key]
        self.evicted_cols += 1
        return key, ids

    def retained(self) -> int:
        """Zero-ref columns currently held resident."""
        return len(self.lru)

    # -- warm tier ---------------------------------------------------------

    def has_warm(self, key: bytes) -> bool:
        return key in self.warm

    def spill(self, key: bytes, payloads: Sequence[bytes]) -> None:
        """Keep a column's immutable page payloads (shard-major, one per
        ``(shard, layer)``) in the host-RAM store, keyed by content."""
        digs = []
        for p in payloads:
            d = page_digest(p)
            if d not in self.store:
                self.store[d] = p
                self.spilled_pages += 1
                self.spilled_bytes += len(p)
            digs.append(d)
        self.warm[key] = digs
        self.store.trim()

    def fetch_warm(self, key: bytes) -> Optional[List[bytes]]:
        """Resolve a warm column back to payload bytes: local store first,
        then the remote tier.  ``None`` means the bytes are gone on every
        tier — the caller re-prefills (counted) and the dead entry is
        dropped."""
        digs = self.warm.get(key)
        if digs is None:
            return None
        got: Dict[bytes, bytes] = {}
        missing = []
        for d in digs:
            if d in self.store:
                got[d] = self.store[d]
            elif d not in got:
                missing.append(d)
        if missing and self.remote_fetch is not None:
            remote = self.remote_fetch(missing)
            for d, p in remote.items():
                if page_digest(p) != d:
                    raise ValueError(
                        f"remote payload does not hash to its digest "
                        f"{d.hex()} — corrupted page on the fetch path")
                got[d] = p
                self.remote_pages += 1
                self.remote_bytes += len(p)
                self.store[d] = p      # re-warm locally
            missing = [d for d in missing if d not in got]
        if missing:
            del self.warm[key]
            self.reprefill_cols += 1
            return None
        out = [got[d] for d in digs]
        self.fetched_pages += len(out)
        self.fetched_bytes += sum(len(p) for p in out)
        return out

    # -- SSM boundary snapshots -------------------------------------------

    def get_snapshot(self, key: bytes) -> Optional[Dict[str, Any]]:
        snap = self.snapshots.get(key)
        if snap is not None:
            self.snapshots.move_to_end(key)
        return snap

    def put_snapshot(self, key: bytes, snap: Dict[str, Any]) -> None:
        self.snapshots[key] = snap
        self.snapshots.move_to_end(key)
        while len(self.snapshots) > self.max_snapshots:
            self.snapshots.popitem(last=False)

    # -- teardown ----------------------------------------------------------

    def drop_retained(self) -> List[np.ndarray]:
        """Evict EVERY zero-ref column (the caller frees the device pages
        from the returned id vectors) and clear the warm + snapshot tiers.
        Columns still referenced by live slots are untouched."""
        ids = []
        while self.lru:
            ids.append(self.evict_lru()[1])
        self.warm.clear()
        self.store = DigestStore(self.store.max_pages)
        self.snapshots.clear()
        return ids
