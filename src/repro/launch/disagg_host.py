"""Multi-process disaggregated serving: decode-replica hosts in their own
OS processes, fed by a driver over the socket page transport.

Roles:

  decode   — build a decode replica and serve it behind a TCP listener
             (``repro.serve.net.server.PageHost``).  Prints one
             ``READY host=... port=...`` line once listening (``--port 0``
             picks a free port), then handles driver sessions.
  driver   — build prefill replicas + a ``DisaggEngine`` whose decode
             replicas are REMOTE (``--decode-addr host:port[,host:port...]``),
             run a shared-prefix demo request stream through the socket,
             and print the link accounting.  ``--check`` also runs the
             monolithic engine and asserts byte-identical token streams.
  selftest — spawn one decode host as a child process and run the driver
             against it with ``--check``: the two-process smoke test CI
             runs (exit code 0 = streams identical across the socket).

Both processes must be launched with the SAME model/codec/geometry/seed
flags: the hello handshake exchanges a config fingerprint and refuses the
session otherwise (params are re-derived deterministically from the seed on
each side, which is what makes cross-process streams byte-identical).

    PYTHONPATH=src python -m repro.launch.disagg_host --role decode \
        --model tiny-bench --codec on --port 7070
    PYTHONPATH=src python -m repro.launch.disagg_host --role driver \
        --model tiny-bench --codec on --decode-addr 127.0.0.1:7070 --check
    PYTHONPATH=src python -m repro.launch.disagg_host --selftest
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import queue
import socket
import subprocess
import sys
import threading
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np


def tiny_bench_config():
    """The tiny dense model the serving bench uses (``benchmarks/run.py``)
    — small enough that two engine-building processes fit a CI runner,
    real enough to exercise pages/rings/dedup end to end."""
    from repro.configs.base import ModelConfig
    return ModelConfig(name="bench", family="dense", n_layers=2, d_model=64,
                       n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=512,
                       head_dim=16)


def build_cfg_run(args):
    """(cfg, run) from the shared model flags — MUST be deterministic in
    the flags alone, both processes call it."""
    from repro.configs import get_config, make_reduced
    from repro.configs.base import RunConfig
    from repro.core.collectives import CodecConfig
    if args.model == "tiny-bench":
        cfg = tiny_bench_config()
    else:
        cfg = make_reduced(get_config(args.model), tp=args.tp)
    codec = (CodecConfig(cache_block=args.cache_block) if args.codec == "on"
             else dataclasses.replace(CodecConfig.off(),
                                      cache_block=args.cache_block))
    codec = dataclasses.replace(codec, decode_backend=args.decode_backend,
                                weight_backend=args.weight_backend)
    return cfg, RunConfig(codec=codec)


def _fingerprint(args, cfg, run) -> bytes:
    from repro.serve.net.framing import config_fingerprint
    return config_fingerprint(cfg, run.codec, args.tp, args.slots,
                              args.max_len, args.seed, eos_id=args.eos_id)


def demo_requests(cfg, args) -> List:
    """Deterministic shared-prefix request mix (duplicates + a fork,
    staggered budgets) sized to the --max-len pool."""
    from repro.serve import Request
    rng = np.random.default_rng(args.seed)
    v = cfg.vocab_size
    plen = min(args.prompt_len, args.max_len - 2 * args.new_tokens)
    plen = max(plen, args.tp)
    base_a = rng.integers(0, v, (plen,)).astype(np.int32)
    base_b = rng.integers(0, v, (max(args.tp, plen * 2 // 3),)
                          ).astype(np.int32)
    forked = np.concatenate([base_a[:plen * 2 // 3],
                             rng.integers(0, v, (plen - plen * 2 // 3,)
                                          ).astype(np.int32)])
    prompts = [base_a, base_b, base_a, forked]
    return [Request(uid=i, prompt=prompts[i % len(prompts)],
                    max_new_tokens=args.new_tokens * (2 if i % 2 == 0
                                                      else 1))
            for i in range(args.requests)]


# ---------------------------------------------------------------------------
# roles
# ---------------------------------------------------------------------------


def run_decode_host(args) -> int:
    from repro.serve import DecodeReplica, PageHost, ServeEngine
    cfg, run = build_cfg_run(args)
    eng = ServeEngine(cfg, run, tp=args.tp, n_slots=args.slots,
                      max_len=args.max_len, seed=args.seed,
                      eos_id=args.eos_id, store_pages=args.store_pages,
                      compress_weights=args.compress_weights)
    host = PageHost(DecodeReplica(eng), _fingerprint(args, cfg, run),
                    max_store_pages=args.store_pages)
    listener = socket.create_server((args.host, args.port))
    actual = listener.getsockname()[1]
    print(f"READY host={args.host} port={actual}", flush=True)
    try:
        host.serve_forever(listener, once=args.once)
    finally:
        listener.close()
    return 0


def run_driver(args) -> int:
    from repro.serve import DisaggEngine, ServeEngine, SocketTransport
    from repro.serve.disagg import format_disagg_stats
    from repro.serve.telemetry import Tracer
    cfg, run = build_cfg_run(args)
    addrs = [a for a in args.decode_addr.split(",") if a]
    transport = SocketTransport()
    tracer = Tracer(enabled=args.trace_out is not None)
    eng = DisaggEngine(cfg, run, tp=args.tp,
                       n_prefill=args.prefill_replicas,
                       n_slots=args.slots, max_len=args.max_len,
                       seed=args.seed, eos_id=args.eos_id,
                       transport=transport, streaming=args.streaming,
                       decode_addrs=addrs, store_pages=args.store_pages,
                       compress_weights=args.compress_weights,
                       tracer=tracer)
    reqs = demo_requests(cfg, args)
    results, st = eng.run(reqs)
    # fleet metrics fold the remote replicas' METRICS RPC snapshots, so
    # query them BEFORE the session closes
    snap = eng.metrics_snapshot() if args.metrics_json else None
    transport.close()
    if args.trace_out:
        tracer.write(args.trace_out)
        print(f"[disagg_host] trace -> {args.trace_out} "
              f"({len(tracer.events)} spans)")
    if snap is not None:
        import json
        with open(args.metrics_json, "w") as f:
            json.dump(snap, f, indent=1)
        print(f"[disagg_host] metrics -> {args.metrics_json}")
    print("[disagg_host] socket:", format_disagg_stats(st))
    if args.check:
        mono = ServeEngine(cfg, run, tp=args.tp, n_slots=args.slots,
                           max_len=args.max_len, seed=args.seed,
                           eos_id=args.eos_id)
        res_m, _ = mono.run(demo_requests(cfg, args))
        for x, y in zip(res_m, results):
            if x.tokens != y.tokens or x.stop_reason != y.stop_reason:
                print(f"[disagg_host] STREAM MISMATCH uid={x.uid}: "
                      f"mono={x.tokens} socket={y.tokens}")
                return 1
        print(f"[disagg_host] check ok: {len(results)} streams "
              "byte-identical to the monolithic engine across the socket")
    return 0


# ---------------------------------------------------------------------------
# child-process helper (shared by --selftest, the bench socket scenario,
# and tests/test_net.py)
# ---------------------------------------------------------------------------


def spawn_decode_host(model_args: Sequence[str], *, tp: int = 1,
                      timeout: float = 240.0
                      ) -> Tuple[subprocess.Popen, int]:
    """Start ``--role decode --port 0 --once`` as a child process with the
    given model flags; returns ``(proc, port)`` once it prints READY.
    Kills the child and raises on startup failure."""
    env = dict(os.environ)
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    if tp > 1 and "XLA_FLAGS" not in env:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={max(tp, 8)}"
    cmd = [sys.executable, "-m", "repro.launch.disagg_host",
           "--role", "decode", "--port", "0", "--once", *model_args]
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    # a reader thread enforces the timeout even while blocked on a silent
    # child, and keeps draining after READY so the child never blocks on a
    # full stdout pipe
    out_q: "queue.Queue[Optional[str]]" = queue.Queue()

    def _reader():
        for line in proc.stdout:
            out_q.put(line)
        out_q.put(None)

    threading.Thread(target=_reader, daemon=True).start()
    port = None
    lines: List[str] = []
    deadline = time.time() + timeout
    while time.time() < deadline:
        try:
            line = out_q.get(timeout=min(1.0, max(deadline - time.time(),
                                                  0.01)))
        except queue.Empty:
            continue
        if line is None:
            break                        # child died before READY
        lines.append(line)
        if line.startswith("READY "):
            port = int(line.split("port=")[1])
            break
    if port is None:
        proc.kill()
        raise RuntimeError("decode host failed to start:\n"
                           + "".join(lines[-30:]))
    return proc, port


def run_selftest(args) -> int:
    model_args = ["--model", args.model, "--codec", args.codec,
                  "--cache-block", str(args.cache_block),
                  "--tp", str(args.tp), "--slots", str(args.slots),
                  "--max-len", str(args.max_len), "--seed", str(args.seed),
                  "--decode-backend", args.decode_backend,
                  "--weight-backend", args.weight_backend,
                  "--store-pages", str(args.store_pages)]
    if args.compress_weights:
        model_args += ["--compress-weights"]
    if args.eos_id is not None:
        model_args += ["--eos-id", str(args.eos_id)]
    proc, port = spawn_decode_host(model_args, tp=args.tp)
    try:
        args.decode_addr = f"127.0.0.1:{port}"
        args.check = True
        return run_driver(args)
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", default=None,
                    choices=["decode", "driver"],
                    help="decode: serve a replica behind a TCP port; "
                         "driver: run requests through remote replicas")
    ap.add_argument("--selftest", action="store_true",
                    help="spawn one decode child + run the driver with "
                         "--check (the two-process smoke test)")
    # shared model/geometry flags — MUST match across processes (the hello
    # handshake enforces it via a config fingerprint)
    ap.add_argument("--model", default="tiny-bench",
                    help="'tiny-bench' or a named arch (reduced)")
    ap.add_argument("--codec", default="on", choices=["on", "off"])
    ap.add_argument("--cache-block", type=int, default=8)
    ap.add_argument("--decode-backend", default="jax",
                    choices=["auto", "pallas", "interpret", "jax"])
    ap.add_argument("--compress-weights", action="store_true",
                    help="serve from the LEXI-packed at-rest weight store "
                         "(both replica kinds; token streams unchanged)")
    # default "auto" (NOT "jax" like --decode-backend): weight_backend is
    # part of the codec repr the config fingerprint hashes, and external
    # drivers (tests, bench) build codecs with the "auto" default
    ap.add_argument("--weight-backend", default="auto",
                    choices=["auto", "pallas", "interpret", "jax"])
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--eos-id", type=int, default=None)
    # decode-host flags
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = pick a free port (printed on the READY line)")
    ap.add_argument("--once", action="store_true",
                    help="exit after the first driver session ends")
    ap.add_argument("--store-pages", type=int, default=4096,
                    help="LRU cap (pages) for the content-addressed "
                         "stores: the transport digest store AND the "
                         "engine PageCache warm tier")
    # driver flags
    ap.add_argument("--decode-addr", default=None,
                    help="comma-separated host:port decode hosts")
    ap.add_argument("--prefill-replicas", type=int, default=1)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--streaming", action="store_true", default=True,
                    help="stream full pages during admission (default)")
    ap.add_argument("--no-streaming", dest="streaming",
                    action="store_false")
    ap.add_argument("--check", action="store_true",
                    help="driver: also run the monolithic engine and "
                         "assert identical token streams")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="driver: write a Chrome trace-event JSON of the "
                         "request lifecycle spans here")
    ap.add_argument("--metrics-json", type=str, default=None,
                    help="driver: write the fleet-merged metrics snapshot "
                         "(local registries + per-host METRICS RPC) here")
    args = ap.parse_args(argv)

    if args.selftest:
        return run_selftest(args)
    if args.role == "decode":
        return run_decode_host(args)
    if args.role == "driver":
        if not args.decode_addr:
            ap.error("--role driver needs --decode-addr")
        return run_driver(args)
    ap.error("pick --role decode|driver or --selftest")


if __name__ == "__main__":
    sys.exit(main())
