"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell on the production mesh and extract memory / cost / collective stats.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        --codec full --out results/dryrun.json

Success of ``.lower().compile()`` for a cell is the deliverable; the recorded
cost/memory/collective numbers feed EXPERIMENTS.md §Dry-run and §Roofline.
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count on first initialization) — do not move them below.
# (No `from __future__ import annotations` here: it would have to precede
# the XLA_FLAGS lines, which must stay first.)

import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import (ASSIGNED_ARCHS, SHAPES, batch_axes, get_config,
                           input_specs, shape_applicable)
from repro.configs.base import MeshConfig, RunConfig, ShapeConfig
from repro.core import collectives as cl
from repro.core.collectives import CodecConfig
from repro.launch.mesh import make_mesh_from_config, mesh_config
from repro.models import lm, params as PM
from repro.roofline import analysis as RA
from repro.serve import engine
from repro.train import optimizer as opt_mod
from repro.train import train_step as TS


def codec_variant(name: str) -> CodecConfig:
    return {"full": CodecConfig(), "weights": CodecConfig.weights_only(),
            "off": CodecConfig.off()}[name]


def abstract_train_state(table):
    params = PM.abstract_params(table)
    f32 = lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32)
    return TS.TrainState(
        params=params,
        opt=opt_mod.OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                             master=jax.tree_util.tree_map(f32, params),
                             m=jax.tree_util.tree_map(f32, params),
                             v=jax.tree_util.tree_map(f32, params)))


def build_lowerable(arch: str, shape_name: str, mesh_cfg: MeshConfig,
                    run: RunConfig, mesh):
    """Returns (jitted_fn, example_args) ready for .lower()."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    tp = mesh_cfg.model
    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    pspecs = PM.param_pspecs(table)
    specs_in = input_specs(cfg, shape, mesh_cfg, run)
    ba = batch_axes(mesh_cfg)
    bspec = ba[0] if len(ba) == 1 else tuple(ba)
    nbatch = mesh_cfg.data * mesh_cfg.pod
    shardable = shape.global_batch % nbatch == 0
    tok_spec = P(bspec) if shardable else P(None)

    if shape.kind == "train":
        f = TS.make_shard_mapped_step(cfg, run, mesh_cfg, table, mesh)
        state = abstract_train_state(table)
        batch = specs_in
        return f, (state, batch)

    if shape.kind == "prefill":
        sstate, sspecs = engine.global_state_struct(
            cfg, run, shape.global_batch, shape.seq_len,
            {"pod": mesh_cfg.pod, "data": mesh_cfg.data,
             "model": mesh_cfg.model})

        def pre(params, batch):
            return engine.prefill(cfg, run, params, dims, batch["tokens"],
                                  shape.seq_len, tp,
                                  front_embeds=batch.get("front_embeds"),
                                  enc_embeds=batch.get("enc_embeds"))

        in_bspecs = {k: tok_spec for k in specs_in}
        f = jax.jit(cl.shmap(pre, mesh, (pspecs, in_bspecs),
                             (P(tok_spec[0] if shardable else None, None,
                                "model"), sspecs)))
        return f, (PM.abstract_params(table), specs_in)

    # decode: serve_step over a seq_len-long cache
    sstate, sspecs = engine.global_state_struct(
        cfg, run, shape.global_batch, shape.seq_len,
        {"pod": mesh_cfg.pod, "data": mesh_cfg.data, "model": mesh_cfg.model})

    def step(params, state, tokens):
        return engine.decode_step(cfg, run, params, dims, state, tokens, tp)

    f = jax.jit(cl.shmap(
        step, mesh, (pspecs, sspecs, tok_spec),
        (P(tok_spec[0] if shardable else None, None, "model"), sspecs)))
    return f, (PM.abstract_params(table), sstate, specs_in["tokens"])


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, codec: str,
             strategy: str = "megatron", fsdp: bool = True,
             verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}
    mesh_cfg = mesh_config(multi_pod=multi_pod)
    run = RunConfig(codec=codec_variant(codec), tp_strategy=strategy,
                    fsdp=fsdp)
    mesh = make_mesh_from_config(mesh_cfg)
    t0 = time.time()
    f, args = build_lowerable(arch, shape_name, mesh_cfg, run, mesh)
    # exact per-chip accounting from the jaxpr (scan trip counts preserved;
    # avals inside shard_map are per-shard) — see roofline.analysis.
    axis_sizes = {"data": mesh_cfg.data, "model": mesh_cfg.model,
                  "pod": mesh_cfg.pod}
    jstats = RA.analyze_jaxpr(jax.make_jaxpr(f)(*args), axis_sizes)
    lowered = f.lower(*args)
    t_lower = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    membytes = RA.analytic_memory_bytes(cfg, shape, mesh_cfg, run)
    rl = RA.Roofline(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16", chips=mesh_cfg.chips,
        hlo_flops=jstats.flops * mesh_cfg.chips,  # per-shard jaxpr x chips
        hlo_bytes=membytes["total"] * mesh_cfg.chips,
        collective_bytes=jstats.collective_wire_bytes,  # per-chip ICI wire
        model_flops=RA.model_flops_for(cfg, shape),
        min_bytes=sum(membytes.get(k, 0.0) for k in
                      ("params", "kv_cache", "ssm_state"))).finalize()
    rec = {
        "status": "ok", **rl.to_dict(),
        "codec": codec, "strategy": strategy, "fsdp": fsdp,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "collective_counts": {k: int(v) for k, v
                              in jstats.coll_counts.items()},
        "collective_op_bytes": {k: float(v) for k, v
                                in jstats.coll_bytes.items()},
        "collective_wire_bytes": {k: float(v) for k, v
                                  in jstats.wire_bytes.items()},
        "memory_model": {k: float(v) for k, v in membytes.items()},
        "xla_cost_raw": {"flops": float(cost.get("flops", 0.0)),
                         "bytes_accessed":
                             float(cost.get("bytes accessed", 0.0))},
        "memory_analysis": {
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {rec['mesh']} codec={codec}] "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s  "
              f"flops/chip={jstats.flops:.3g} "
              f"mem/chip={membytes['total']:.3g}B "
              f"coll/chip={jstats.collective_wire_bytes:.3g}B(wire)  "
              f"dominant={rl.dominant} "
              f"roofline_frac={rl.roofline_fraction:.3f}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--codec", default="full",
                    choices=["full", "weights", "off"])
    ap.add_argument("--strategy", default="megatron",
                    choices=["megatron", "fsdp"])
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    failures = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                try:
                    rec = run_cell(a, s, multi_pod=mp, codec=args.codec,
                                   strategy=args.strategy, fsdp=args.fsdp)
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    rec = {"arch": a, "shape": s,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e)}
                    failures += 1
                results.append(rec)
                if args.out:
                    with open(args.out, "w") as fh:
                        json.dump(results, fh, indent=1)
    print(f"\n{len(results)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
