"""End-to-end trainer (runnable on CPU with reduced configs; the same code
path drives the production mesh).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 50 --mesh 2x4 --ckpt-dir /tmp/ckpt --ckpt-every 20 \
        [--simulate-failure 30] [--resume]

Demonstrates: manual-SPMD train step, LEXI codec on all transports,
checkpoint/restart fault tolerance, straggler monitoring.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import SHAPES, get_config, make_reduced
from repro.configs.base import MeshConfig, RunConfig, ShapeConfig
from repro.core.collectives import CodecConfig
from repro.data import pipeline as data_mod
from repro.launch.mesh import make_mesh_from_config
from repro.models import lm
from repro.train import checkpoint as ckpt_mod
from repro.train import fault
from repro.train import train_step as TS


def train_loop(cfg, shape: ShapeConfig, mesh_cfg: MeshConfig,
               run: RunConfig, *, steps: int, ckpt_dir: Optional[str],
               ckpt_every: int, resume: bool,
               fail_at: Optional[int] = None, log=print) -> Dict:
    mesh = make_mesh_from_config(mesh_cfg)
    table = lm.lm_table(cfg, mesh_cfg, run)
    step_fn = TS.make_shard_mapped_step(cfg, run, mesh_cfg, table, mesh,
                                        total_steps=steps)
    data = data_mod.for_config(cfg, shape, seed=run.seed)

    start = 0
    state = TS.init_state(table, seed=run.seed)
    if resume and ckpt_dir and (ckpt_mod.latest_step(ckpt_dir) is not None):
        start = ckpt_mod.latest_step(ckpt_dir)
        state = ckpt_mod.restore(ckpt_dir, state)
        log(f"[train] resumed from step {start}")

    mon = fault.StragglerMonitor(
        on_straggler=lambda s, dt, p95: log(
            f"[fault] straggler at step {s}: {dt * 1e3:.0f}ms vs p95 "
            f"{p95 * 1e3:.0f}ms"))
    wd = fault.Watchdog(deadline_s=600.0)
    losses = []
    for step in range(start, steps):
        if fail_at is not None and step == fail_at:
            raise fault.SimulatedFailure(f"injected failure at step {step}")
        batch = data.batch_at(step)
        wd.arm()
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        wd.disarm()
        mon.record(step, dt)
        losses.append(loss)
        if step % max(1, steps // 20) == 0 or step == steps - 1:
            log(f"[train] step {step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} {dt * 1e3:.0f}ms")
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            path = ckpt_mod.save(ckpt_dir, step + 1, state)
            sz = ckpt_mod.stored_size(ckpt_dir, step + 1)
            log(f"[ckpt] step {step + 1} -> {path} "
                f"({sz['stored_bytes'] / 1e6:.1f} MB vs "
                f"{sz['raw_bytes'] / 1e6:.1f} MB raw, LEXI "
                f"{sz['raw_bytes'] / max(sz['stored_bytes'], 1):.2f}x)")
    if ckpt_dir:
        ckpt_mod.save(ckpt_dir, steps, state)
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "stragglers": mon.straggler_steps}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--codec", default="full",
                    choices=["full", "weights", "off"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=None,
                    help="inject a failure at this step once, then recover")
    args = ap.parse_args(argv)

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh_cfg = MeshConfig(data=d, model=m, pod=1)
    codec = {"full": CodecConfig(), "weights": CodecConfig.weights_only(),
             "off": CodecConfig.off()}[args.codec]
    run = RunConfig(codec=codec, warmup_steps=max(args.steps // 10, 1))
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg, tp=m)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    failed_once = {"done": False}

    def run_once() -> Dict:
        fail_at = None
        if args.simulate_failure is not None and not failed_once["done"]:
            failed_once["done"] = True
            fail_at = args.simulate_failure
        return train_loop(cfg, shape, mesh_cfg, run, steps=args.steps,
                          ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every or 0,
                          resume=True, fail_at=fail_at)

    out = fault.run_with_restarts(run_once, max_restarts=2)
    print(f"[train] done: loss {out['first_loss']:.4f} -> "
          f"{out['final_loss']:.4f}, restarts={out['restarts']}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
