"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: 16x16 per pod, 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(data=16, model=16, pod=2 if multi_pod else 1)


def make_mesh_from_config(mc: MeshConfig):
    if mc.pod > 1:
        return jax.make_mesh((mc.pod, mc.data, mc.model),
                             ("pod", "data", "model"))
    return jax.make_mesh((mc.data, mc.model), ("data", "model"))
