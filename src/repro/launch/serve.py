"""Serving demo: fixed-batch (prefill a prompt batch, decode greedily) or
continuous batching (request stream through the paged-cache ServeEngine),
with LEXI-compressed weights/activations/caches.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --reduced \
        --batch 4 --prompt-len 64 --new-tokens 32 --mesh 1x4
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --continuous --requests 8 --slots 4 --mesh 1x4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, make_reduced
from repro.configs.base import MeshConfig, RunConfig
from repro.core import collectives as cl
from repro.core.collectives import CodecConfig
from repro.launch.mesh import make_mesh_from_config
from repro.models import lm, params as PM
from repro.serve import engine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--mesh", default="1x4")
    ap.add_argument("--codec", default="full",
                    choices=["full", "weights", "off"])
    ap.add_argument("--continuous", action="store_true",
                    help="serve a request stream through the "
                         "continuous-batching engine")
    ap.add_argument("--requests", type=int, default=8,
                    help="continuous mode: number of queued requests")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous mode: decode slots")
    ap.add_argument("--decode-backend", default="auto",
                    choices=["auto", "pallas", "interpret", "jax"],
                    help="decode-attention backend (fused Pallas kernels "
                         "vs pure-JAX scan)")
    ap.add_argument("--compress-weights", action="store_true",
                    help="continuous/disagg: serve from the LEXI-packed "
                         "at-rest weight store (fused JIT decompress+matmul "
                         "on the decode path; token streams are identical)")
    ap.add_argument("--weight-backend", default="auto",
                    choices=["auto", "pallas", "interpret", "jax"],
                    help="how packed weights are multiplied (fused "
                         "decompress_matmul vs exact unpack-then-einsum)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="continuous mode: evict a slot when it emits "
                         "this token id")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="continuous mode: disable prefix-cache page "
                         "sharing between requests")
    ap.add_argument("--disagg", action="store_true",
                    help="serve through disaggregated prefill->decode "
                         "replicas over compressed page transfer")
    ap.add_argument("--prefill-replicas", type=int, default=1,
                    help="--disagg: number of prefill replicas")
    ap.add_argument("--decode-replicas", type=int, default=1,
                    help="--disagg: number of decode replicas")
    ap.add_argument("--streaming", action="store_true",
                    help="--disagg: stream full compressed pages across "
                         "the transfer link as admission fills them "
                         "(prefill-side streaming export); multi-process "
                         "serving lives in repro.launch.disagg_host")
    ap.add_argument("--stop-seq", type=str, default=None,
                    help="continuous/disagg: comma-separated token ids; "
                         "a slot stops when its stream ends with them "
                         "(stop_reason=stop_string)")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write a Chrome trace-event JSON (Perfetto-"
                         "loadable) of the request lifecycle spans here "
                         "(continuous/disagg modes)")
    ap.add_argument("--metrics-json", type=str, default=None,
                    help="write the run's metrics-registry snapshot "
                         "(repro.serve.telemetry) as JSON here")
    args = ap.parse_args(argv)

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh_cfg = MeshConfig(data=d, model=m, pod=1)
    mesh = make_mesh_from_config(mesh_cfg)
    import dataclasses
    codec = {"full": CodecConfig(cache_block=32),
             "weights": CodecConfig.weights_only(),
             "off": CodecConfig.off()}[args.codec]
    codec = dataclasses.replace(codec, decode_backend=args.decode_backend,
                                weight_backend=args.weight_backend)
    run = RunConfig(codec=codec)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg, tp=m)

    if args.continuous or args.disagg:
        return _serve_continuous(cfg, run, m, args)

    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    params = PM.init_params(table, jax.random.key(run.seed))
    pspecs = PM.param_pspecs(table)
    tp = mesh_cfg.model
    B, S, N = args.batch, args.prompt_len, args.new_tokens
    maxlen = S + N + codec.cache_block
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    extras = {}
    if cfg.frontend == "vision_stub":
        extras["front_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.encdec:
        extras["enc_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, S, cfg.d_model)), jnp.bfloat16)

    def serve(pp, toks, extra):
        logits, st = engine.prefill(cfg, run, pp, dims, toks, maxlen, tp,
                                    front_embeds=extra.get("front_embeds"),
                                    enc_embeds=extra.get("enc_embeds"))
        outs = []
        tok = engine.greedy_token(cfg, logits, tp)
        for _ in range(N):
            outs.append(tok)
            logits, st = engine.decode_step(cfg, run, pp, dims, st, tok, tp)
            tok = engine.greedy_token(cfg, logits, tp)
        outs.append(tok)
        return jnp.concatenate(outs, axis=1)

    espec = {k: P("data") for k in extras}
    f = jax.jit(cl.shmap(serve, mesh,
                         (pspecs, P("data"), espec), P("data")))
    t0 = time.time()
    out = np.asarray(f(params, prompts, extras))
    dt = time.time() - t0
    print(f"[serve] {B} seqs x ({S} prompt + {N} new) in {dt:.1f}s "
          f"({B * N / dt:.1f} tok/s incl. compile)")
    t0 = time.time()
    out = np.asarray(f(params, prompts, extras))
    dt = time.time() - t0
    n_tok = B * (N + 1)
    print(f"[serve] steady-state: {B * N / dt:.1f} tok/s")
    print(f"[serve] stats: {B} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s), "
          f"decode backend {codec.decode_backend}")
    if args.metrics_json:
        from repro.serve.telemetry import MetricsRegistry
        reg = MetricsRegistry()
        reg.counter("serve.requests").set(B)
        reg.counter("serve.tokens").set(n_tok)
        reg.counter("serve.decode_steps").set(N)
        reg.gauge("serve.wall_s", agg="max").set(dt)
        _write_json(args.metrics_json, reg.snapshot())
        print(f"[serve] metrics -> {args.metrics_json}")
    print("[serve] sample continuations:", out[:2, :12].tolist())
    return 0


def _write_json(path: str, obj) -> None:
    import json
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)


def _serve_continuous(cfg, run, tp: int, args) -> int:
    """Request-stream mode: queue > slots, mixed prompt lengths.  With
    --disagg the stream runs through prefill->decode replicas connected by
    compressed page transfer instead of one monolithic engine."""
    from repro.serve import ServeEngine
    from repro.serve.scheduler import demo_serving_setup, format_stats
    from repro.serve.telemetry import Tracer
    run, max_len, reqs = demo_serving_setup(
        run, cfg.vocab_size, tp, args.prompt_len, args.new_tokens,
        args.requests)
    stops = ([tuple(int(t) for t in args.stop_seq.split(","))]
             if args.stop_seq else None)
    tracer = Tracer(enabled=args.trace_out is not None)
    if args.disagg:
        from repro.serve.disagg import DisaggEngine, format_disagg_stats
        eng = DisaggEngine(cfg, run, tp=tp,
                           n_prefill=args.prefill_replicas,
                           n_decode=args.decode_replicas,
                           n_slots=args.slots, max_len=max_len,
                           seed=run.seed, eos_id=args.eos_id,
                           stop_seqs=stops, streaming=args.streaming,
                           compress_weights=args.compress_weights,
                           tracer=tracer)
        results, st = eng.run(reqs)
        snap = eng.metrics_snapshot()
        print("[serve] disagg:", format_disagg_stats(st))
    else:
        eng = ServeEngine(cfg, run, tp=tp, n_slots=args.slots,
                          max_len=max_len, seed=run.seed,
                          eos_id=args.eos_id, stop_seqs=stops,
                          prefix_sharing=not args.no_prefix_sharing,
                          compress_weights=args.compress_weights,
                          tracer=tracer)
        results, st = eng.run(reqs)
        snap = eng.registry.snapshot()
        print("[serve] continuous:", format_stats(st))
    if args.trace_out:
        tracer.write(args.trace_out)
        print(f"[serve] trace -> {args.trace_out} "
              f"({len(tracer.events)} spans)")
    if args.metrics_json:
        _write_json(args.metrics_json, snap)
        print(f"[serve] metrics -> {args.metrics_json}")
    print("[serve] sample continuations:",
          [(r.tokens[:6], r.stop_reason) for r in results[:2]])
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
