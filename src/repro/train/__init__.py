"""Training substrate: optimizer, train step, checkpointing, fault tolerance."""
from . import optimizer, train_step  # noqa: F401
