"""AdamW with f32 master weights, gradient sync, global-norm clipping and a
warmup+cosine schedule — all pure JAX, sharding-aware (runs inside shard_map).

Memory layout: master/m/v are stored like the (sharded) params, so FSDP
params get ZeRO-3-style optimizer sharding for free; replicated-over-data
params still get their optimizer state data-sharded is NOT done here (the
big archs use FSDP anyway, which covers the memory-critical leaves).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.core import collectives as cl


class OptState(NamedTuple):
    step: jax.Array          # () i32
    master: Any              # f32 copy of params (same sharding)
    m: Any
    v: Any


def init_opt_state(params: Any) -> OptState:
    f32 = lambda leaf: leaf.astype(jnp.float32)
    zeros = lambda leaf: jnp.zeros(leaf.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    master=jax.tree_util.tree_map(f32, params),
                    m=jax.tree_util.tree_map(zeros, params),
                    v=jax.tree_util.tree_map(zeros, params))


def _spec_axes(spec) -> Tuple[str, ...]:
    names = []
    for entry in tuple(spec):
        if entry is None:
            continue
        if isinstance(entry, tuple):
            names.extend(entry)
        else:
            names.append(entry)
    return tuple(names)


def sync_grads(grads: Any, pspecs: Any, mesh_axes: Sequence[str],
               run: RunConfig) -> Any:
    """psum each leaf over the mesh axes its sharding spec does NOT cover.

    This is the whole manual-SPMD gradient story: sharded dims were reduced
    by the AD transposes of the forward collectives (e.g. the FSDP
    all-gather transposes to a psum_scatter over "data"), and replicated
    dims still hold per-shard partials.  The AG half of each psum is
    LEXI-compressed when codec.grads is on (the beyond-paper trick).
    """

    def one(g, spec):
        covered = set(_spec_axes(spec))
        axes = tuple(a for a in mesh_axes if a not in covered)
        if not axes:
            return g
        if run.codec.enabled and run.codec.grads:
            return cl.compressed_psum(g, axes, run.codec)
        return jax.lax.psum(g, axes)

    return jax.tree_util.tree_map(one, grads, pspecs)


def global_norm(grads: Any, pspecs: Any, mesh_axes: Sequence[str]
                ) -> jax.Array:
    """True global L2 norm of a synced (replication-consistent) grad tree.

    Sharded leaves need a cross-shard sum of squares; replicated leaves must
    not be double counted — each leaf's local sum is psum'd over its
    *sharded* axes only.
    """
    total = jnp.zeros((), jnp.float32)
    gl = jax.tree_util.tree_leaves(grads)
    sl = jax.tree_util.tree_leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    for g, s in zip(gl, sl):
        loc = jnp.sum(g.astype(jnp.float32) ** 2)
        axes = _spec_axes(s)
        if axes:
            loc = jax.lax.psum(loc, tuple(axes))
        total = total + loc
    return jnp.sqrt(total)


def lr_at(run: RunConfig, step: jax.Array, total_steps: int = 10_000
          ) -> jax.Array:
    warm = jnp.minimum(step / max(run.warmup_steps, 1), 1.0)
    t = jnp.clip((step - run.warmup_steps)
                 / max(total_steps - run.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return run.lr * warm * (0.1 + 0.9 * cos)


NO_DECAY_MIN_NDIM = 2   # norms/biases (ndim < 2) skip weight decay


def adamw_update(run: RunConfig, params: Any, grads: Any, opt: OptState,
                 pspecs: Any, mesh_axes: Sequence[str],
                 total_steps: int = 10_000):
    """One AdamW step.  Returns (new_params bf16, new OptState, metrics)."""
    gnorm = global_norm(grads, pspecs, mesh_axes)
    scale = jnp.minimum(1.0, run.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = opt.step + 1
    lr = lr_at(run, step, total_steps)
    b1, b2, eps, wd = run.beta1, run.beta2, run.eps, run.weight_decay
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def one(p_master, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        upd = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        if p_master.ndim >= NO_DECAY_MIN_NDIM:
            upd = upd + wd * p_master
        return p_master - lr * upd, m_new, v_new

    flat_master, td = jax.tree_util.tree_flatten(opt.master)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt.m)
    flat_v = jax.tree_util.tree_leaves(opt.v)
    out = [one(pm, g, m, v) for pm, g, m, v
           in zip(flat_master, flat_g, flat_m, flat_v)]
    master = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    m = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
    v = jax.tree_util.tree_unflatten(td, [o[2] for o in out])
    new_params = jax.tree_util.tree_map(
        lambda pm, old: pm.astype(old.dtype), master, params)
    return new_params, OptState(step=step, master=master, m=m, v=v), {
        "grad_norm": gnorm, "lr": lr, "clip_scale": scale}
