"""Fault-tolerant checkpointing: sharded, atomic, LEXI-compressed,
mesh-shape independent.

Layout (one directory per step):

    <dir>/step_000123/
        MANIFEST.json        tree structure, shapes, dtypes, per-leaf sha256,
                             codec flags, step metadata
        leaf_00000.lexi      LEXI-H container (bf16 leaves: ~1.5x smaller,
                             bit-exact — the paper's offline weight path)
        leaf_00001.npy       raw numpy (f32/int leaves)
    <dir>/LATEST             text file: last complete step directory name

Atomicity: written to ``<dir>/.tmp_step_x``, fsync'd, then renamed; LATEST
is updated last, so a crash mid-write never corrupts the restore point.
Restore targets any mesh: leaves are stored as full logical arrays and
resharded by the first jitted step (host memory bounds this to example-scale
models; production-scale sharded-save is a straight extension, noted in
DESIGN).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.core import bitstream


def _leaf_paths(tree) -> Tuple[Any, list]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return treedef, leaves


def save(ckpt_dir: str, step: int, state: Any, *, compress: bool = True,
         extra: Optional[Dict] = None) -> str:
    """Atomically write a checkpoint; returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, f".tmp_{name}")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    treedef, leaves = _leaf_paths(state)
    manifest: Dict[str, Any] = {
        "step": step, "treedef": str(treedef), "n_leaves": len(leaves),
        "leaves": [], "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        entry: Dict[str, Any] = {"shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
        if compress and arr.dtype == ml_dtypes.bfloat16 and arr.size >= 4096:
            blob = bitstream.compress_bf16(arr.view(np.uint16))
            fn = f"leaf_{i:05d}.lexi"
            entry["codec"] = "lexi-h"
            entry["stored_bytes"] = len(blob)
        elif compress and arr.dtype == np.float32 and arr.size >= 4096:
            # beyond-paper: f32 optimizer states get exponent-only coding too
            blob = bitstream.compress_f32(arr)
            fn = f"leaf_{i:05d}.lexi32"
            entry["codec"] = "lexi-f32"
            entry["stored_bytes"] = len(blob)
        else:
            blob = arr.tobytes()
            fn = f"leaf_{i:05d}.npy"
            entry["codec"] = "raw"
            entry["stored_bytes"] = len(blob)
        entry["file"] = fn
        entry["sha256"] = hashlib.sha256(blob).hexdigest()
        with open(os.path.join(tmp, fn), "wb") as fh:
            fh.write(blob)
            fh.flush()
            os.fsync(fh.fileno())
        manifest["leaves"].append(entry)
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as fh:
        json.dump(manifest, fh)
        fh.flush()
        os.fsync(fh.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, "LATEST"), "w") as fh:
        fh.write(name)
        fh.flush()
        os.fsync(fh.fileno())
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    name = open(p).read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "MANIFEST.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None) -> Any:
    """Load into the structure of ``like`` (shapes must match; any mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, "MANIFEST.json")))
    treedef, like_leaves = _leaf_paths(like)
    assert manifest["n_leaves"] == len(like_leaves), "tree mismatch"
    out = []
    for entry, ref in zip(manifest["leaves"], like_leaves):
        blob = open(os.path.join(d, entry["file"]), "rb").read()
        if hashlib.sha256(blob).hexdigest() != entry["sha256"]:
            raise IOError(f"checksum mismatch for {entry['file']}")
        if entry["codec"] == "lexi-h":
            u16 = bitstream.decompress_bf16(blob)
            arr = u16.view(ml_dtypes.bfloat16).reshape(entry["shape"])
        elif entry["codec"] == "lexi-f32":
            arr = bitstream.decompress_f32(blob).reshape(entry["shape"])
        else:
            arr = np.frombuffer(blob, dtype=np.dtype(entry["dtype"])
                                ).reshape(entry["shape"])
        assert tuple(arr.shape) == tuple(ref.shape), \
            (entry["file"], arr.shape, ref.shape)
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def stored_size(ckpt_dir: str, step: int) -> Dict[str, int]:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, "MANIFEST.json")))
    def _raw_itemsize(e):
        if e["codec"] == "lexi-h":
            return 2
        if e["codec"] == "lexi-f32":
            return 4
        return np.dtype(e["dtype"]).itemsize

    raw = sum(int(np.prod(e["shape"])) * _raw_itemsize(e)
              for e in manifest["leaves"])
    stored = sum(e["stored_bytes"] for e in manifest["leaves"])
    return {"raw_bytes": raw, "stored_bytes": stored}
