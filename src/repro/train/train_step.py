"""The jitted training step (manual-SPMD) + TrainState plumbing."""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import batch_axes
from repro.configs.base import MeshConfig, ModelConfig, RunConfig
from repro.core import collectives as cl
from repro.models import lm, params as PM
from . import optimizer as opt_mod


class TrainState(NamedTuple):
    params: Any
    opt: opt_mod.OptState


def batch_pspecs(cfg: ModelConfig, mesh: MeshConfig) -> Dict[str, P]:
    ba = batch_axes(mesh)
    bspec = ba[0] if len(ba) == 1 else tuple(ba)
    out = {"tokens": P(bspec), "labels": P(bspec)}
    if cfg.frontend == "vision_stub":
        out["front_embeds"] = P(bspec)
    if cfg.encdec:
        out["enc_embeds"] = P(bspec)
    return out


def state_pspecs(table) -> Any:
    pspecs = PM.param_pspecs(table)
    return TrainState(params=pspecs,
                      opt=opt_mod.OptState(step=P(), master=pspecs,
                                           m=pspecs, v=pspecs))


def make_train_step(cfg: ModelConfig, run: RunConfig, mesh_cfg: MeshConfig,
                    table, total_steps: int = 10_000):
    """Returns train_step(state, batch) -> (state, metrics) — call it inside
    shard_map (launch.train / launch.dryrun wrap it)."""
    dims = lm.lm_fsdp_dims(table)
    pspecs = PM.param_pspecs(table)
    tp = mesh_cfg.model
    baxes = batch_axes(mesh_cfg)
    mesh_axes = tuple(baxes) + ("model",)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        def loss_fn(p):
            return lm.train_loss(cfg, run, p, batch, tp, baxes, dims=dims)

        local_loss, grads = jax.value_and_grad(loss_fn)(state.params)
        # global mean loss: every shard's local contribution summed once
        loss = jax.lax.psum(local_loss, mesh_axes)
        grads = opt_mod.sync_grads(grads, pspecs, mesh_axes, run)
        new_params, new_opt, metrics = opt_mod.adamw_update(
            run, state.params, grads, state.opt, pspecs, mesh_axes,
            total_steps)
        metrics = dict(metrics, loss=loss)
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def make_shard_mapped_step(cfg: ModelConfig, run: RunConfig,
                           mesh_cfg: MeshConfig, table, mesh,
                           total_steps: int = 10_000):
    """jit(shard_map(train_step)) with all the specs filled in."""
    step = make_train_step(cfg, run, mesh_cfg, table, total_steps)
    sspecs = state_pspecs(table)
    bspecs = batch_pspecs(cfg, mesh_cfg)
    mspecs = {"loss": P(), "grad_norm": P(), "lr": P(), "clip_scale": P()}
    return jax.jit(cl.shmap(step, mesh, (sspecs, bspecs), (sspecs, mspecs)))


def init_state(table, seed: int = 0) -> TrainState:
    params = PM.init_params(table, jax.random.key(seed))
    return TrainState(params=params, opt=opt_mod.init_opt_state(params))
