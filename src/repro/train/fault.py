"""Fault tolerance: step watchdog, straggler monitor, auto-restart driver.

On a real multi-pod deployment these wrap the per-host training loop; here
they are exercised by the example trainer (including a --simulate-failure
mode that kills the loop mid-run and proves checkpoint/restart recovery).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class StragglerMonitor:
    """Tracks step latencies; flags steps beyond p95 x tolerance.

    At scale the same statistic (exchanged via a tiny allreduce of per-host
    step times) drives the mitigation policy: re-shard input files away from
    slow hosts / evict persistent stragglers to spares.  Here the policy is
    surfaced as a flag + callback.
    """

    tolerance: float = 2.0
    window: int = 50
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _times: List[float] = dataclasses.field(default_factory=list)
    straggler_steps: List[int] = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < 10:
            return False
        srt = sorted(self._times)
        p95 = srt[int(0.95 * (len(srt) - 1))]
        if dt > self.tolerance * p95:
            self.straggler_steps.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, p95)
            return True
        return False


@dataclasses.dataclass
class Watchdog:
    """Detects a hung step (e.g. a dead host stalling a collective).

    The caller stamps ``arm()`` before the blocking step and ``disarm()``
    after; ``expired`` turning True means the step exceeded the deadline and
    the driver should treat the run as failed (triggering restart-from-
    checkpoint).  Single-process stand-in for a real heartbeat service.
    """

    deadline_s: float = 300.0
    _armed_at: Optional[float] = None

    def arm(self) -> None:
        self._armed_at = time.monotonic()

    def disarm(self) -> None:
        self._armed_at = None

    @property
    def expired(self) -> bool:
        return (self._armed_at is not None
                and time.monotonic() - self._armed_at > self.deadline_s)


class SimulatedFailure(RuntimeError):
    """Raised by the example trainer's failure injector."""


def run_with_restarts(run_fn: Callable[[], Dict], *, max_restarts: int = 3,
                      backoff_s: float = 0.5,
                      log=print) -> Dict:
    """Restart-on-failure driver.

    ``run_fn`` must be resumable (restore-from-latest-checkpoint inside).
    Mirrors the production pattern where the cluster scheduler relaunches
    dead jobs and the trainer self-resumes.
    """
    attempts = 0
    while True:
        try:
            out = run_fn()
            out["restarts"] = attempts
            return out
        except SimulatedFailure as e:
            attempts += 1
            log(f"[fault] run failed ({e}); restart {attempts}/{max_restarts}")
            if attempts > max_restarts:
                raise
            time.sleep(backoff_s)
