"""Hardware models of the paper's codec + chiplet platform (sections 4-5)."""
from . import area, lanecache, lut_decoder, noc  # noqa: F401
