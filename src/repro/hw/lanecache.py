"""M-lane local-cache histogram unit (paper §4.2.1, Figs 3a/4/5).

Behavioral model of the compressor's histogram stage: exponents arriving
from the PE array are distributed round-robin across M lanes; each lane
keeps a small FIFO-evicting frequency cache; misses evict the oldest entry
to the global histogram through an arbiter that grants one writer per
ARBITER_CYCLES.

Reproduces:
  Fig 4 — per-lane cache hit rate vs depth (>90 % at depth 8),
  Fig 5 — codebook-generation latency vs (lanes × depth) with 512
          activations at 1 GHz (≈788 ns at 1×4, ≈55 ns at 10×8, ≈17 ns at
          32×16).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

ARBITER_CYCLES = 3          # paper: exclusive grant for 3 cycles
PIPELINE_CYCLES = 78        # paper: 15 (bitonic) + 31 (tree) + 32 (LUT)
TRAIN_WINDOW = 512          # paper: tree built from first 512 activations


@dataclasses.dataclass
class LaneCacheStats:
    lanes: int
    depth: int
    hits: int
    misses: int
    drain_cycles: int        # histogram-merge serialization at the arbiter

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 1.0


def simulate_lanes(exponents: np.ndarray, lanes: int, depth: int
                   ) -> LaneCacheStats:
    """Cycle-approximate simulation of the M-lane histogram unit.

    Each lane sees every ``lanes``-th exponent (round-robin from the PE
    array).  A hit increments a local counter; a miss evicts the oldest
    (FIFO) entry to the global histogram (one arbiter transaction) and
    inserts the new symbol.
    """
    x = np.asarray(exponents, dtype=np.uint8).reshape(-1)
    hits = misses = evictions = 0
    for lane in range(lanes):
        stream = x[lane::lanes]
        keys: List[int] = []           # FIFO order
        counts: Dict[int, int] = {}
        for e in stream:
            e = int(e)
            if e in counts:
                counts[e] += 1
                hits += 1
            else:
                misses += 1
                if len(keys) >= depth:
                    old = keys.pop(0)
                    counts.pop(old)
                    evictions += 1    # arbiter write during accumulation
                keys.append(e)
                counts[e] = 1
        # NOTE: the final drain of live entries overlaps the sort/tree
        # pipeline (paper §4.3: "fully pipelined with subsequent data"), so
        # it does not appear in the Fig-5 latency — only mid-stream
        # evictions serialize at the arbiter.
    drain = evictions * ARBITER_CYCLES
    return LaneCacheStats(lanes=lanes, depth=depth, hits=hits,
                          misses=misses, drain_cycles=drain)


def codebook_latency_cycles(exponents: np.ndarray, lanes: int, depth: int,
                            window: int = TRAIN_WINDOW) -> int:
    """Histogram-accumulation latency for the first ``window`` activations
    (cycles @ 1 GHz = ns) — the paper's Fig-5 quantity.

    = serial ingest (one exponent per lane per cycle) + arbiter stalls for
    mid-stream capacity evictions.  The final cache drain and the 78-cycle
    sort/tree/LUT pipeline overlap subsequent data (paper §4.3), so they are
    a one-time throughput non-event and excluded here (use
    ``PIPELINE_CYCLES`` for the end-to-end one-off cost).
    """
    st = simulate_lanes(np.asarray(exponents).reshape(-1)[:window],
                        lanes, depth)
    ingest = -(-window // lanes)
    return ingest + st.drain_cycles


def cache_size_bytes(lanes: int, depth: int) -> int:
    """Total local-cache SRAM: depth entries x (8-bit tag + 8-bit count)."""
    return lanes * depth * 2
