"""Simba-style 6×6 chiplet mesh communication model (paper §5.1/§5.3).

Replaces the paper's trace-driven HeteroGarnet runs with an analytical
network model.  Layers map round-robin onto the 6×6 compute array (4 west-
edge memory chiplets, Simba-style package DRAM).  Traffic classes per phase:

  weights      : memory -> compute, streamed once per phase (weight-resident
                 execution within a phase; per-layer working set),
  activations  : producer -> consumer chiplet, once per token per layer,
  KV cache     : write once per token; decode reads the history once per
                 cache block of tokens (block-resident reuse, matching the
                 block-by-block compression granularity),
  SSM state    : read + write once per token per layer (fixed size).

Latency: wormhole routing with a hop-dependent contention factor
(bytes x (1 + 0.5·(hops-1)) / link_bw + router pipeline per hop); compute is
dense FLOPs at 4 TOPS/chiplet.  LEXI scales each class by its *measured*
whole-value compression ratio (fed from the real codec, not assumed).

Calibration targets (paper Table 3 / Fig 7): comm = 68–95 % of e2e
uncompressed; LEXI cuts comm 33–45 % and e2e 30–35 %.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

MESH_X, MESH_Y = 6, 6
LINK_GBPS = 100.0                    # paper: 100 Gb/s inter-chiplet links
ROUTER_NS_PER_HOP = 5.0
CHIPLET_TOPS = 4.0                   # Simba-class chiplet, dense ops/s
MEM_PORTS = ((0, 0), (0, 2), (0, 3), (0, 5))   # west-edge memory chiplets
CACHE_REUSE_BLOCK = 256              # decode re-reads history once per block


def _xy_hops(a: Tuple[int, int], b: Tuple[int, int]) -> int:
    return abs(a[0] - b[0]) + abs(a[1] - b[1])


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """The per-transfer cost model of one inter-chiplet route — the single
    source of truth for link latency, shared by the phase-level simulator
    below (``simulate``) and the serving-stack page transport
    (``repro.serve.transport``), which meters every prefill→decode replica
    handoff through it to report the paper's link-byte/latency reduction.

    Wormhole routing with a hop-dependent contention factor plus a router
    pipeline charge per hop (paper §5.1).
    """
    gbps: float = LINK_GBPS
    router_ns_per_hop: float = ROUTER_NS_PER_HOP

    def transfer_ns(self, nbytes: float, hops: int = 1) -> float:
        hops = max(int(hops), 1)
        contention = 1.0 + 0.5 * (hops - 1)
        return (hops * self.router_ns_per_hop
                + nbytes * contention / (self.gbps / 8.0))


@dataclasses.dataclass
class MeteredLink:
    """LinkModel façade that accounts every priced transfer into a
    telemetry registry under ``<prefix>.bytes`` / ``<prefix>.transfers``
    / ``<prefix>.model_ns``.  ``registry`` is duck-typed (anything with
    ``counter(name).inc(n)`` — in practice
    :class:`repro.serve.telemetry.MetricsRegistry`), so the hardware
    model stays import-free of the serving stack."""
    link: LinkModel
    registry: object
    prefix: str = "link"

    def transfer_ns(self, nbytes: float, hops: int = 1) -> float:
        ns = self.link.transfer_ns(nbytes, hops)
        reg = self.registry
        reg.counter(f"{self.prefix}.bytes").inc(int(nbytes))
        reg.counter(f"{self.prefix}.transfers").inc()
        reg.counter(f"{self.prefix}.model_ns").inc(ns)
        return ns


def _chiplet_of(layer: int) -> Tuple[int, int]:
    idx = layer % (MESH_X * MESH_Y)
    return (idx % MESH_X, idx // MESH_X)


def _nearest_mem(c: Tuple[int, int]) -> Tuple[int, int]:
    return min(MEM_PORTS, key=lambda m: _xy_hops(m, c))


@dataclasses.dataclass
class SimResult:
    comm_ms: float
    compute_ms: float
    class_ms: Dict[str, float]

    @property
    def e2e_ms(self) -> float:
        # the paper reports comm dominating 68-95 % of e2e; Simba overlaps
        # compute with NoC transfers only marginally -> serial composition.
        return self.comm_ms + self.compute_ms


def _kv_width(cfg) -> float:
    if cfg.mla is not None:
        return cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
    return 2.0 * cfg.n_kv_heads * cfg.head_dim


def simulate(cfg, *, in_tokens: int, out_tokens: int,
             crs: Dict[str, float]) -> Dict[str, SimResult]:
    """Prefill + decode phases under three methods (paper Table 3 rows):
    uncompressed / compressed weights only / full LEXI."""
    methods = {
        "uncompressed": {"weights": 1.0, "activations": 1.0, "cache": 1.0},
        "weights_only": {"weights": crs["weights"], "activations": 1.0,
                         "cache": 1.0},
        "lexi": dict(crs),
    }
    d = cfg.d_model
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    per_layer_w = (cfg.param_count() - emb) / cfg.n_layers * 2.0
    active_scale = cfg.active_param_count() / cfg.param_count()
    kvw = _kv_width(cfg) if cfg.n_heads else 0.0
    ssm_state = 0.0
    if cfg.ssm is not None:
        ssm_state = (cfg.ssm.n_heads(d) * cfg.ssm.headdim * cfg.ssm.d_state
                     * 2.0 + cfg.ssm.d_inner(d) * (cfg.ssm.d_conv - 1) * 2.0)

    link = LinkModel()
    out: Dict[str, SimResult] = {}
    for mname, mcr in methods.items():
        cls_ns = {"weights": 0.0, "activations": 0.0, "cache": 0.0}
        flops = 0.0

        def xfer(src, dst, nbytes, cls):
            cls_ns[cls] += link.transfer_ns(nbytes,
                                            max(_xy_hops(src, dst), 1))

        for li in range(cfg.n_layers):
            c = _chiplet_of(li)
            mem = _nearest_mem(c)
            nxt = _chiplet_of(li + 1)
            # --- weights: once per phase (prefill + decode) ---------------
            w = per_layer_w / mcr["weights"]
            xfer(mem, c, 2.0 * w, "weights")
            # --- activations: per token, both phases ----------------------
            a_tok = 2.0 * d * 2.0 / mcr["activations"]   # boundary in+out
            xfer(c, nxt, a_tok * (in_tokens + out_tokens), "activations")
            # --- hybrid caches --------------------------------------------
            if kvw:
                k_write = kvw * 2.0 * (in_tokens + out_tokens) / mcr["cache"]
                xfer(c, mem, k_write, "cache")
                # decode: history re-read once per reuse block
                hist = 0.0
                for blk_start in range(0, out_tokens, CACHE_REUSE_BLOCK):
                    hist += (in_tokens + blk_start) * kvw * 2.0
                xfer(mem, c, hist / mcr["cache"], "cache")
            if ssm_state:
                s_rw = 2.0 * ssm_state * out_tokens / mcr["cache"]
                xfer(c, mem, s_rw, "cache")
            # --- compute ---------------------------------------------------
            flops += (2.0 * per_layer_w / 2.0 * active_scale
                      * (in_tokens + out_tokens))
        compute_ms = flops / (CHIPLET_TOPS * 1e12 * MESH_X * MESH_Y) * 1e3
        comm_ms = sum(cls_ns.values()) * 1e-6
        out[mname] = SimResult(
            comm_ms=comm_ms, compute_ms=compute_ms,
            class_ms={k: v * 1e-6 for k, v in cls_ns.items()})
    return out
