"""Multi-stage LUT Huffman decoder model (paper §4.4, Figs 3b/6).

Stage k consumes a prefix of B_k bits (8/16/24/32 by default); short,
frequent codes resolve in stage 1 (one cycle); rarer codes traverse deeper
stages; the reserved escape resolves in the final stage.  The model decodes
a real bitstream produced by ``core.bitstream`` (bit-exact against the
canonical decoder) and reports per-symbol stage counts → average latency,
plus an area estimate per configuration for the Fig-6 trade-off.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import bitstream, huffman

DEFAULT_STAGES = (8, 16, 24, 32)
CYCLE_NS = 1.0                      # 1 GHz
# area model calibrated to the paper's points: a 4-stage 8-entry design
# occupies 98.5 um^2; a single 32-bit flat LUT costs 157.6 um^2.
AREA_PER_ENTRY_UM2 = 98.5 / (4 * 8)
FLAT_LUT_AREA_UM2 = 157.6


@dataclasses.dataclass
class DecodeTrace:
    symbols: np.ndarray
    stage_hits: List[int]           # per-stage resolution counts

    @property
    def avg_cycles(self) -> float:
        total = sum(self.stage_hits)
        if not total:
            return 0.0
        return sum((i + 1) * h for i, h in enumerate(self.stage_hits)) / total

    def latency_ns_for(self, n_symbols: int, lanes: int = 10) -> float:
        """Average latency to decode ``n_symbols`` across ``lanes`` lanes."""
        per_lane = -(-n_symbols // lanes)
        return per_lane * self.avg_cycles * CYCLE_NS


def decode_staged(stream: bitstream.EncodedStream,
                  stages: Sequence[int] = DEFAULT_STAGES) -> DecodeTrace:
    """Decode via staged prefix tables; asserts bit-exactness."""
    book = stream.book
    first_code, first_index, symbols = book.decode_tables()
    max_l = int(book.lengths.max())
    counts = np.bincount(book.lengths, minlength=max_l + 2)
    bits = np.unpackbits(np.frombuffer(stream.payload, dtype=np.uint8))
    out = np.empty(stream.n_symbols, dtype=np.uint8)
    stage_hits = [0] * len(stages)
    p = 0
    for i in range(stream.n_symbols):
        code = 0
        l = 0
        sym = None
        for s_i, b_k in enumerate(stages):
            # consume bits up to this stage's cumulative width
            while l < min(b_k, max_l):
                code = (code << 1) | int(bits[p + l])
                l += 1
                idx = code - int(first_code[l])
                if counts[l] > 0 and 0 <= idx < counts[l]:
                    sym = int(symbols[int(first_index[l]) + idx])
                    break
            if sym is not None:
                stage_hits[s_i] += 1
                break
        assert sym is not None, "staged decode failed"
        p += l
        if sym == huffman.ESCAPE:
            raw = 0
            for _ in range(huffman.RAW_EXP_BITS):
                raw = (raw << 1) | int(bits[p])
                p += 1
            out[i] = raw
        else:
            out[i] = sym
    assert p == stream.total_bits
    return DecodeTrace(symbols=out, stage_hits=stage_hits)


def decoder_area_um2(stages: Sequence[int] = DEFAULT_STAGES,
                     entries_per_stage: int = 8) -> float:
    """Area model: entries scale linearly; a flat L_max LUT is the paper's
    157.6 um^2 comparison point."""
    if len(stages) == 1:
        return FLAT_LUT_AREA_UM2
    return len(stages) * entries_per_stage * AREA_PER_ENTRY_UM2


def dse_points(exp_stream: np.ndarray,
               configs: Sequence[Sequence[int]] = (
                   (32,), (8, 32), (8, 16, 32), (8, 16, 24, 32),
                   (4, 8, 16, 24, 32))) -> List[Tuple[str, float, float]]:
    """Fig-6 style (config, latency_ns per 10 exponents, area) points."""
    st = bitstream.encode(np.asarray(exp_stream, dtype=np.uint8))
    rows = []
    for stages in configs:
        tr = decode_staged(st, stages)
        name = "/".join(str(s) for s in stages)
        rows.append((name, tr.latency_ns_for(10, lanes=1),
                     decoder_area_um2(stages)))
    return rows
