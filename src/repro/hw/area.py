"""GF 22 nm area/power accounting (paper §5.4, Table 4) + node scaling.

The numbers below are the paper's post-synthesis results for the selected
configuration (10 lanes × 8-entry caches, 10 encode LUTs, one global
histogram + codebook generator, 10 four-stage decode LUTs).  The model
exposes them parametrically so the DSE benchmarks can sweep lanes/depths,
and scales 22 nm → 16 nm with the Stillmaker-Baas area factor the paper
uses for the Simba comparison.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

# paper Table 4 (per-unit, GF 22 nm, 1 GHz)
LOCAL_CACHE_UM2 = 9.85
LOCAL_CACHE_MW = 0.25
GLOBAL_HIST_UM2 = 13_113.0
GLOBAL_HIST_MW = 5.23
ENC_LUT_UM2 = 79.87
ENC_LUT_MW = 1.74
DEC_LUT_UM2 = 98.5
DEC_LUT_MW = 2.03

# Stillmaker & Baas scaling, 22 nm -> 16 nm (paper: 14995.2 -> 5452.8 um^2)
AREA_SCALE_22_TO_16 = 5452.8 / 14995.2
SIMBA_CHIPLET_MM2 = 6.0


@dataclasses.dataclass
class LexiArea:
    lanes: int = 10
    cache_depth: int = 8
    dec_lanes: int = 10

    def breakdown_um2(self) -> Dict[str, float]:
        depth_scale = self.cache_depth / 8.0
        return {
            "local_caches": self.lanes * LOCAL_CACHE_UM2 * depth_scale,
            "global_hist_codegen": GLOBAL_HIST_UM2,
            "enc_luts": self.lanes * ENC_LUT_UM2,
            "dec_luts": self.dec_lanes * DEC_LUT_UM2,
        }

    def breakdown_mw(self) -> Dict[str, float]:
        depth_scale = self.cache_depth / 8.0
        return {
            "local_caches": self.lanes * LOCAL_CACHE_MW * depth_scale,
            "global_hist_codegen": GLOBAL_HIST_MW,
            "enc_luts": self.lanes * ENC_LUT_MW,
            "dec_luts": self.dec_lanes * DEC_LUT_MW,
        }

    @property
    def total_um2(self) -> float:
        return sum(self.breakdown_um2().values())

    @property
    def total_mw(self) -> float:
        return sum(self.breakdown_mw().values())

    @property
    def total_um2_16nm(self) -> float:
        return self.total_um2 * AREA_SCALE_22_TO_16

    @property
    def chiplet_overhead(self) -> float:
        """Fraction of a 6 mm^2 Simba chiplet (paper: 0.09 %)."""
        return self.total_um2_16nm / (SIMBA_CHIPLET_MM2 * 1e6)
