"""Optional GPipe-style pipeline parallelism over (LEXI-compressed)
collective_permute.

The production mapping for the assigned meshes is DP x TP (DESIGN §5), but
inter-stage activation forwarding is the closest TPU analogue of the paper's
chiplet-to-chiplet transfers, so the feature exists as a library: stage s
holds layers [s*L/S, (s+1)*L/S); microbatches stream through stages with the
classic (M + S - 1)-tick schedule; each hop moves activations through
``lexi_ppermute`` (packed on the wire).

Use with any mesh exposing a "stage" axis; exercised by tests on a 4-stage
mesh and available to launch scripts via --pipeline.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import collectives as cl
from repro.core.collectives import CodecConfig


def pipeline_forward(stage_fn: Callable, params_stage, x_microbatches,
                     *, axis: str = "stage", codec: CodecConfig = None):
    """Run microbatches through pipeline stages.

    stage_fn(params_stage, x) -> y  : this shard's layer group.
    x_microbatches: (M, mb, ...) — every stage receives the same input
    array; only stage 0 actually consumes it (others get forwarded data).
    Returns (M, mb, ...) outputs as produced by the LAST stage (valid there;
    other stages return their local intermediate — callers select).
    """
    codec = codec or CodecConfig.off()
    n_stage = jax.lax.psum(1, axis)
    sidx = jax.lax.axis_index(axis)
    m = x_microbatches.shape[0]
    fwd_perm = tuple((i, i + 1) for i in range(n_stage - 1))

    buf = jnp.zeros_like(x_microbatches[0])
    outs = jnp.zeros_like(x_microbatches)

    def tick(carry, t):
        buf, outs = carry
        # stage 0 ingests microbatch t (if any remain); others use forwarded
        mb_idx = jnp.clip(t, 0, m - 1)
        x_in = jnp.where(sidx == 0,
                         x_microbatches[mb_idx], buf)
        y = stage_fn(params_stage, x_in)
        # forward to the next stage (compressed inter-stage hop)
        buf_next = cl.lexi_ppermute(y, axis, fwd_perm, codec)
        # last stage banks its result for microbatch (t - (S-1))
        done_idx = t - (n_stage - 1)
        outs = jax.lax.cond(
            (done_idx >= 0) & (sidx == n_stage - 1),
            lambda o: o.at[jnp.clip(done_idx, 0, m - 1)].set(y),
            lambda o: o, outs)
        return (buf_next, outs), None

    (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                  jnp.arange(m + n_stage - 1))
    return outs
