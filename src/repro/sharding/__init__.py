"""Sharding utilities: optional pipeline parallelism over ppermute."""
from . import pipeline  # noqa: F401
