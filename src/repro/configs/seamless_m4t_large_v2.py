"""seamless-m4t-large-v2 [audio] — encoder-decoder backbone.

24L enc + 24L dec, d=1024 16H (kv=16) d_ff=8192 vocab 256206.  The speech
frontend is a STUB per task instructions: input_specs supplies precomputed
frame embeddings (B, S, D) to the encoder.  [arXiv:2308.11596]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=256206, head_dim=64, encdec=True, frontend="audio_stub",
)
