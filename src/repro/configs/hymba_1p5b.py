"""hymba-1.5b [hybrid] — parallel attention ∥ Mamba heads per layer.

32L d=1600 25H (GQA kv=5) d_ff=5504 vocab 32001, ssm_state=16.  SWA (1024)
everywhere except 3 global layers (first/middle/last).  [arXiv:2411.13676]
SSM head_dim set to 50 (64 heads) so heads divide TP=16 without padding;
query heads pad 25→32 for head-parallel prefill (see DESIGN §4).
Meta-tokens are out of scope (stub note in DESIGN).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, head_dim=64, parallel_hybrid=True,
    attn_layout="hymba_3global", window=1024, sub_quadratic=True,
    ssm=SSMConfig(d_state=16, headdim=50, expand=2),
)
