"""internvl2-76b [vlm] — InternViT frontend (stub) + 80L LLM backbone.

80L d=8192 64H (GQA kv=8) d_ff=28672 vocab 128256.  The ViT frontend is a
STUB: input_specs supplies 256 precomputed patch embeddings that replace
the first 256 token positions.  [arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256, head_dim=128, frontend="vision_stub",
    n_frontend_tokens=256, rope_theta=5e5,
)
