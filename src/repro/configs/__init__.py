"""Config registry: ``get_config(name)``, reduced smoke variants, and the
per-(arch × shape) input specs used by smoke tests and the dry-run."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .base import (LONG_500K, DECODE_32K, PREFILL_32K, TRAIN_4K, SHAPES,
                   MeshConfig, ModelConfig, RunConfig, ShapeConfig,
                   shape_applicable)

_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "hymba-1.5b": "hymba_1p5b",
    "qwen2.5-32b": "qwen2p5_32b",
    "codeqwen1.5-7b": "codeqwen1p5_7b",
    "gemma2-9b": "gemma2_9b",
    "qwen3-4b": "qwen3_4b",
    "mamba2-370m": "mamba2_370m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "internvl2-76b": "internvl2_76b",
    # paper's own models (benchmark suite)
    "jamba-tiny-dev": "jamba_tiny",
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen1.5-1.8b": "qwen1p5_1p8b",
}

ASSIGNED_ARCHS = list(_MODULES)[:10]
PAPER_ARCHS = list(_MODULES)[10:]


def get_config(name: str) -> ModelConfig:
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def make_reduced(cfg: ModelConfig, tp: int = 1) -> ModelConfig:
    """Structure-preserving tiny variant for CPU smoke tests.

    Keeps every architectural feature (GQA ratios, MLA, MoE top-k, SSM,
    windows, softcaps) while shrinking width/depth/vocab.
    """
    d = 128
    heads = 0 if cfg.n_heads == 0 else max(4, min(cfg.n_heads, 8))
    kv = 0 if cfg.n_kv_heads == 0 else max(1, heads * cfg.n_kv_heads
                                           // max(cfg.n_heads, 1))
    changes: Dict = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.attn_layout != "hymba_3global"
                     else 3),
        d_model=d, n_heads=heads, n_kv_heads=kv,
        head_dim=0 if heads == 0 else 16,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=min(cfg.vocab_size, 1009),   # odd: exercises padding
        window=None if cfg.window is None else 16,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=max(8, tp), top_k=min(cfg.moe.top_k, 2),
            d_ff=64)
    if cfg.mla is not None:
        changes["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_dim=16)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, headdim=16, chunk=16)
    if cfg.n_frontend_tokens:
        changes["n_frontend_tokens"] = 8
    return dataclasses.replace(cfg, **changes)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def batch_axes(mesh: MeshConfig) -> Tuple[str, ...]:
    return ("pod", "data") if mesh.pod > 1 else ("data",)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshConfig,
                run: RunConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one (arch × shape) cell, as abstract arrays.

    For train/prefill these are global-batch tensors; for decode they are
    the one-token step inputs (the cache state is built separately by
    ``launch.dryrun`` via ``engine.abstract_state``).
    """
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    i32, bf16 = jnp.int32, jnp.bfloat16
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    else:  # decode: one new token; the cache carries the s-long history
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
    if cfg.frontend == "vision_stub" and shape.kind != "decode":
        out["front_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, d), bf16)
    if cfg.encdec and shape.kind != "decode":
        out["enc_embeds"] = jax.ShapeDtypeStruct((b, s, d), bf16)
    return out


__all__ = [
    "ASSIGNED_ARCHS", "PAPER_ARCHS", "SHAPES", "get_config", "make_reduced",
    "input_specs", "batch_axes", "MeshConfig", "ModelConfig", "RunConfig",
    "ShapeConfig", "shape_applicable",
]
