"""mamba2-370m [ssm] — attention-free SSD (state-space duality).

48L d=1024, d_state=128, headdim=64 (32 heads at expand=2), vocab 50280.
[arXiv:2405.21060]  No KV cache exists; LEXI's cache path applies to the
SSM *state* cache instead (DESIGN §4 applicability note).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, tie_embeddings=True, sub_quadratic=True,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2),
)
