"""granite-moe-1b-a400m [moe] — IBM Granite 3.0 1B-A400M base.

24L d=1024 16H (GQA kv=8) per-expert d_ff=512, vocab 49155, 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=0,
    vocab_size=49155, head_dim=64, tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8, d_ff=512),
)
