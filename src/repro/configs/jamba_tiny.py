"""jamba-tiny-dev (319M) — paper's hybrid model #1 (benchmark suite).

Used by the Table-2/3/Fig-7 reproduction.  Approximation note: Jamba
interleaves attention and Mamba layers serially with MoE on alternate
layers; our runnable zoo realizes hybrids as parallel attn∥SSM blocks, so
this config is used (a) at full shape analytically by the Simba traffic
model and (b) reduced for CR measurements, where only tensor shapes and
value distributions matter.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-tiny-dev", family="hybrid",
    n_layers=8, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=0,
    vocab_size=65536, head_dim=64, parallel_hybrid=True, sub_quadratic=True,
    ssm=SSMConfig(d_state=16, headdim=64, expand=2),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=2048),
)
