"""gemma2-9b [dense] — alternating local/global attention, logit softcaps.

42L d=3584 16H (GQA kv=8) d_ff=14336 vocab 256000, head_dim=256, window
4096 on even layers, attn softcap 50, final softcap 30, sandwich norms,
sqrt(d) embedding scaling, tied embeddings.  [arXiv:2408.00118]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_ff=14336,
    vocab_size=256000, head_dim=256, attn_layout="alternating_local",
    window=4096, attn_softcap=50.0, final_softcap=30.0, post_norm=True,
    scale_embeddings=True, tie_embeddings=True,
)
