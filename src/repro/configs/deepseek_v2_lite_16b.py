"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE.

27L d=2048 16H MLA (kv_lora=512, rope 64, nope 128, v 128), per-expert
d_ff=1408, vocab 102400, 64 routed experts top-6 + 2 shared.
[arXiv:2405.04434]  Note: the pool line says "MoE 64e top-6" with a
"160 routed" aside that matches full V2, not Lite; we follow the primary
spec (64 routed).  V2-Lite's dense first layer is simplified to MoE-everywhere.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=0,
    vocab_size=102400, head_dim=128,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared=2),
)
