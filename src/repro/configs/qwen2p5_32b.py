"""qwen2.5-32b [dense] — GQA with QKV bias.

64L d=5120 40H (GQA kv=8) d_ff=27648 vocab 152064.  [hf:Qwen/Qwen2.5-*]
Query heads pad 40→48 for TP=16 head parallelism (waste surfaces in the
MODEL_FLOPS/HLO ratio).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=27648,
    vocab_size=152064, head_dim=128, qkv_bias=True, rope_theta=1e6,
)
