"""qwen1.5-1.8b-chat — paper's transformer-only model (benchmark suite)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=5504,
    vocab_size=151936, head_dim=128, qkv_bias=True,
)
