"""zamba2-1.2b-instruct — paper's hybrid model #2 (benchmark suite).

Mamba2 backbone with shared attention blocks; modeled here as an SSM-heavy
hybrid for traffic/CR purposes (see jamba_tiny.py note).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=26, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=32000, head_dim=128, parallel_hybrid=True, sub_quadratic=True,
    ssm=SSMConfig(d_state=64, headdim=64, expand=2),
)
