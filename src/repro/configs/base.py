"""Config schema: architectures, shapes, meshes, runs.

Every assigned architecture is a ``ModelConfig``; the four canonical input
shapes are ``ShapeConfig``s; ``RunConfig`` carries the LEXI codec knobs plus
distribution/training hyper-parameters.  Everything is a frozen dataclass so
configs hash cleanly into jit static args.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.collectives import CodecConfig


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int               # routed experts
    top_k: int
    d_ff: int                    # per-expert hidden size
    n_shared: int = 0            # always-on shared experts (deepseek)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention geometry."""
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64            # may be non-power-of-2 (hymba: 50)
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128             # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        di = self.d_inner(d_model)
        assert di % self.headdim == 0, (di, self.headdim)
        return di // self.headdim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                 # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int                    # dense FFN hidden (per-expert size in MoEConfig)
    vocab_size: int
    head_dim: int = 128
    # attention features
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    post_norm: bool = False      # gemma2 sandwich norms
    rope_theta: float = 10_000.0
    attn_layout: str = "full"    # full | alternating_local | hymba_3global
    window: Optional[int] = None # sliding-window size for local layers
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    parallel_hybrid: bool = False  # hymba: attn and SSM heads in parallel
    # encoder-decoder / multimodal frontends
    encdec: bool = False         # n_layers encoder + n_layers decoder
    frontend: str = "none"       # none | audio_stub | vision_stub
    n_frontend_tokens: int = 0   # patch/frame tokens supplied pre-embedded
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embeddings: bool = False   # gemma-style sqrt(d_model) scaling
    sub_quadratic: bool = False  # eligible for long_500k (SSM/hybrid)

    # ---- derived ----
    def padded_heads(self, tp: int) -> int:
        """Query heads padded up to a multiple of tp (zero-init extra heads;
        the waste is reported via the MODEL_FLOPS/HLO ratio)."""
        if self.n_heads == 0:
            return 0
        return -(-self.n_heads // tp) * tp

    def kv_repeat(self, tp: int) -> int:
        """KV-head replication factor when kv < tp (MaxText-style)."""
        if self.n_kv_heads == 0:
            return 1
        return max(1, tp // self.n_kv_heads)

    def padded_vocab(self, tp: int) -> int:
        return -(-self.vocab_size // (tp * 128)) * (tp * 128)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND."""
        d, l = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.head_dim
        if self.n_heads:
            if self.mla is not None:
                m = self.mla
                q = d * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                kv = d * (m.kv_lora_rank + m.qk_rope_dim) \
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_dim)
                o = self.n_heads * m.v_dim * d
                per_layer += q + kv + o
            else:
                per_layer += d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * d
        if self.ssm is not None:
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_heads(d)
            per_layer += d * (2 * di + 2 * self.ssm.d_state + nh) \
                + di * self.ssm.d_conv + di * d
        if self.moe is not None:
            e = self.moe
            per_layer += d * e.n_experts * 3 * e.d_ff + d * e.n_experts
            per_layer += d * e.n_shared * 3 * e.d_ff
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff
        total = emb + l * per_layer * (2 if self.encdec else 1)
        if self.encdec:  # cross-attention in decoder layers
            total += l * (d * hd * (self.n_heads + 2 * self.n_kv_heads)
                          + self.n_heads * hd * d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        d, l = self.d_model, self.n_layers
        inactive = l * d * 3 * e.d_ff * (e.n_experts - e.top_k)
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason) for each of the 40 cells (skips documented)."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, ("full-attention arch: 512k-token decode cache is "
                       "quadratic-history; skipped per task instructions")
    return True, ""


# ---------------------------------------------------------------------------
# mesh + run
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pod: int = 1                 # >1 => multi-pod (pure extra DP / batch)

    @property
    def chips(self) -> int:
        return self.data * self.model * self.pod


@dataclasses.dataclass(frozen=True)
class RunConfig:
    codec: CodecConfig = CodecConfig()
    fsdp: bool = True            # shard stacked block params over data
    fsdp_min_size: int = 1 << 16
    # "megatron": model axis = tensor parallelism (head/ffn sharding with
    #   sequence-parallel boundaries).  "fsdp": model axis = extra parameter
    #   sharding; batch shards over it too and block compute is fully local
    #   (ZeRO-3-style; weight gathers are LEXI-compressed).  The §Perf
    #   hillclimb shows fsdp wins for small-d_model training shapes.
    tp_strategy: str = "megatron"
    remat: bool = True
    loss_chunk: int = 512        # seq chunk for vocab-sharded xent
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 512
    decode_ring: int = 256       # raw tail tokens before block compression
    # optimizer
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    seed: int = 0
