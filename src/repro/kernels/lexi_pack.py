"""Pallas TPU kernel: LEXI-FW exponent pack (the paper's egress encoder).

Splits a BF16 stream into {sign·mantissa bytes, bit-plane-packed k-bit
exponent codes} at link rate.  This is the hardware-adapted analogue of the
paper's M-lane LUT encoder: the 256-entry encode LUT lives in VMEM and every
lane of the VPU performs the lookup simultaneously (the paper replicates the
LUT per lane for the same reason).

Layout: input is reshaped to (G, B) blocks (B = 32*128 elements); each grid
step packs one block entirely in VMEM:

    x (1, B) bf16  ->  signman (1, B) u8, planes (1, k, B/32) u32

Bit-plane packing groups 32 *consecutive* elements per uint32 word, matching
``repro.core.packing`` bit-for-bit, so kernel output is interchangeable with
the pure-JAX codec.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BLOCK_ELEMS

LANES = 32


def _pack_kernel(x_ref, lut_ref, sm_ref, planes_ref, *, k: int):
    xb = x_ref[0]                                     # (B,) bf16
    u16 = jax.lax.bitcast_convert_type(xb, jnp.uint16)
    sign = (u16 >> 15).astype(jnp.uint8)
    man = (u16 & jnp.uint16(0x7F)).astype(jnp.uint8)
    sm_ref[0] = (sign << 7) | man
    exp = ((u16 >> 7) & jnp.uint16(0xFF)).astype(jnp.int32)
    codes = jnp.take(lut_ref[...], exp, axis=0)       # (B,) uint32 VMEM LUT
    grouped = codes.reshape(-1, LANES)                # (B/32, 32) flat groups
    lane = jnp.arange(LANES, dtype=jnp.uint32)
    for b in range(k):                                # unrolled: k <= 8
        planes_ref[0, b] = jnp.sum(
            ((grouped >> jnp.uint32(b)) & jnp.uint32(1)) << lane,
            axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def lexi_pack(x: jax.Array, enc_lut: jax.Array, *, k: int,
              block: int = BLOCK_ELEMS, interpret: bool = True):
    """Pack a (G, B) bf16 stream. Returns (signman (G,B) u8,
    planes (G,k,B/32) u32)."""
    g, b = x.shape
    assert b % LANES == 0 and b % block == 0 or b == block, (g, b, block)
    grid = (g,)
    return pl.pallas_call(
        functools.partial(_pack_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((1, k, b // LANES), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, b), jnp.uint8),
            jax.ShapeDtypeStruct((g, k, b // LANES), jnp.uint32),
        ],
        interpret=interpret,
    )(x, enc_lut)
