"""Jit'd public wrappers around the Pallas kernels.

These are the entry points models/benchmarks use; each wrapper

* reshapes arbitrary tensors into the kernels' (G, B) block layout,
* auto-selects ``interpret=True`` off-TPU (this container is CPU-only; the
  kernels are written for TPU and validated in interpret mode),
* round-trips escapes through the jnp side channel so the overall semantics
  match ``repro.core.fixed`` exactly.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import entropy as E
from repro.core import fixed
from . import ref
from .decode_attend import (WINDOW_NONE, decode_attend,  # noqa: F401
                            decode_attend_paged)
from .decompress_matmul import decompress_matmul as _dm
from .exp_histogram import exp_histogram as _hist
from .lexi_pack import lexi_pack as _pack
from .lexi_unpack import lexi_unpack as _unpack


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


# ---------------------------------------------------------------------------
# decode-attention backend dispatch
#
# ``CodecConfig.decode_backend`` selects how the serving decode path computes
# cache attention; ``models.cache.attend_cache``/``attend_paged`` both route
# through here so fixed-batch and paged decode cannot diverge:
#
#   auto      -- pallas on TPU, jax elsewhere (the only sane defaults)
#   pallas    -- the fused decompress+attend kernels, compiled (TPU)
#   interpret -- the same kernels under the Pallas interpreter (CPU testing:
#                exercises the exact kernel logic, slowly)
#   jax       -- the pure-JAX block/page scan (reference semantics)
# ---------------------------------------------------------------------------

DECODE_BACKENDS = ("auto", "pallas", "interpret", "jax")


def resolve_decode_backend(codec=None) -> str:
    """Resolve a CodecConfig's decode_backend to a concrete backend name."""
    be = getattr(codec, "decode_backend", "auto") if codec is not None \
        else "auto"
    if be not in DECODE_BACKENDS:
        raise ValueError(f"decode_backend must be one of {DECODE_BACKENDS}, "
                         f"got {be!r}")
    if be == "auto":
        return "pallas" if on_tpu() else "jax"
    return be


# ---------------------------------------------------------------------------
# serving weight-matmul backend dispatch
#
# ``CodecConfig.weight_backend`` selects how matmuls against PackedWeight
# leaves (the compressed-at-rest serving store, ``core.weights``) compute.
# ``models.layers.matmul_f32``/``pdot`` route every weight-consuming einsum
# through here, so attention/MLP/MoE/LM-head cannot diverge:
#
#   auto      -- pallas on TPU, jax elsewhere
#   pallas    -- fused decompress_matmul (packed tiles HBM->VMEM, decoded on
#                the VPU, fed to the MXU; bf16 W never lands in HBM)
#   interpret -- the same kernel under the Pallas interpreter (CPU testing)
#   jax       -- exact in-graph unpack + einsum (the CPU correctness gate:
#                bit-identical to serving from raw bf16 weights)
# ---------------------------------------------------------------------------

WEIGHT_BACKENDS = ("auto", "pallas", "interpret", "jax")


def resolve_weight_backend(codec=None) -> str:
    """Resolve a CodecConfig's weight_backend to a concrete backend name."""
    be = getattr(codec, "weight_backend", "auto") if codec is not None \
        else "auto"
    if be not in WEIGHT_BACKENDS:
        raise ValueError(f"weight_backend must be one of {WEIGHT_BACKENDS}, "
                         f"got {be!r}")
    if be == "auto":
        return "pallas" if on_tpu() else "jax"
    return be


def matmul_packed(x: jax.Array, pw) -> jax.Array:
    """``x @ unpack(pw)`` in f32 for a ``core.weights.PackedWeight`` leaf,
    on the backend baked into the leaf at pack time.

    The fused path handles 2-D packed leaves (stacked leaves are sliced by
    scan/indexing before they get here, but vmapped closures can still see
    them — those fall back to the exact path, as does backend "jax")."""
    from repro.core import weights as W
    be = pw.backend
    if be == "jax" or pw.signman.ndim != 2:
        w = W.unpack_weight(pw)
        return jnp.einsum("...k,kn->...n", x, w,
                          preferred_element_type=jnp.float32)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.bfloat16)
    out = _dm(x2, pw.signman, pw.planes, pw.dict_syms, k=pw.k,
              interpret=(be == "interpret") or _interpret())
    return out.reshape(lead + (out.shape[-1],))


def _blockify(x: jax.Array, block: int) -> Tuple[jax.Array, int]:
    """Flatten + zero-pad to (G, block)."""
    flat = x.reshape(-1)
    n = flat.size
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block), n


def histogram(x: jax.Array, *, block: int = ref.BLOCK_ELEMS) -> jax.Array:
    """256-bin exponent histogram of any bf16 tensor (Pallas).

    Zero-padding adds counts to bin 0 (exponent of +0.0); the wrapper
    subtracts them so the result matches ``ref.histogram_ref`` exactly.
    """
    xb, n = _blockify(x.astype(jnp.bfloat16), block)
    hist = _hist(xb, interpret=_interpret())
    pad = xb.size - n
    return hist.at[0].add(-pad)


def pack(x: jax.Array, *, k: int = fixed.DEFAULT_K,
         esc_capacity: int | None = None,
         block: int = ref.BLOCK_ELEMS) -> fixed.Compressed:
    """Kernel-backed equivalent of ``fixed.compress`` (same Compressed)."""
    shape = tuple(x.shape)
    x = x.astype(jnp.bfloat16)
    n = x.size
    c = esc_capacity if esc_capacity is not None else max(
        n // fixed.DEFAULT_ESC_FRAC, 8)
    hist = histogram(x, block=block)
    dict_syms, enc_lut = fixed.build_dictionary(hist, k)
    xb, _ = _blockify(x, block)
    sm_b, planes_b = _pack(xb, enc_lut, k=k, block=block,
                           interpret=_interpret())
    g = xb.shape[0]
    signman = sm_b.reshape(-1)[:n]
    # (G, k, B/32) -> (k, G*B/32): grid-major plane order == flat group order.
    planes = jnp.moveaxis(planes_b, 1, 0).reshape(k, -1)
    # escape side channel (host-of-graph jnp; rare path)
    esc = fixed.esc_index(k)
    u16 = E.jnp_to_u16(x).reshape(-1)
    exp = ((u16 >> 7) & 0xFF).astype(jnp.int32)
    codes = enc_lut[exp]
    esc_mask = codes == esc
    slot = jnp.cumsum(esc_mask.astype(jnp.int32)) - 1
    n_escapes = jnp.sum(esc_mask.astype(jnp.int32))
    write_slot = jnp.where(esc_mask & (slot < c), slot, c)
    np_ = xb.size
    esc_pos = jnp.full((c + 1,), np_, jnp.int32).at[write_slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")[:c]
    esc_raw = jnp.zeros((c + 1,), jnp.uint8).at[write_slot].set(
        exp.astype(jnp.uint8), mode="drop")[:c]
    return fixed.Compressed(signman=signman, planes=planes,
                            dict_syms=dict_syms, esc_pos=esc_pos,
                            esc_raw=esc_raw, n_escapes=n_escapes,
                            shape=shape, k=k)


def unpack(ct: fixed.Compressed, *, block: int = ref.BLOCK_ELEMS) -> jax.Array:
    """Kernel-backed equivalent of ``fixed.decompress``."""
    n = ct.n
    k = ct.k
    w = ct.planes.shape[-1]                      # total words
    bw = block // 32
    g = w // bw
    planes_b = jnp.moveaxis(ct.planes.reshape(k, g, bw), 0, 1)  # (G,k,bw)
    sm = jnp.pad(ct.signman, (0, g * block - n))
    sm_b = sm.reshape(g, block)
    xb = _unpack(sm_b, planes_b, ct.dict_syms, k=k, interpret=_interpret())
    out = xb.reshape(-1)[:n]
    # patch escapes: rebuild full bf16 values at the <=C escape positions
    # (gather signman clip-safe; sentinel positions drop at the scatter)
    pos = jnp.minimum(ct.esc_pos, n - 1)
    smv = ct.signman[pos].astype(jnp.uint16)
    fix_u16 = ((smv & 0x80) << 8) | (ct.esc_raw.astype(jnp.uint16) << 7) \
        | (smv & 0x7F)
    fix_val = jax.lax.bitcast_convert_type(fix_u16, jnp.bfloat16)
    out = out.at[ct.esc_pos].set(fix_val, mode="drop")
    return out.reshape(ct.shape)


def compress_weight(w: jax.Array, *, k: int = 6):
    """(K,N) bf16 -> packed fields for ``matmul_compressed``."""
    return ref.compress_weight_2d(w.astype(jnp.bfloat16), k=k)


def matmul_compressed(x: jax.Array, signman: jax.Array, planes: jax.Array,
                      dict_syms: jax.Array, *, k: int = 6,
                      bm: int = 128, bk: int = 128, bn: int = 256) -> jax.Array:
    """Fused just-in-time-decompress matmul (paper's near-compute decode)."""
    return _dm(x.astype(jnp.bfloat16), signman, planes, dict_syms, k=k,
               bm=bm, bk=bk, bn=bn, interpret=_interpret())
