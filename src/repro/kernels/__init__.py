"""Pallas TPU kernels for LEXI's compute hot-spots.

  lexi_pack         -- egress exponent encoder (VPU LUT + bit-plane pack)
  lexi_unpack       -- ingress decoder (bit-plane unpack + dict select-sum)
  exp_histogram     -- 256-bin exponent histogram via one MXU matmul
  decompress_matmul -- fused JIT weight decompression + MXU matmul

``ops`` holds the jit'd public wrappers (auto interpret=True off-TPU);
``ref`` holds the pure-jnp oracles every kernel is tested against.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
