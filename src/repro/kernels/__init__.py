"""Pallas TPU kernels for LEXI's compute hot-spots.

  lexi_pack         -- egress exponent encoder (VPU LUT + bit-plane pack)
  lexi_unpack       -- ingress decoder (bit-plane unpack + dict select-sum)
  exp_histogram     -- 256-bin exponent histogram via one MXU matmul
  decompress_matmul -- fused JIT weight decompression + MXU matmul
  decode_attend     -- fused decompress+attend over the fixed-batch KV
                       block store (ring fused as the final grid step)
  decode_attend_paged -- the same through a scalar-prefetch page table
                       (the continuous-batching serving decode path)

``ops`` holds the jit'd public wrappers plus the decode-attention backend
dispatch (``resolve_decode_backend``: auto | pallas | interpret | jax);
``ref`` holds the pure-jnp oracles every kernel is tested against.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
