"""Pallas TPU kernel: decode attention over a LEXI-compressed KV cache.

The paper's decode-phase story fused into one kernel: each grid step streams
ONE compressed cache block HBM→VMEM ({sign·mantissa bytes, bit-plane packed
exponent codes, 32-entry dictionary}), decodes it on the VPU, and runs one
online-softmax attention step on the MXU — the decompressed block never
touches HBM, so cache bandwidth is the packed size (the −16 % §Perf decode
win executes HERE on real hardware).

    q        (B, H, hd)                      one decode token, full heads
    signman  (nblk, B, blk, W) u8            W = 2*Hkv*hd (K‖V interleaved)
    planes   (nblk, k, B*blk*W/32) u32
    dicts    (nblk, 2^k) u8
    valid    (nblk, blk) bool                live-slot mask (positions/window)
    -> out   (B, H, hd) f32 unnormalized, m (B, H), l (B, H)

Grid iterates cache blocks; the (out, m, l) partials accumulate in the
output refs exactly like ``models.cache.attend_cache`` does in pure JAX —
that function is this kernel's oracle (``ref.decode_attend_ref``).
GQA mapping uses a static per-q-head kv index table (one-hot select-sum,
no dynamic gather on the TPU path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

LANES = 32
NEG_INF = -2.0e38


def _kernel(q_ref, sm_ref, planes_ref, dict_ref, valid_ref,
            out_ref, m_ref, l_ref, *, k: int, hkv: int, hd: int,
            kv_idx: tuple, scale: float):
    b, h, _ = q_ref.shape
    blk = valid_ref.shape[-1]
    w = 2 * hkv * hd

    # ---- decode the block: planes -> codes -> exponents -> bf16 ----------
    words = planes_ref[0]                               # (k, n/32) u32
    lane = jnp.arange(LANES, dtype=jnp.uint32)
    codes = jnp.zeros(words.shape[1:] + (LANES,), jnp.uint32)
    for bit in range(k):                                # unrolled
        bits = (words[bit][:, None] >> lane) & jnp.uint32(1)
        codes = codes | (bits << jnp.uint32(bit))
    codes = codes.reshape(b, blk, w)
    d = dict_ref[0]
    exp = jnp.zeros((b, blk, w), jnp.uint16)
    for j in range(d.shape[0]):                         # unrolled 2^k selects
        exp = jnp.where(codes == jnp.uint32(j), jnp.uint16(0) + d[j], exp)
    smu = sm_ref[0].astype(jnp.uint16)                  # (b, blk, w)
    u16 = ((smu & jnp.uint16(0x80)) << 8) | (exp << 7) | (smu & jnp.uint16(0x7F))
    kv = jax.lax.bitcast_convert_type(u16, jnp.bfloat16)
    kv = kv.reshape(b, blk, hkv, 2, hd)
    kmat = kv[:, :, :, 0]                               # (b, blk, hkv, hd)
    vmat = kv[:, :, :, 1]

    # ---- per-query-head kv select (static table, one-hot sum) ------------
    # k_sel/v_sel: (b, blk, h, hd)
    k_sel = jnp.zeros((b, blk, h, hd), jnp.bfloat16)
    v_sel = jnp.zeros((b, blk, h, hd), jnp.bfloat16)
    for qh, kh in enumerate(kv_idx):                    # unrolled h selects
        k_sel = k_sel.at[:, :, qh].set(kmat[:, :, kh])
        v_sel = v_sel.at[:, :, qh].set(vmat[:, :, kh])

    # ---- one online-softmax step over this block --------------------------
    qv = q_ref[...]                                     # (b, h, hd)
    s = jnp.einsum("bhd,bnhd->bhn", qv, k_sel,
                   preferred_element_type=jnp.float32) * scale
    ok = valid_ref[0]                                   # (b, blk)
    s = jnp.where(ok[:, None, :], s, NEG_INF)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, s.max(-1))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(ok[:, None, :], p, 0.0)
    alpha = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    pv = jnp.einsum("bhn,bnhd->bhd", p, v_sel.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    out_ref[...] = out_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new


@functools.partial(jax.jit, static_argnames=("k", "hkv", "hd", "kv_idx",
                                             "scale", "interpret"))
def decode_attend(q, signman, planes, dicts, valid, *, k: int, hkv: int,
                  hd: int, kv_idx: tuple, scale: float,
                  interpret: bool = True):
    """Returns (out (B,H,hd) f32 unnormalized, m (B,H), l (B,H)) —
    merge across shards with ``layers.merge_partials`` as usual."""
    nblk, b, blk, w = signman.shape
    h = q.shape[1]
    return pl.pallas_call(
        functools.partial(_kernel, k=k, hkv=hkv, hd=hd, kv_idx=kv_idx,
                          scale=scale),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((b, h, hd), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, b, blk, w), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, k, planes.shape[-1]), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dicts.shape[-1]), lambda i: (i, 0)),
            pl.BlockSpec((1, b, blk), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, h, hd), lambda i: (0, 0, 0)),
            pl.BlockSpec((b, h), lambda i: (0, 0)),
            pl.BlockSpec((b, h), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(q, signman, planes, dicts, valid)
