"""Pallas TPU kernels: decode attention over a LEXI-compressed KV cache.

The paper's decode-phase story fused into one kernel family: each grid step
streams ONE compressed cache block HBM→VMEM ({sign·mantissa bytes, bit-plane
packed exponent codes, 2^k-entry dictionary, escape side channel}), decodes
it on the VPU, and runs one online-softmax attention step on the MXU — the
decompressed block never touches HBM, so cache bandwidth is the packed size
(the −16 % §Perf decode win executes HERE on real hardware).

Two entry points share the decode + attend body:

``decode_attend``  — fixed-batch block store (``models.cache.KVBlocks``).
    Blocks are indexed directly by the grid; all B sequences share one
    traced ``length``.  Grid = (nblk + 1,): the final step attends over the
    raw bf16 ring (the in-flight partial block) instead of a decoded block.

``decode_attend_paged`` — paged store (``models.cache.PagedKV``), the
    continuous-batching serving path.  **Page-table calling convention**:
    the kernel reads through per-slot page-id indirection — ``page_ids``
    (S, maxp + 1) int32 is a scalar-prefetch operand, and the BlockSpec
    index_map of every compressed field is ``lambda s, i, pids, ...:
    pids[s, i]``, so the DMA engine fetches slot ``s``'s ``i``-th page
    directly from the page pool with no gather materialised in HBM.
    Unmapped table entries must be clipped to a valid page id by the caller
    (they are masked dead in-kernel); column ``maxp`` is the ring step and
    its page id is ignored.  ``lengths`` (S,) holds per-slot token counts
    (post-append); grid = (S, maxp + 1) with the page axis innermost, so
    each slot's online-softmax accumulator lives in VMEM across its pages.

Shared in-kernel features (exactly mirroring the pure-JAX oracle
``models.cache`` scan path — see ``ref.decode_attend_ref`` /
``ref.paged_decode_attend_ref``):

* live-slot masking from lengths: shard ``ti`` owns interleaved global
  positions {p : p % tp == ti}; a full block ``i`` is live iff
  ``i < loc_len // blk``; the ring covers local slots
  [nfull*blk, loc_len).
* windowed attention: positions must satisfy ``pos > L - 1 - window``
  (callers pass a huge sentinel for non-windowed layers, so the mask is
  uniform data — no retrace per layer).
* GQA/MQA head mapping via a static per-q-head kv index table (one-hot
  select-sum, no dynamic gather on the TPU path).
* MLA payloads (``mla_lora`` set): the block payload IS the shared latent —
  every query head attends the same k = (blk, lora+rope); v = k[:, :lora].
* logit soft-capping (gemma2) with the same op order as
  ``layers.attention_partial``.
* escape patching: the side channel stores (position-ordered) raw exponents
  for codes == ESCAPE, so the kernel recovers them with a cumsum rank +
  gather from the per-block ``esc_raw`` — bit-exact with
  ``fixed.decompress`` whenever ``n_escapes <= C`` (and identical overflow
  behaviour beyond: dict slot ESCAPE decodes as exponent 0).
  [TPU note: the rank gather is `jnp.take` — validated in interpret mode;
  the compiled TPU lowering may need a one-hot rewrite, see ROADMAP.]

Outputs are unnormalised partials (out f32, m, l) — merge across shards
with ``layers.merge_partials`` exactly like the pure-JAX path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38
WINDOW_NONE = 1 << 30      # matches models.attention.GLOBAL_WINDOW


def _iota(n: int) -> jax.Array:
    """(n,) int32 iota via 2D broadcasted_iota (TPU needs >=2D)."""
    return jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]


# ---------------------------------------------------------------------------
# shared kernel body pieces
# ---------------------------------------------------------------------------

def _decode_vals(sm_ref, planes_ref, dict_row, esc_ref, shape, k: int):
    """Decode one compressed block to bf16 ``shape`` (flat size n).

    planes -> codes -> dictionary exponents -> escape patch -> bf16.
    The bit-plane stream is padded to a multiple of 32 elements (pad codes
    are 0, never ESCAPE); the tail is decoded and discarded.  ``dict_row``
    is this block's (2^k,) u16 exponent LUT row, sliced from the
    whole-store LUT that the wrapper widens ONCE per kernel invocation and
    pins in VMEM across grid steps (constant index_map — no per-step dict
    DMA, no per-step u8->u16 widening).
    """
    n = 1
    for d in shape:
        n *= d
    words = planes_ref[0]                               # (k, npad/32) u32
    lane = jnp.arange(32, dtype=jnp.uint32)
    codes = jnp.zeros(words.shape[1:] + (32,), jnp.uint32)
    for bit in range(k):                                # unrolled
        bits = (words[bit][:, None] >> lane) & jnp.uint32(1)
        codes = codes | (bits << jnp.uint32(bit))
    codes = codes.reshape(-1)[:n]
    exp = jnp.zeros((n,), jnp.uint16)
    for j in range(dict_row.shape[0]):                  # unrolled 2^k selects
        exp = jnp.where(codes == jnp.uint32(j), dict_row[j], exp)
    # escape patch: side-channel entries are position-ordered, so the r-th
    # escape element takes esc_raw[r]; beyond capacity the dict's ESCAPE
    # slot (exponent 0) stands, matching fixed.decompress overflow.
    esc_code = jnp.uint32((1 << k) - 1)
    is_esc = codes == esc_code
    rank = jnp.cumsum(is_esc.astype(jnp.int32)) - 1
    esc_raw = esc_ref[0]                                # (C,) u8
    c = esc_raw.shape[0]
    patched = jnp.take(esc_raw, jnp.clip(rank, 0, c - 1)).astype(jnp.uint16)
    exp = jnp.where(is_esc & (rank < c), patched, exp)
    smu = sm_ref[0].reshape(n).astype(jnp.uint16)
    u16 = ((smu & jnp.uint16(0x80)) << 8) | (exp << 7) \
        | (smu & jnp.uint16(0x7F))
    return jax.lax.bitcast_convert_type(u16, jnp.bfloat16).reshape(shape)


def _split_heads(vals, h: int, hkv: int, hd: int, kv_idx, mla_lora):
    """(..., blk, W) payload -> (k_sel, v_sel) per-query-head views.

    GQA: W = 2*hkv*hd K‖V interleaved, static one-hot head table.
    MLA: the latent is shared by all heads — k = vals, v = vals[..., :lora].
    """
    if mla_lora is not None:
        return vals, vals[..., :mla_lora]
    lead = vals.shape[:-2]
    blk = vals.shape[-2]
    kv = vals.reshape(lead + (blk, hkv, 2, hd))
    kmat = kv[..., 0, :]                                # (..., blk, hkv, hd)
    vmat = kv[..., 1, :]
    k_sel = jnp.zeros(lead + (blk, h, hd), jnp.bfloat16)
    v_sel = jnp.zeros(lead + (blk, h, hd), jnp.bfloat16)
    for qh, kh in enumerate(kv_idx):                    # unrolled h selects
        k_sel = k_sel.at[..., qh, :].set(kmat[..., kh, :])
        v_sel = v_sel.at[..., qh, :].set(vmat[..., kh, :])
    return k_sel, v_sel


def _block_partial(q, k_sel, v_sel, ok, scale, softcap, mla: bool):
    """One block's attention partial, mirroring ``layers.attention_partial``.

    q (B?, H, hd); k_sel/v_sel (B?, blk, [H,] hd); ok (B?, blk) bool.
    Returns (po (B?, H, hd_v) f32, m (B?, H), l (B?, H)).
    """
    if mla:
        s = jnp.einsum("...hd,...nd->...hn", q, k_sel,
                       preferred_element_type=jnp.float32) * scale
    else:
        s = jnp.einsum("...hd,...nhd->...hn", q, k_sel,
                       preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    okb = ok[..., None, :]                              # (B?, 1, blk)
    s = jnp.where(okb, s, NEG_INF)
    m = s.max(-1)
    p = jnp.where(okb, jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(-1)
    if mla:
        po = jnp.einsum("...hn,...nd->...hd", p, v_sel.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    else:
        po = jnp.einsum("...hn,...nhd->...hd", p, v_sel.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    return po, m, l


def _accumulate(out_ref, m_ref, l_ref, po, pm, pl_, init_pred):
    """Online-softmax merge of one partial into the output refs — the same
    arithmetic as ``models.cache.merge_partial`` so backends agree."""
    @pl.when(init_pred)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, pm)
    a_old = jnp.exp(m_old - m_new)
    a_new = jnp.exp(pm - m_new)
    out_ref[...] = out_ref[...] * a_old[..., None] + po * a_new[..., None]
    l_ref[...] = l_ref[...] * a_old + pl_ * a_new
    m_ref[...] = m_new


def _live_masks(L, i, is_ring, blk: int, tp: int, ti, window):
    """(blk,)-shaped live mask for block ``i`` / the ring, per slot.

    L may be a scalar (fixed store) or the final axis broadcasts over it.
    """
    loc_len = jnp.maximum((L - 1 - ti) // tp + 1, 0)
    nfull = loc_len // blk
    sl = jnp.where(is_ring, nfull * blk, i * blk)[..., None] + _iota(blk)
    pos = sl * tp + ti
    ok = (pos < L[..., None]) & (pos > L[..., None] - 1 - window)
    live = jnp.where(is_ring, sl < loc_len[..., None],
                     i < nfull[..., None])
    return ok & live


# ---------------------------------------------------------------------------
# fixed-batch store kernel
# ---------------------------------------------------------------------------

def _fixed_kernel(len_ref, meta_ref, q_ref, *rest, k: int, hkv: int, hd: int,
                  kv_idx: tuple, scale: float, softcap, mla_lora, tp: int,
                  blk: int, nblk: int, codec_on: bool):
    if codec_on:
        sm_ref, planes_ref, dict_ref, esc_ref, ring_ref = rest[:5]
        out_ref, m_ref, l_ref = rest[5:]
    else:
        raw_ref, ring_ref = rest[:2]
        out_ref, m_ref, l_ref = rest[2:]
    b, h, _ = q_ref.shape
    w = ring_ref.shape[-1]
    i = pl.program_id(0)
    is_ring = i == nblk
    ti, window = meta_ref[0], meta_ref[1]
    L = len_ref[0].reshape(())

    if codec_on:
        # dict_ref holds the whole store's pre-widened u16 LUT, resident in
        # VMEM across grid steps (constant index_map) — slice this block's row
        row = pl.load(dict_ref, (pl.ds(jnp.minimum(i, nblk - 1), 1),
                                 pl.ds(0, dict_ref.shape[1])))[0]
        vals = _decode_vals(sm_ref, planes_ref, row, esc_ref,
                            (b, blk, w), k)
    else:
        vals = raw_ref[0]
    vals = jnp.where(is_ring, ring_ref[...], vals)      # (b, blk, w)

    ok = _live_masks(L[None], i, is_ring, blk, tp, ti, window)  # (1, blk)
    ok = jnp.broadcast_to(ok, (b, blk))
    k_sel, v_sel = _split_heads(vals, h, hkv, hd, kv_idx, mla_lora)
    po, pm, pl_ = _block_partial(q_ref[...], k_sel, v_sel, ok, scale,
                                 softcap, mla_lora is not None)
    _accumulate(out_ref, m_ref, l_ref, po, pm, pl_, i == 0)


def decode_attend(q, signman, planes, dicts, esc_raw, raw_blocks, ring,
                  length, ti, window, *, k: int, hkv: int, hd: int,
                  kv_idx: tuple, scale: float, softcap=None, mla_lora=None,
                  tp: int = 1, interpret: bool = True):
    """Fused decompress+attend over a fixed-batch block store + its ring.

    q (B, H, hd); codec on: signman (nblk, B*blk*W) u8, planes
    (nblk, k, n/32) u32, dicts (nblk, 2^k) u8, esc_raw (nblk, C) u8;
    codec off: raw_blocks (nblk, B, blk, W) bf16.  ring (B, blk, W) bf16;
    length/ti/window are traced scalars.  Returns (out (B,H,hd_v) f32
    unnormalized, m (B,H), l (B,H)) — merge across shards with
    ``layers.merge_partials`` as usual.
    """
    codec_on = signman is not None
    b, h, _ = q.shape
    blk, w = ring.shape[-2], ring.shape[-1]
    nblk = signman.shape[0] if codec_on else raw_blocks.shape[0]
    hd_v = mla_lora if mla_lora is not None else hd
    lens = jnp.asarray(length, jnp.int32).reshape(1)
    meta = jnp.stack([jnp.asarray(ti, jnp.int32),
                      jnp.asarray(window, jnp.int32)])

    nsp = 2
    if codec_on:
        n = b * blk * w
        # whole-store dictionary LUT, u16-widened ONCE per invocation and
        # mapped with a constant index — it stays in VMEM across grid steps
        # instead of being re-fetched + re-widened per block (ROADMAP
        # "Kernels" hoist item); tiny: nblk * 2^k * 2 bytes.
        dict_lut = dicts.astype(jnp.uint16)
        in_specs = [
            pl.BlockSpec((b, h, q.shape[-1]), lambda i, *s: (0, 0, 0)),
            pl.BlockSpec((1, n), lambda i, *s: (jnp.minimum(i, nblk - 1), 0)),
            pl.BlockSpec((1, k, planes.shape[-1]),
                         lambda i, *s: (jnp.minimum(i, nblk - 1), 0, 0)),
            pl.BlockSpec((nblk, dicts.shape[-1]), lambda i, *s: (0, 0)),
            pl.BlockSpec((1, esc_raw.shape[-1]),
                         lambda i, *s: (jnp.minimum(i, nblk - 1), 0)),
            pl.BlockSpec((b, blk, w), lambda i, *s: (0, 0, 0)),
        ]
        operands = (q, signman, planes, dict_lut, esc_raw, ring)
    else:
        in_specs = [
            pl.BlockSpec((b, h, q.shape[-1]), lambda i, *s: (0, 0, 0)),
            pl.BlockSpec((1, b, blk, w),
                         lambda i, *s: (jnp.minimum(i, nblk - 1), 0, 0, 0)),
            pl.BlockSpec((b, blk, w), lambda i, *s: (0, 0, 0)),
        ]
        operands = (q, raw_blocks, ring)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=nsp,
        grid=(nblk + 1,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((b, h, hd_v), lambda i, *s: (0, 0, 0)),
            pl.BlockSpec((b, h), lambda i, *s: (0, 0)),
            pl.BlockSpec((b, h), lambda i, *s: (0, 0)),
        ])
    kern = functools.partial(
        _fixed_kernel, k=k, hkv=hkv, hd=hd, kv_idx=tuple(kv_idx),
        scale=scale, softcap=softcap, mla_lora=mla_lora, tp=tp, blk=blk,
        nblk=nblk, codec_on=codec_on)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, hd_v), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(lens, meta, *operands)


# ---------------------------------------------------------------------------
# paged store kernel (continuous batching)
# ---------------------------------------------------------------------------

def _paged_kernel(pid_ref, len_ref, meta_ref, q_ref, *rest, k: int, hkv: int,
                  hd: int, kv_idx: tuple, scale: float, softcap, mla_lora,
                  tp: int, blk: int, maxp: int, codec_on: bool):
    if codec_on:
        sm_ref, planes_ref, dict_ref, esc_ref, ring_ref = rest[:5]
        out_ref, m_ref, l_ref = rest[5:]
    else:
        raw_ref, ring_ref = rest[:2]
        out_ref, m_ref, l_ref = rest[2:]
    _, h, _ = q_ref.shape
    w = ring_ref.shape[-1]
    s = pl.program_id(0)
    i = pl.program_id(1)
    is_ring = i == maxp
    ti, window = meta_ref[0], meta_ref[1]
    L = len_ref[s].reshape(())

    if codec_on:
        # whole-pool LUT pinned in VMEM; this page's row via the prefetched
        # page id (column maxp carries a valid dummy id, masked dead below)
        row = pl.load(dict_ref, (pl.ds(pid_ref[s, i], 1),
                                 pl.ds(0, dict_ref.shape[1])))[0]
        vals = _decode_vals(sm_ref, planes_ref, row, esc_ref,
                            (blk, w), k)
    else:
        vals = raw_ref[0]
    vals = jnp.where(is_ring, ring_ref[0], vals)        # (blk, w)

    ok = _live_masks(L[None], i, is_ring, blk, tp, ti, window)[0]  # (blk,)
    k_sel, v_sel = _split_heads(vals, h, hkv, hd, kv_idx, mla_lora)
    po, pm, pl_ = _block_partial(q_ref[0], k_sel, v_sel, ok, scale,
                                 softcap, mla_lora is not None)
    _accumulate(out_ref, m_ref, l_ref, po[None], pm[None], pl_[None],
                i == 0)


def decode_attend_paged(q, signman, planes, dicts, esc_raw, raw_pages, ring,
                        page_ids, lengths, ti, window, *, k: int, hkv: int,
                        hd: int, kv_idx: tuple, scale: float, softcap=None,
                        mla_lora=None, tp: int = 1, interpret: bool = True):
    """Fused decompress+attend through a page table (see module docstring).

    q (S, H, hd); page pool fields have leading n_pages; ring (S, blk, W);
    page_ids (S, maxp) int32 with unmapped entries ALREADY clipped to a
    valid id (they are masked dead in-kernel); lengths (S,) post-append
    token counts; ti/window traced scalars.  Returns per-slot partials
    (out (S,H,hd_v) f32, m (S,H), l (S,H)).
    """
    codec_on = signman is not None
    n_s, h, _ = q.shape
    blk, w = ring.shape[-2], ring.shape[-1]
    maxp = page_ids.shape[1]
    hd_v = mla_lora if mla_lora is not None else hd
    # column maxp = ring step (page id unused; any valid id keeps DMA legal)
    pids = jnp.concatenate(
        [page_ids, jnp.zeros((n_s, 1), jnp.int32)], axis=1)
    lens = jnp.asarray(lengths, jnp.int32).reshape(n_s)
    meta = jnp.stack([jnp.asarray(ti, jnp.int32),
                      jnp.asarray(window, jnp.int32)])

    if codec_on:
        n = blk * w
        # whole-pool dictionary LUT, widened once per invocation + constant
        # index_map: resident across the whole (S, maxp + 1) grid
        dict_lut = dicts.astype(jnp.uint16)
        in_specs = [
            pl.BlockSpec((1, h, q.shape[-1]),
                         lambda s, i, pid, *r: (s, 0, 0)),
            pl.BlockSpec((1, n), lambda s, i, pid, *r: (pid[s, i], 0)),
            pl.BlockSpec((1, k, planes.shape[-1]),
                         lambda s, i, pid, *r: (pid[s, i], 0, 0)),
            pl.BlockSpec((dicts.shape[0], dicts.shape[-1]),
                         lambda s, i, pid, *r: (0, 0)),
            pl.BlockSpec((1, esc_raw.shape[-1]),
                         lambda s, i, pid, *r: (pid[s, i], 0)),
            pl.BlockSpec((1, blk, w), lambda s, i, pid, *r: (s, 0, 0)),
        ]
        operands = (q, signman, planes, dict_lut, esc_raw, ring)
    else:
        in_specs = [
            pl.BlockSpec((1, h, q.shape[-1]),
                         lambda s, i, pid, *r: (s, 0, 0)),
            pl.BlockSpec((1, blk, w),
                         lambda s, i, pid, *r: (pid[s, i], 0, 0)),
            pl.BlockSpec((1, blk, w), lambda s, i, pid, *r: (s, 0, 0)),
        ]
        operands = (q, raw_pages, ring)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_s, maxp + 1),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, h, hd_v), lambda s, i, *r: (s, 0, 0)),
            pl.BlockSpec((1, h), lambda s, i, *r: (s, 0)),
            pl.BlockSpec((1, h), lambda s, i, *r: (s, 0)),
        ])
    kern = functools.partial(
        _paged_kernel, k=k, hkv=hkv, hd=hd, kv_idx=tuple(kv_idx),
        scale=scale, softcap=softcap, mla_lora=mla_lora, tp=tp, blk=blk,
        maxp=maxp, codec_on=codec_on)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_s, h, hd_v), jnp.float32),
            jax.ShapeDtypeStruct((n_s, h), jnp.float32),
            jax.ShapeDtypeStruct((n_s, h), jnp.float32),
        ],
        interpret=interpret,
    )(pids, lens, meta, *operands)
