"""Pallas TPU kernel: LEXI-FW exponent unpack (the paper's ingress decoder).

Inverse of ``lexi_pack``: reconstructs BF16 values from {sign·mantissa bytes,
bit-plane-packed codes, dictionary}.  This is the TPU analogue of the paper's
multi-stage LUT decoder — but where variable-length Huffman needs 4 staged
prefix tables, the fixed-width code resolves every symbol with one 32-entry
dictionary lookup per element, implemented as an unrolled select-sum so it
lowers to pure VPU ops (no dynamic gather on the critical path).

Escapes are NOT resolved here (they are data-dependent scatter); the ops.py
wrapper patches the <=C escape positions afterwards — the paper's escape is
likewise resolved by a separate final-stage path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BLOCK_ELEMS

LANES = 32


def _unpack_kernel(sm_ref, planes_ref, dict_ref, x_ref, *, k: int):
    sm = sm_ref[0]                                    # (B,) uint8
    words = planes_ref[0]                             # (k, B/32) uint32
    lane = jnp.arange(LANES, dtype=jnp.uint32)
    codes = jnp.zeros(words.shape[1:] + (LANES,), jnp.uint32)
    for b in range(k):                                # unrolled
        bits = (words[b][:, None] >> lane) & jnp.uint32(1)
        codes = codes | (bits << jnp.uint32(b))
    codes = codes.reshape(-1)                         # (B,) flat groups of 32
    d = dict_ref[...]                                 # (2^k,) uint8
    exp = jnp.zeros_like(codes, dtype=jnp.uint16)
    for j in range(d.shape[0]):                       # unrolled select-sum
        exp = jnp.where(codes == jnp.uint32(j), jnp.uint16(0) + d[j], exp)
    smu = sm.astype(jnp.uint16)
    u16 = ((smu & jnp.uint16(0x80)) << 8) | (exp << 7) | (smu & jnp.uint16(0x7F))
    x_ref[0] = jax.lax.bitcast_convert_type(u16, jnp.bfloat16)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def lexi_unpack(signman: jax.Array, planes: jax.Array, dict_syms: jax.Array,
                *, k: int, interpret: bool = True) -> jax.Array:
    """Unpack (G,B) blocks back to bf16 (escape-free fast path)."""
    g, b = signman.shape
    return pl.pallas_call(
        functools.partial(_unpack_kernel, k=k),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((1, k, b // LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((dict_syms.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, b), jnp.bfloat16),
        interpret=interpret,
    )(signman, planes, dict_syms)
