"""Pallas TPU kernel: 256-bin exponent histogram (the paper's M-lane unit).

The paper builds the per-layer exponent histogram with M parallel lanes of
small frequency caches merged through an arbiter.  The TPU-native equivalent
is an MXU trick: split the 8-bit exponent into hi/lo nibbles, one-hot each to
(N, 16), and compute ``hiOH^T @ loOH`` — a single 16×N×16 matmul whose
(16, 16) result *is* the 256-bin histogram (hist[hi*16+lo]).  The systolic
array plays the role of the paper's parallel counting lanes.

Grid steps accumulate into the same output block (standard Pallas reduction
pattern), so arbitrarily long streams cost one (16,16) tile of VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(x_ref, hist_ref):
    xb = x_ref[0]                                     # (B,) bf16
    u16 = jax.lax.bitcast_convert_type(xb, jnp.uint16)
    exp = ((u16 >> 7) & jnp.uint16(0xFF)).astype(jnp.int32)
    hi = (exp >> 4)[:, None]                          # (B, 1)
    lo = (exp & 15)[:, None]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 16), 1)
    hi_oh = (hi == iota).astype(jnp.float32)          # (B, 16)
    lo_oh = (lo == iota).astype(jnp.float32)          # (B, 16)
    counts = jax.lax.dot_general(                     # (16, 16) on the MXU
        hi_oh, lo_oh, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += counts.reshape(-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def exp_histogram(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """256-bin exponent histogram of a (G, B) bf16 stream -> (256,) int32."""
    g, b = x.shape
    return pl.pallas_call(
        _hist_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((1, b), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((256,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((256,), jnp.int32),
        interpret=interpret,
    )(x)
