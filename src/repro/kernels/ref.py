"""Pure-jnp oracles for every Pallas kernel in this package.

Layout contract shared by kernels and oracles (and bit-compatible with
``repro.core.fixed`` / ``repro.core.packing``):

* tensors are processed as (G, B) row-major blocks of a flattened stream,
  B = BLOCK_ELEMS (default 32*128 = 4096, MXU/VPU aligned);
* exponent codes are bit-plane packed in flat groups of 32 consecutive
  elements: planes[(g,) b, w] holds bit b of elements 32*w .. 32*w+31 of
  block g;
* the encode LUT maps the 8-bit exponent to a k-bit dictionary index with
  ESCAPE = 2^k - 1; the decode dictionary maps index -> exponent byte.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import entropy as E
from repro.core import packing

BLOCK_ROWS = 32
BLOCK_COLS = 128
BLOCK_ELEMS = BLOCK_ROWS * BLOCK_COLS


def pack_ref(x: jax.Array, enc_lut: jax.Array, k: int):
    """Oracle for ``lexi_pack``: (G, B) bf16 -> (signman (G,B) u8,
    planes (G,k,B/32) u32)."""
    g, b = x.shape
    u16 = E.jnp_to_u16(x)
    signman = E.jnp_signman(u16)
    exp = ((u16 >> 7) & 0xFF).astype(jnp.int32)
    codes = enc_lut[exp]                              # (G, B) uint32
    planes = packing.bitplane_pack(codes, k)          # (G, k, B/32)
    return signman, planes


def unpack_ref(signman: jax.Array, planes: jax.Array, dict_syms: jax.Array,
               k: int) -> jax.Array:
    """Oracle for ``lexi_unpack``: inverse of pack_ref (escapes handled by
    the caller via the side channel)."""
    codes = packing.bitplane_unpack(planes, k)        # (G, B)
    exp = dict_syms[codes.astype(jnp.int32)]          # (G, B) uint8
    u16 = E.jnp_combine(signman, exp)
    return E.jnp_from_u16(u16)


def histogram_ref(x: jax.Array) -> jax.Array:
    """Oracle for ``exp_histogram``: 256-bin exponent histogram (int32)."""
    u16 = E.jnp_to_u16(x)
    exp = ((u16 >> 7) & 0xFF).astype(jnp.int32).reshape(-1)
    return jnp.zeros((256,), jnp.int32).at[exp].add(1)


def decompress_matmul_ref(x: jax.Array, signman: jax.Array, planes: jax.Array,
                          dict_syms: jax.Array, k: int) -> jax.Array:
    """Oracle for ``decompress_matmul``: x (M,K) bf16 @ packed W (K,N).

    ``planes`` is (k, K, N/32): row i's exponent codes are packed along N in
    flat groups of 32 (so W tiles cleanly along both axes).
    """
    kk, n = signman.shape
    codes = packing.bitplane_unpack(jnp.moveaxis(planes, 0, -2), k)  # (K, N)
    exp = dict_syms[codes.astype(jnp.int32)]
    u16 = E.jnp_combine(signman, exp)
    w = E.jnp_from_u16(u16)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def compress_weight_2d(w: jax.Array, k: int = 6):
    """Host-side packer for matmul weights: (K,N) bf16 ->
    (signman (K,N) u8, planes (k,K,N/32) u32, dict (2^k,) u8, n_escapes).

    k defaults to 6 for at-rest weights: a 63-symbol dictionary empirically
    covers every exponent of real weight tensors (distinct ~23), so the
    fused kernel never sees an escape; ``n_escapes`` lets callers verify.
    """
    from repro.core import fixed
    kk, n = w.shape
    assert n % 32 == 0, "N must be a multiple of 32"
    u16 = E.jnp_to_u16(w)
    signman = E.jnp_signman(u16)
    exp = ((u16 >> 7) & 0xFF).astype(jnp.int32)
    hist = jnp.zeros((256,), jnp.int32).at[exp.reshape(-1)].add(1)
    dict_syms, enc_lut = fixed.build_dictionary(hist, k)
    codes = enc_lut[exp]                              # (K, N)
    esc = fixed.esc_index(k)
    n_escapes = jnp.sum((codes == esc).astype(jnp.int32))
    planes = packing.bitplane_pack(codes, k)          # (K, k, N/32)
    planes = jnp.moveaxis(planes, -2, 0)              # (k, K, N/32)
    return signman, planes, dict_syms, n_escapes


from .decode_attend import WINDOW_NONE  # one sentinel everywhere


def _softmax_attend(q, k, v, ok, scale, softcap, mla: bool):
    """Single-pass masked softmax attention (independent summation order
    from the kernels' online accumulation — a true oracle).

    q (B,H,hd); k/v (B,L,[H,]hd); ok (B,L).  Returns normalized (B,H,hd_v).
    """
    if mla:
        s = jnp.einsum("bhd,bnd->bhn", q, k,
                       preferred_element_type=jnp.float32) * scale
    else:
        s = jnp.einsum("bhd,bnhd->bhn", q, k,
                       preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(ok[:, None, :], s, -2.0e38)
    m = s.max(-1)
    p = jnp.where(ok[:, None, :], jnp.exp(s - m[..., None]), 0.0)
    l = jnp.maximum(p.sum(-1), 1e-30)
    if mla:
        out = jnp.einsum("bhn,bnd->bhd", p, v.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bhn,bnhd->bhd", p, v.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    return out / l[..., None]


def _head_views(vals, kv_idx, hd, mla_lora):
    """(B, L, W) payload -> per-head (k, v) for the oracle attention."""
    if mla_lora is not None:
        return vals, vals[..., :mla_lora]
    b, L, w = vals.shape
    hkv = w // (2 * hd)
    kv = vals.reshape(b, L, hkv, 2, hd)
    kidx = jnp.asarray(kv_idx)
    k = jnp.take(kv[..., 0, :], kidx, axis=2)       # (B, L, H, hd)
    v = jnp.take(kv[..., 1, :], kidx, axis=2)
    return k, v


def decode_attend_ref(q, blocks_bf16, ring, length, *, kv_idx, scale,
                      softcap=None, mla_lora=None, window=WINDOW_NONE,
                      tp=1, ti=0):
    """Oracle for ``decode_attend`` (fixed store): q (B,H,hd); blocks
    (nblk,B,blk,W) decompressed bf16; ring (B,blk,W); length/ti python ints.
    Returns the NORMALIZED single-shard attention (B,H,hd_v) f32 — compare
    against the kernel's out/l."""
    nblk, b, blk, w = blocks_bf16.shape
    loc_len = max((length - 1 - ti) // tp + 1, 0)
    nfull = loc_len // blk
    vals = jnp.concatenate(
        [jnp.moveaxis(blocks_bf16, 0, 1).reshape(b, nblk * blk, w), ring],
        axis=1)
    sl = jnp.concatenate([jnp.arange(nblk * blk),
                          nfull * blk + jnp.arange(blk)])
    live = jnp.concatenate([jnp.arange(nblk * blk) // blk < nfull,
                            nfull * blk + jnp.arange(blk) < loc_len])
    pos = sl * tp + ti
    ok = live & (pos < length) & (pos > length - 1 - window)
    k, v = _head_views(vals, kv_idx, q.shape[-1], mla_lora)
    return _softmax_attend(q, k, v, jnp.broadcast_to(ok[None], (b, ok.size)),
                           scale, softcap, mla_lora is not None)


def paged_decode_attend_ref(q, pages_bf16, page_table, lengths, ring, *,
                            kv_idx, scale, softcap=None, mla_lora=None,
                            window=WINDOW_NONE, tp=1, ti=0):
    """Oracle for ``decode_attend_paged``: q (S,H,hd); pages (P,blk,W)
    decompressed bf16; page_table (S,maxp) int32 (-1 unmapped); lengths (S,)
    ints; ring (S,blk,W).  Returns normalized (S,H,hd_v) f32."""
    n_s, maxp = page_table.shape
    _, blk, w = pages_bf16.shape
    lengths = jnp.asarray(lengths, jnp.int32)
    loc_len = jnp.maximum((lengths - 1 - ti) // tp + 1, 0)      # (S,)
    nfull = loc_len // blk
    gathered = pages_bf16[jnp.clip(page_table, 0, None)]        # (S,maxp,blk,W)
    vals = jnp.concatenate([gathered.reshape(n_s, maxp * blk, w), ring],
                           axis=1)
    sl = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(maxp * blk)[None], (n_s, maxp * blk)),
         nfull[:, None] * blk + jnp.arange(blk)[None]], axis=1)
    live = jnp.concatenate(
        [jnp.arange(maxp * blk)[None] // blk < nfull[:, None],
         nfull[:, None] * blk + jnp.arange(blk)[None] < loc_len[:, None]],
        axis=1)
    pos = sl * tp + ti
    ok = live & (pos < lengths[:, None]) \
        & (pos > lengths[:, None] - 1 - window)
    k, v = _head_views(vals, kv_idx, q.shape[-1], mla_lora)
    return _softmax_attend(q, k, v, ok, scale, softcap, mla_lora is not None)
