"""Pure-jnp oracles for every Pallas kernel in this package.

Layout contract shared by kernels and oracles (and bit-compatible with
``repro.core.fixed`` / ``repro.core.packing``):

* tensors are processed as (G, B) row-major blocks of a flattened stream,
  B = BLOCK_ELEMS (default 32*128 = 4096, MXU/VPU aligned);
* exponent codes are bit-plane packed in flat groups of 32 consecutive
  elements: planes[(g,) b, w] holds bit b of elements 32*w .. 32*w+31 of
  block g;
* the encode LUT maps the 8-bit exponent to a k-bit dictionary index with
  ESCAPE = 2^k - 1; the decode dictionary maps index -> exponent byte.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import entropy as E
from repro.core import packing

BLOCK_ROWS = 32
BLOCK_COLS = 128
BLOCK_ELEMS = BLOCK_ROWS * BLOCK_COLS


def pack_ref(x: jax.Array, enc_lut: jax.Array, k: int):
    """Oracle for ``lexi_pack``: (G, B) bf16 -> (signman (G,B) u8,
    planes (G,k,B/32) u32)."""
    g, b = x.shape
    u16 = E.jnp_to_u16(x)
    signman = E.jnp_signman(u16)
    exp = ((u16 >> 7) & 0xFF).astype(jnp.int32)
    codes = enc_lut[exp]                              # (G, B) uint32
    planes = packing.bitplane_pack(codes, k)          # (G, k, B/32)
    return signman, planes


def unpack_ref(signman: jax.Array, planes: jax.Array, dict_syms: jax.Array,
               k: int) -> jax.Array:
    """Oracle for ``lexi_unpack``: inverse of pack_ref (escapes handled by
    the caller via the side channel)."""
    codes = packing.bitplane_unpack(planes, k)        # (G, B)
    exp = dict_syms[codes.astype(jnp.int32)]          # (G, B) uint8
    u16 = E.jnp_combine(signman, exp)
    return E.jnp_from_u16(u16)


def histogram_ref(x: jax.Array) -> jax.Array:
    """Oracle for ``exp_histogram``: 256-bin exponent histogram (int32)."""
    u16 = E.jnp_to_u16(x)
    exp = ((u16 >> 7) & 0xFF).astype(jnp.int32).reshape(-1)
    return jnp.zeros((256,), jnp.int32).at[exp].add(1)


def decompress_matmul_ref(x: jax.Array, signman: jax.Array, planes: jax.Array,
                          dict_syms: jax.Array, k: int) -> jax.Array:
    """Oracle for ``decompress_matmul``: x (M,K) bf16 @ packed W (K,N).

    ``planes`` is (k, K, N/32): row i's exponent codes are packed along N in
    flat groups of 32 (so W tiles cleanly along both axes).
    """
    kk, n = signman.shape
    codes = packing.bitplane_unpack(jnp.moveaxis(planes, 0, -2), k)  # (K, N)
    exp = dict_syms[codes.astype(jnp.int32)]
    u16 = E.jnp_combine(signman, exp)
    w = E.jnp_from_u16(u16)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def compress_weight_2d(w: jax.Array, k: int = 6):
    """Host-side packer for matmul weights: (K,N) bf16 ->
    (signman (K,N) u8, planes (k,K,N/32) u32, dict (2^k,) u8, n_escapes).

    k defaults to 6 for at-rest weights: a 63-symbol dictionary empirically
    covers every exponent of real weight tensors (distinct ~23), so the
    fused kernel never sees an escape; ``n_escapes`` lets callers verify.
    """
    from repro.core import fixed
    kk, n = w.shape
    assert n % 32 == 0, "N must be a multiple of 32"
    u16 = E.jnp_to_u16(w)
    signman = E.jnp_signman(u16)
    exp = ((u16 >> 7) & 0xFF).astype(jnp.int32)
    hist = jnp.zeros((256,), jnp.int32).at[exp.reshape(-1)].add(1)
    dict_syms, enc_lut = fixed.build_dictionary(hist, k)
    codes = enc_lut[exp]                              # (K, N)
    esc = fixed.esc_index(k)
    n_escapes = jnp.sum((codes == esc).astype(jnp.int32))
    planes = packing.bitplane_pack(codes, k)          # (K, k, N/32)
    planes = jnp.moveaxis(planes, -2, 0)              # (k, K, N/32)
    return signman, planes, dict_syms, n_escapes


def decode_attend_ref(q, blocks_bf16, valid, kv_idx, scale):
    """Oracle for ``decode_attend``: q (B,H,hd); blocks (nblk,B,blk,2*Hkv*hd)
    decompressed bf16; valid (nblk,blk).  Returns (out f32 unnorm, m, l)."""
    nblk, b, blk, w = blocks_bf16.shape
    h = q.shape[1]
    hd = q.shape[-1]
    hkv = w // (2 * hd)
    kv = blocks_bf16.reshape(nblk, b, blk, hkv, 2, hd)
    kidx = jnp.asarray(kv_idx)
    k = jnp.take(kv[:, :, :, :, 0], kidx, axis=3)   # (nblk,b,blk,h,hd)
    v = jnp.take(kv[:, :, :, :, 1], kidx, axis=3)
    s = jnp.einsum("bhd,nbkhd->nbhk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[:, :, None, :], s, -2.0e38)
    s2 = jnp.moveaxis(s, 0, 2).reshape(b, h, -1)    # (b,h,nblk*blk)
    m = s2.max(-1)
    p = jnp.exp(s2 - m[..., None])
    msk = jnp.moveaxis(jnp.broadcast_to(valid[:, :, None, :],
                                        (nblk, b, h, blk)), 0, 2
                       ).reshape(b, h, -1)
    p = jnp.where(msk, p, 0.0)
    l = p.sum(-1)
    v2 = jnp.moveaxis(v, 0, 1).reshape(b, -1, h, hd)   # (b, nblk*blk, h, hd)
    out = jnp.einsum("bhk,bkhd->bhd", p, v2.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out, m, l
