"""Pallas TPU kernel: fused JIT weight decompression + matmul.

The paper stores weights compressed in DRAM/HBM and decompresses them
just-in-time "near compute".  On TPU, "near compute" is VMEM: this kernel
streams packed weight tiles HBM→VMEM, decodes them on the VPU, and feeds the
MXU — HBM weight traffic is the *packed* size, and the decompressed tile
never round-trips to HBM.  This is the memory-roofline payoff of LEXI for
the decode phase (weight-bandwidth-bound).

    out (M,N) f32 = x (M,K) bf16 @ W_packed (K,N)

W_packed = (signman (K,N) u8, planes (k,K,N/32) u32, dict (2^k,) u8), as
produced by ``ref.compress_weight_2d``.  Escape-free tiles only (k=6 at-rest
weights never escape in practice; the param packer verifies at pack time).

Serving shapes are arbitrary (M=1 decode rows, tp-sharded N), so the wrapper
pads every dim up to a block multiple and slices the result: padded x rows/
columns are zero, so the padded K tail contributes exactly 0.0 to every
accumulator (0 × decoded-garbage == 0 — padded plane words decode to
dict[0]'s exponent with a zero mantissa, a finite value), and padded M/N
output is sliced off.  N itself must be a multiple of 32 (the bit-plane
lane width — a pack-time invariant of the format, not a block-shape limit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 32


def _dm_kernel(x_ref, sm_ref, planes_ref, dict_ref, out_ref, *, k: int):
    # --- decode W tile (bk, bn) from packed fields ---------------------------
    sm = sm_ref[...]                                  # (bk, bn) uint8
    words = planes_ref[...]                           # (k, bk, bn/32) uint32
    lane = jnp.arange(LANES, dtype=jnp.uint32)
    codes = jnp.zeros(words.shape[1:] + (LANES,), jnp.uint32)
    for b in range(k):                                # (bk, bn/32, 32)
        bits = (words[b][..., None] >> lane) & jnp.uint32(1)
        codes = codes | (bits << jnp.uint32(b))
    codes = codes.reshape(sm.shape)                   # (bk, bn)
    # hoisted dictionary LUT (pre-widened to u16 by the wrapper, pinned in
    # VMEM by its constant index_map): one gather replaces the former
    # 2^k-iteration where-select — the same pattern decode_attend uses.
    exp = jnp.take(dict_ref[...], codes.astype(jnp.int32))
    smu = sm.astype(jnp.uint16)
    u16 = ((smu & jnp.uint16(0x80)) << 8) | (exp << 7) | (smu & jnp.uint16(0x7F))
    w = jax.lax.bitcast_convert_type(u16, jnp.bfloat16)

    # --- MXU matmul with K-accumulation --------------------------------------
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(x_ref[...], w,
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("k", "bm", "bk", "bn", "interpret"))
def decompress_matmul(x: jax.Array, signman: jax.Array, planes: jax.Array,
                      dict_syms: jax.Array, *, k: int = 6, bm: int = 128,
                      bk: int = 128, bn: int = 256,
                      interpret: bool = True) -> jax.Array:
    """x (M,K) bf16 @ packed W (K,N) -> (M,N) f32.  Any M/K/N (N % 32 == 0):
    non-block-multiple dims are padded in, computed, and sliced back out."""
    m, kk = x.shape
    _, n = signman.shape
    assert n % LANES == 0, "packed N must be a multiple of 32 (bit-plane lanes)"
    bm, bk, bn = min(bm, m), min(bk, kk), min(bn, n)
    mp = -(-m // bm) * bm
    kp = -(-kk // bk) * bk
    np_ = -(-n // bn) * bn
    if mp != m or kp != kk:
        x = jnp.pad(x, ((0, mp - m), (0, kp - kk)))
    if kp != kk or np_ != n:
        signman = jnp.pad(signman, ((0, kp - kk), (0, np_ - n)))
        planes = jnp.pad(planes, ((0, 0), (0, kp - kk),
                                  (0, (np_ - n) // LANES)))
    dict_lut = dict_syms.astype(jnp.uint16)
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_dm_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((k, bk, bn // LANES), lambda i, j, l: (0, l, j)),
            pl.BlockSpec((dict_lut.shape[0],), lambda i, j, l: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(x, signman, planes, dict_lut)
    if mp != m or np_ != n:
        out = out[:m, :n]
    return out
