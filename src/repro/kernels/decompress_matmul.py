"""Pallas TPU kernel: fused JIT weight decompression + matmul.

The paper stores weights compressed in DRAM/HBM and decompresses them
just-in-time "near compute".  On TPU, "near compute" is VMEM: this kernel
streams packed weight tiles HBM→VMEM, decodes them on the VPU, and feeds the
MXU — HBM weight traffic is the *packed* size, and the decompressed tile
never round-trips to HBM.  This is the memory-roofline payoff of LEXI for
the decode phase (weight-bandwidth-bound).

    out (M,N) f32 = x (M,K) bf16 @ W_packed (K,N)

W_packed = (signman (K,N) u8, planes (k,K,N/32) u32, dict (2^k,) u8), as
produced by ``ref.compress_weight_2d``.  Escape-free tiles only (k=6 at-rest
weights never escape in practice; ``ops.decompress_matmul`` verifies).

Block shapes are MXU-aligned (bm, bk, bn multiples of 128 for the dot dims;
bn additionally a multiple of 32 for the planes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 32


def _dm_kernel(x_ref, sm_ref, planes_ref, dict_ref, out_ref, *, k: int):
    # --- decode W tile (bk, bn) from packed fields ---------------------------
    sm = sm_ref[...]                                  # (bk, bn) uint8
    words = planes_ref[...]                           # (k, bk, bn/32) uint32
    lane = jnp.arange(LANES, dtype=jnp.uint32)
    codes = jnp.zeros(words.shape[1:] + (LANES,), jnp.uint32)
    for b in range(k):                                # (bk, bn/32, 32)
        bits = (words[b][..., None] >> lane) & jnp.uint32(1)
        codes = codes | (bits << jnp.uint32(b))
    codes = codes.reshape(sm.shape)                   # (bk, bn)
    d = dict_ref[...]
    exp = jnp.zeros(sm.shape, jnp.uint16)
    for j in range(d.shape[0]):                       # unrolled select-sum
        exp = jnp.where(codes == jnp.uint32(j), jnp.uint16(0) + d[j], exp)
    smu = sm.astype(jnp.uint16)
    u16 = ((smu & jnp.uint16(0x80)) << 8) | (exp << 7) | (smu & jnp.uint16(0x7F))
    w = jax.lax.bitcast_convert_type(u16, jnp.bfloat16)

    # --- MXU matmul with K-accumulation --------------------------------------
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(x_ref[...], w,
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("k", "bm", "bk", "bn", "interpret"))
def decompress_matmul(x: jax.Array, signman: jax.Array, planes: jax.Array,
                      dict_syms: jax.Array, *, k: int = 6, bm: int = 128,
                      bk: int = 128, bn: int = 256,
                      interpret: bool = True) -> jax.Array:
    """x (M,K) bf16 @ packed W (K,N) -> (M,N) f32."""
    m, kk = x.shape
    _, n = signman.shape
    bm, bk, bn = min(bm, m), min(bk, kk), min(bn, n)
    assert m % bm == 0 and kk % bk == 0 and n % bn == 0 and bn % LANES == 0
    grid = (m // bm, n // bn, kk // bk)
    return pl.pallas_call(
        functools.partial(_dm_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((k, bk, bn // LANES), lambda i, j, l: (0, l, j)),
            pl.BlockSpec((dict_syms.shape[0],), lambda i, j, l: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, signman, planes, dict_syms)
