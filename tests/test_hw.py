"""Hardware-model tests: M-lane cache, staged LUT decoder, area table and
the Simba NoC simulator land in the paper's reported bands."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import bitstream, codec, entropy
from repro.hw import area, lanecache, lut_decoder, noc

RNG = np.random.default_rng(0)


def exp_stream(n=100_000):
    x = RNG.normal(0, 0.05, n).astype(np.float32)
    return entropy.split_fields(entropy.to_bf16_u16(x))[1]


class TestLaneCache:
    def test_fig4_hit_rate(self):
        exp = exp_stream(20_000)
        hr = {d: lanecache.simulate_lanes(exp, 10, d).hit_rate
              for d in (2, 4, 8, 16)}
        assert hr[8] > 0.90                    # paper: >90 % at depth 8
        assert hr[2] < hr[4] < hr[8] < hr[16]  # monotone in depth

    def test_fig5_latency_points(self):
        exp = exp_stream()
        l_small = lanecache.codebook_latency_cycles(exp, 1, 4)
        l_mid = lanecache.codebook_latency_cycles(exp, 10, 8)
        l_big = lanecache.codebook_latency_cycles(exp, 32, 16)
        assert 600 <= l_small <= 1100          # paper: 788 ns
        assert 40 <= l_mid <= 80               # paper: ~55 ns
        assert 10 <= l_big <= 25               # paper: ~17 ns
        assert lanecache.cache_size_bytes(10, 8) == 160  # 0.625 KiB/4

    def test_pipeline_constant(self):
        assert lanecache.PIPELINE_CYCLES == 78  # 15 + 31 + 32


class TestLutDecoder:
    def test_staged_equals_canonical(self):
        exp = exp_stream(4000).copy()
        exp[::101] = RNG.integers(0, 256, exp[::101].shape)  # force escapes
        stm = bitstream.encode(exp)
        tr = lut_decoder.decode_staged(stm)
        assert np.array_equal(tr.symbols, exp)

    def test_most_resolve_stage1(self):
        stm = bitstream.encode(exp_stream(4000))
        tr = lut_decoder.decode_staged(stm)
        assert tr.stage_hits[0] / sum(tr.stage_hits) > 0.95

    def test_fig6_area_points(self):
        assert abs(lut_decoder.decoder_area_um2((8, 16, 24, 32)) - 98.5) < 0.1
        assert abs(lut_decoder.decoder_area_um2((32,)) - 157.6) < 0.1


class TestArea:
    def test_table4_totals(self):
        la = area.LexiArea()
        assert abs(la.total_um2 - 14995.2) < 1.0
        assert abs(la.total_mw - 45.43) < 0.1
        assert abs(la.total_um2_16nm - 5452.8) < 1.0
        assert abs(la.chiplet_overhead - 0.0009) < 2e-4  # 0.09 %


class TestNoC:
    def test_paper_bands(self):
        x = RNG.normal(0, 0.05, 300_000).astype(np.float32)
        cr = codec.overall_bf16_ratio(codec.measure_crs(x)["lexi"])
        crs = {"weights": cr, "activations": cr, "cache": cr}
        for name in ("jamba-tiny-dev", "zamba2-1.2b", "qwen1.5-1.8b"):
            res = noc.simulate(get_config(name), in_tokens=1024,
                               out_tokens=512, crs=crs)
            u, l = res["uncompressed"], res["lexi"]
            comm_red = 1 - l.comm_ms / u.comm_ms
            e2e_red = 1 - l.e2e_ms / u.e2e_ms
            assert 0.30 <= comm_red <= 0.48, name   # paper: 33-45 %
            assert 0.28 <= e2e_red <= 0.40, name    # paper: 30-35 %
            assert u.comm_ms / u.e2e_ms > 0.65, name  # comm-dominated

    def test_weights_only_between(self):
        x = RNG.normal(0, 0.05, 100_000).astype(np.float32)
        cr = codec.overall_bf16_ratio(codec.measure_crs(x)["lexi"])
        crs = {"weights": cr, "activations": cr, "cache": cr}
        res = noc.simulate(get_config("qwen1.5-1.8b"), in_tokens=1024,
                           out_tokens=512, crs=crs)
        assert (res["lexi"].comm_ms < res["weights_only"].comm_ms
                < res["uncompressed"].comm_ms)
