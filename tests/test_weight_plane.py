"""Serving weight plane: LEXI-packed at-rest params (``core.weights``) must
be invisible to the token stream — serving from the packed store has to emit
bit-identical tokens to raw bf16 weights across dense / hybrid / MoE configs
and both weight backends (exact unpack-then-einsum and the fused
decompress_matmul kernel), while the store itself holds fewer HBM bytes."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig, RunConfig, SSMConfig
from repro.core import weights as W
from repro.core.collectives import CodecConfig
from repro.kernels import ops as kops
from repro.serve import Request, ServeEngine

RNG = np.random.default_rng(0)


class TestResolveWeightBackend:
    def test_auto(self):
        want = "pallas" if kops.on_tpu() else "jax"
        assert kops.resolve_weight_backend(CodecConfig()) == want
        assert kops.resolve_weight_backend(None) == want

    @pytest.mark.parametrize("be", ["pallas", "interpret", "jax"])
    def test_explicit(self, be):
        codec = dataclasses.replace(CodecConfig(), weight_backend=be)
        assert kops.resolve_weight_backend(codec) == be

    def test_invalid(self):
        codec = dataclasses.replace(CodecConfig(), weight_backend="zorp")
        with pytest.raises(ValueError, match="weight_backend"):
            kops.resolve_weight_backend(codec)


def _tree():
    mk = lambda shape, std=0.05: jnp.asarray(RNG.normal(0, std, shape),
                                             jnp.bfloat16)
    return {
        "embed": mk((512, 64)),          # gather consumer -> stays raw
        "blocks": {
            "wq": mk((64, 64)),          # 4096 elems -> packs
            "stack": mk((3, 64, 64)),    # stacked (scan) leaf -> packs
            "scale": jnp.ones((64,), jnp.bfloat16),   # 1-D -> raw
            "small": mk((8, 8)),         # below MIN_COMPRESS_SIZE -> raw
        },
    }


def _specs():
    return {
        "embed": P(None, "model"),
        "blocks": {"wq": P(None, "model"), "stack": P(None, None, "model"),
                   "scale": P(), "small": P()},
    }


class TestPackServingParams:
    def test_eligibility_and_losslessness(self):
        params = _tree()
        pk, sp = W.pack_serving_params(params, _specs(), backend="jax", tp=1)
        assert isinstance(pk["blocks"]["wq"], W.PackedWeight)
        assert isinstance(pk["blocks"]["stack"], W.PackedWeight)
        assert not isinstance(pk["embed"], W.PackedWeight)
        assert not isinstance(pk["blocks"]["scale"], W.PackedWeight)
        assert not isinstance(pk["blocks"]["small"], W.PackedWeight)
        # the packed store decodes back bit-exactly
        for name in ("wq", "stack"):
            assert jnp.array_equal(W.unpack_weight(pk["blocks"][name]),
                                   params["blocks"][name]), name
        # specs mirror the packed layout for shard_map tree matching
        assert isinstance(sp["blocks"]["wq"], W.PackedWeight)
        assert sp["blocks"]["wq"].signman == P(None, "model")
        assert sp["blocks"]["stack"].planes == P(None, None, None, "model")
        assert sp["embed"] == P(None, "model")

    def test_idempotent(self):
        pk, sp = W.pack_serving_params(_tree(), _specs(), backend="jax")
        pk2, sp2 = W.pack_serving_params(pk, sp, backend="jax")
        assert pk2["blocks"]["wq"] is pk["blocks"]["wq"]
        assert jax.tree.structure(pk2) == jax.tree.structure(pk)

    def test_bytes_metering(self):
        params = _tree()
        pk, _ = W.pack_serving_params(params, _specs(), backend="jax")
        stored, raw = W.weight_plane_bytes(pk)
        want_raw = sum(2 * l.size for l in jax.tree.leaves(params))
        assert raw == want_raw
        assert stored < raw
        # adaptive k picks the smallest escape-free dictionary
        assert 4 <= pk["blocks"]["wq"].k <= 6

    def test_tp_sharded_n_must_stay_lane_aligned(self):
        # local N = 40 at tp=2 -> 20 per shard, not %32: leaf stays raw
        mk = lambda s: jnp.asarray(RNG.normal(0, 0.05, s), jnp.bfloat16)
        params = {"w": mk((128, 40))}
        pk, _ = W.pack_serving_params(params, {"w": P(None, "model")}, tp=2)
        assert not isinstance(pk["w"], W.PackedWeight)


# tiny serving configs: d_ff / vocab sized so attention, MLP, MoE-expert and
# LM-head leaves all clear MIN_COMPRESS_SIZE and lane alignment (vocab 512,
# expert d_ff 64) — the packed plane is actually exercised, not bypassed
CASES = {
    "dense": ModelConfig(name="t2", family="dense", n_layers=2, d_model=64,
                         n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=512,
                         head_dim=16),
    "hybrid": ModelConfig(
        name="h", family="hybrid", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
        parallel_hybrid=True, attn_layout="hymba_3global", window=16,
        ssm=SSMConfig(d_state=16, headdim=8, chunk=16), sub_quadratic=True),
    "moe": ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=512,
                       head_dim=16,
                       moe=MoEConfig(n_experts=4, top_k=2, d_ff=64,
                                     n_shared=1, capacity_factor=4.0)),
}


def _run_cfg(wb: str) -> RunConfig:
    codec = dataclasses.replace(CodecConfig(cache_block=4),
                                decode_backend="jax", weight_backend=wb)
    return RunConfig(codec=codec)


def _requests():
    rng = np.random.default_rng(7)
    specs = [(8, 4), (16, 3), (12, 4)]
    return [Request(uid=i,
                    prompt=rng.integers(0, 512, (s,)).astype(np.int32),
                    max_new_tokens=n) for i, (s, n) in enumerate(specs)]


_RAW_TOKENS = {}


def _raw_tokens(case, tp=1):
    """Raw-weights reference stream, computed once per (case, tp)."""
    if (case, tp) not in _RAW_TOKENS:
        eng = ServeEngine(CASES[case], _run_cfg("auto"), tp=tp, n_slots=2,
                          max_len=48, seed=1)
        res, st = eng.run(_requests())
        assert not st.weights_compressed
        _RAW_TOKENS[(case, tp)] = [r.tokens for r in res]
    return _RAW_TOKENS[(case, tp)]


@pytest.mark.parametrize("wb", ["jax", "interpret"])
@pytest.mark.parametrize("case", sorted(CASES))
def test_stream_identity_packed_vs_raw(case, wb):
    eng = ServeEngine(CASES[case], _run_cfg(wb), tp=1, n_slots=2,
                      max_len=48, seed=1, compress_weights=True)
    res, st = eng.run(_requests())
    assert [r.tokens for r in res] == _raw_tokens(case)
    assert st.weights_compressed
    assert st.weight_backend == wb
    # something actually packed, and the metered store shrank
    assert st.weight_bytes_per_step < st.weight_raw_bytes_per_step
    assert st.weight_ratio < 0.95


def test_stream_identity_tp2_fused():
    """Fused kernel under shard_map: tp=2 packed serving must match the
    tp=2 raw stream token-for-token."""
    eng = ServeEngine(CASES["dense"], _run_cfg("interpret"), tp=2,
                      n_slots=2, max_len=48, seed=1, compress_weights=True)
    res, st = eng.run(_requests())
    assert [r.tokens for r in res] == _raw_tokens("dense", tp=2)
    assert st.weights_compressed and st.weight_ratio < 0.95
