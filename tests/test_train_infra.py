"""Training-substrate tests: checkpointing (atomic, compressed, checksummed),
fault tolerance, data pipeline determinism, optimizer invariants."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MeshConfig, RunConfig
from repro.data import pipeline as data_mod
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train import fault
from repro.train import train_step as TS

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=500,
                  head_dim=16)


def _state():
    table = lm.lm_table(CFG, MeshConfig(1, 1, 1), RunConfig())
    return TS.init_state(table, seed=3)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        st = _state()
        ckpt.save(str(tmp_path), 7, st)
        st2 = ckpt.restore(str(tmp_path), st)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
            assert np.array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))

    def test_latest_and_multiple(self, tmp_path):
        st = _state()
        ckpt.save(str(tmp_path), 5, st)
        ckpt.save(str(tmp_path), 10, st)
        assert ckpt.latest_step(str(tmp_path)) == 10

    def test_compression_actually_compresses(self, tmp_path):
        st = _state()
        ckpt.save(str(tmp_path), 1, st.params)   # bf16-only tree
        sz = ckpt.stored_size(str(tmp_path), 1)
        assert sz["stored_bytes"] < sz["raw_bytes"] * 0.75

    def test_corruption_detected(self, tmp_path):
        st = _state()
        d = ckpt.save(str(tmp_path), 2, st)
        victim = [f for f in sorted(os.listdir(d)) if f.startswith("leaf")][0]
        with open(os.path.join(d, victim), "r+b") as fh:
            fh.seek(10)
            fh.write(b"\xde\xad")
        with pytest.raises(IOError):
            ckpt.restore(str(tmp_path), st, step=2)

    def test_atomic_no_partial_latest(self, tmp_path):
        # a .tmp_ directory must never be advertised via LATEST
        st = _state()
        ckpt.save(str(tmp_path), 3, st)
        assert not any(f.startswith(".tmp") for f in os.listdir(tmp_path)
                       if os.path.isdir(os.path.join(tmp_path, f))
                       and ckpt.latest_step(str(tmp_path)) == 3)


class TestFault:
    def test_straggler_detection(self):
        mon = fault.StragglerMonitor(tolerance=2.0)
        for i in range(20):
            mon.record(i, 0.1)
        assert mon.record(20, 0.5)          # 5x p95
        assert 20 in mon.straggler_steps

    def test_watchdog(self):
        wd = fault.Watchdog(deadline_s=0.0)
        wd.arm()
        import time
        time.sleep(0.01)
        assert wd.expired
        wd.disarm()
        assert not wd.expired

    def test_restart_driver(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise fault.SimulatedFailure("boom")
            return {"ok": True}

        out = fault.run_with_restarts(flaky, max_restarts=5, backoff_s=0,
                                      log=lambda *_: None)
        assert out["ok"] and out["restarts"] == 2

    def test_restart_exhaustion(self):
        def always():
            raise fault.SimulatedFailure("dead")

        with pytest.raises(fault.SimulatedFailure):
            fault.run_with_restarts(always, max_restarts=1, backoff_s=0,
                                    log=lambda *_: None)


class TestDataPipeline:
    def test_deterministic_and_resumable(self):
        d1 = data_mod.SyntheticLM(vocab_size=1000, global_batch=4, seq_len=32,
                                  seed=1)
        d2 = data_mod.SyntheticLM(vocab_size=1000, global_batch=4, seq_len=32,
                                  seed=1)
        b1 = d1.batch_at(17)
        b2 = d2.batch_at(17)
        assert np.array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(d1.batch_at(18)["tokens"], b1["tokens"])

    def test_labels_are_shifted(self):
        d = data_mod.SyntheticLM(vocab_size=1000, global_batch=2, seq_len=16)
        b = d.batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)

    def test_zipf_distribution(self):
        d = data_mod.SyntheticLM(vocab_size=1000, global_batch=64,
                                 seq_len=256)
        toks = np.asarray(d.batch_at(0)["tokens"]).reshape(-1)
        counts = np.bincount(toks, minlength=1000)
        assert counts[0] > counts[100] > counts[900]

    def test_multimodal_extras(self):
        d = data_mod.SyntheticLM(vocab_size=1000, global_batch=2, seq_len=16,
                                 d_model=32, n_front_tokens=4,
                                 enc_embeds=True)
        b = d.batch_at(0)
        assert b["front_embeds"].shape == (2, 4, 32)
        assert b["enc_embeds"].shape == (2, 16, 32)


class TestOptimizer:
    def test_global_norm_replication_consistent(self, mesh24):
        """Replicated leaves must not be double counted across shards."""
        from jax.sharding import PartitionSpec as P
        from repro.core import collectives as cl
        from repro.train import optimizer as opt

        g = {"rep": jnp.ones((8, 4), jnp.float32),
             "shard": jnp.ones((8, 4), jnp.float32)}
        specs = {"rep": P(None, None), "shard": P("model", None)}

        def norm(t):
            return opt.global_norm(t, specs, ("data", "model"))

        got = jax.jit(cl.shmap(norm, mesh24, (specs,), P()))(g)
        # both leaves are (8,4) of ones GLOBALLY: the sharded leaf's local
        # sums psum back to 32; the replicated leaf counts once -> sqrt(64).
        want = np.sqrt(8 * 4 + 8 * 4)
        assert abs(float(got) - want) < 1e-4
