"""Optional pipeline-parallel feature: staged execution == sequential."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import collectives as cl
from repro.core.collectives import CodecConfig
from repro.sharding import pipeline as pp


@pytest.fixture(scope="module")
def mesh_stage():
    return jax.make_mesh((4,), ("stage",))


def test_pipeline_matches_sequential(mesh_stage):
    rng = np.random.default_rng(0)
    # 4 stages, each multiplies by its own matrix
    ws = jnp.asarray(rng.normal(0, 0.5, (4, 16, 16)), jnp.bfloat16)
    x = jnp.asarray(rng.normal(0, 1, (8, 4, 16)), jnp.bfloat16)  # 8 microb.

    def stage_fn(w, v):
        return jnp.einsum("bd,dk->bk", v, w[0]).astype(jnp.bfloat16)

    def piped(w, v):
        return pp.pipeline_forward(stage_fn, w, v, axis="stage",
                                   codec=CodecConfig())

    out = jax.jit(cl.shmap(piped, mesh_stage,
                           (P("stage"), P(None)), P(None)))(ws, x)
    # reference: sequential through all 4 stages
    ref = x
    for s in range(4):
        ref = jnp.einsum("mbd,dk->mbk", ref, ws[s]).astype(jnp.bfloat16)
    # pipeline output is valid on the last stage; out_specs P(None) takes
    # shard 0's copy — so compare only where the last stage banked results.
    # Instead re-run with out spec selecting the last stage via psum trick:
    def piped_last(w, v):
        y = pp.pipeline_forward(stage_fn, w, v, axis="stage",
                                codec=CodecConfig())
        sidx = jax.lax.axis_index("stage")
        return jax.lax.psum(jnp.where(sidx == 3, y.astype(jnp.float32), 0.0),
                            "stage")

    out = jax.jit(cl.shmap(piped_last, mesh_stage,
                           (P("stage"), P(None)), P(None)))(ws, x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref, np.float32), rtol=0.05,
                               atol=0.05)
