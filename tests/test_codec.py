"""Core LEXI codec tests: LEXI-H (Huffman) and LEXI-FW (fixed-width),
including property tests on the system's losslessness invariant.

The property tests use ``hypothesis`` when it is installed; otherwise they
fall back to a fixed-seed corpus of adversarial arrays exercising the same
roundtrip properties, so collection never errors in minimal environments.
"""

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # minimal env: property tests run on fixed corpus
    hypothesis = hnp = st = None
    HAVE_HYPOTHESIS = False

from repro.core import (baselines, bitstream, codec, entropy, fixed, huffman,
                        packing)

RNG = np.random.default_rng(0)


def _corpus_arrays(dtype, max_n, n_cases=12, seed=7):
    """Fixed-seed stand-in for hypothesis array strategies: edge-case sizes,
    all-zero / all-max / random bit patterns."""
    rng = np.random.default_rng(seed)
    info = np.iinfo(dtype)
    out = [np.zeros(1, dtype), np.full(2, info.max, dtype),
           np.zeros(max_n, dtype), np.full(max_n, info.max, dtype)]
    for _ in range(n_cases):
        n = int(rng.integers(1, max_n + 1))
        out.append(rng.integers(0, int(info.max) + 1, n).astype(dtype))
    return out


def _exp_stream(n=20_000, std=0.05):
    x = RNG.normal(0, std, n).astype(np.float32)
    u16 = entropy.to_bf16_u16(x)
    return entropy.split_fields(u16)[1]


# ---------------------------------------------------------------------------
# LEXI-H: canonical length-limited Huffman
# ---------------------------------------------------------------------------

class TestHuffman:
    def test_kraft_equality(self):
        hist = np.bincount(_exp_stream(), minlength=256).astype(float)
        lengths = huffman.length_limited_lengths(hist)
        assert abs(sum(2.0 ** -l for l in lengths.values()) - 1.0) < 1e-9

    def test_optimality_vs_entropy(self):
        exp = _exp_stream()
        hist = np.bincount(exp, minlength=256).astype(float)
        h = entropy.shannon_entropy(hist)
        book = huffman.build_codebook(hist)
        bits = huffman.code_cost_bits(hist, book) / hist.sum()
        assert h <= bits <= h + 1.0 + 1e-6  # within 1 bit of entropy

    def test_length_limit_respected(self):
        # adversarial: exponential frequencies force deep trees
        freqs = np.zeros(256)
        freqs[:30] = [2.0 ** i for i in range(30)]
        book = huffman.build_codebook(freqs, max_len=12)
        assert int(book.lengths.max()) <= 12

    def test_roundtrip_basic(self):
        exp = _exp_stream(5000)
        stm = bitstream.encode(exp)
        assert np.array_equal(bitstream.decode(stm), exp)

    def test_roundtrip_with_escapes(self):
        exp = _exp_stream(5000).copy()
        exp[::37] = RNG.integers(0, 256, exp[::37].shape).astype(np.uint8)
        book = huffman.build_codebook(
            np.bincount(exp[:512], minlength=256).astype(float))
        stm = bitstream.encode(exp, book)
        assert np.array_equal(bitstream.decode(stm), exp)

    def test_codebook_serialization(self):
        exp = _exp_stream(2000)
        stm = bitstream.encode(exp)
        blob = bitstream.serialize_codebook(stm.book)
        book2, _ = bitstream.deserialize_codebook(blob)
        assert np.array_equal(book2.symbols, stm.book.symbols)
        assert np.array_equal(book2.enc_code, stm.book.enc_code)

    def test_container_roundtrip(self):
        x = RNG.normal(0, 0.02, 4096).astype(np.float32)
        u16 = entropy.to_bf16_u16(x)
        blob = bitstream.compress_bf16(u16)
        assert np.array_equal(bitstream.decompress_bf16(blob), u16)
        assert len(blob) < u16.nbytes  # actually compresses

    if HAVE_HYPOTHESIS:
        @hypothesis.given(hnp.arrays(np.uint8, st.integers(1, 400)))
        @hypothesis.settings(max_examples=30, deadline=None)
        def test_property_any_bytes_roundtrip(self, exp):
            """Losslessness holds for ARBITRARY exponent streams (escapes)."""
            stm = bitstream.encode(exp)
            assert np.array_equal(bitstream.decode(stm), exp)

    def test_corpus_any_bytes_roundtrip(self):
        """Fixed-seed stand-in for the hypothesis property above."""
        for exp in _corpus_arrays(np.uint8, 400):
            stm = bitstream.encode(exp)
            assert np.array_equal(bitstream.decode(stm), exp)

    def test_cr_matches_paper(self):
        """Table 2: LEXI ≈ 3.1x on bell-shaped weight exponents."""
        cr = huffman.compression_ratio(_exp_stream(200_000))
        assert 2.8 <= cr <= 3.5


# ---------------------------------------------------------------------------
# baselines (Table 2 comparison codecs)
# ---------------------------------------------------------------------------

class TestBaselines:
    def test_rle_expands_on_weights(self):
        assert baselines.rle_cr(_exp_stream(100_000)) < 1.0  # paper: 0.64x

    def test_rle_compresses_runs(self):
        assert baselines.rle_cr(np.full(1000, 7, np.uint8)) > 50

    def test_bdi_in_paper_band(self):
        cr = baselines.bdi_cr(_exp_stream(200_000))
        assert 2.0 <= cr <= 2.6  # paper: 2.36-2.43x

    def test_ordering_matches_table2(self):
        exp = _exp_stream(100_000)
        rle = baselines.rle_cr(exp)
        bdi = baselines.bdi_cr(exp)
        lexi = huffman.compression_ratio(exp)
        assert rle < 1.0 < bdi < lexi


# ---------------------------------------------------------------------------
# bit-plane packing
# ---------------------------------------------------------------------------

class TestPacking:
    @pytest.mark.parametrize("k", [1, 3, 5, 6, 8])
    def test_roundtrip(self, k):
        codes = jnp.asarray(RNG.integers(0, 1 << k, 32 * 40), jnp.uint32)
        planes = packing.bitplane_pack(codes, k)
        assert planes.shape == (k, 40)
        assert jnp.array_equal(packing.bitplane_unpack(planes, k), codes)

    def test_batched(self):
        codes = jnp.asarray(RNG.integers(0, 32, (3, 64)), jnp.uint32)
        planes = packing.bitplane_pack(codes, 5)
        assert planes.shape == (3, 5, 2)
        assert jnp.array_equal(packing.bitplane_unpack(planes, 5), codes)


# ---------------------------------------------------------------------------
# LEXI-FW (deployment codec)
# ---------------------------------------------------------------------------

class TestFixedCodec:
    @pytest.mark.parametrize("k", [4, 5, 6])
    @pytest.mark.parametrize("shape", [(1000,), (33, 77), (4, 5, 129)])
    def test_roundtrip_shapes(self, k, shape):
        x = jnp.asarray(RNG.normal(0, 0.3, shape), jnp.bfloat16)
        ct = fixed.compress(x, k=k)
        xr = fixed.decompress(ct)
        assert xr.shape == x.shape
        assert jnp.array_equal(
            jax.lax.bitcast_convert_type(xr, jnp.uint16),
            jax.lax.bitcast_convert_type(x, jnp.uint16))

    def test_special_values(self):
        vals = [0.0, -0.0, 1e-38, -1e38, 1e38, float("inf"), 1.5, -2.25]
        x = jnp.asarray(np.array(vals * 16, np.float32)).astype(jnp.bfloat16)
        ct = fixed.compress(x)
        assert jnp.array_equal(
            jax.lax.bitcast_convert_type(fixed.decompress(ct), jnp.uint16),
            jax.lax.bitcast_convert_type(x, jnp.uint16))

    def test_escape_overflow_detected(self):
        # > 2^k-1 distinct exponents and tiny escape capacity
        x = jnp.asarray((2.0 ** np.arange(-60, 60, 0.5)), jnp.bfloat16)
        ct = fixed.compress(x, k=4, esc_capacity=8)
        assert int(ct.n_escapes) > 8  # overflow is *reported*

    if HAVE_HYPOTHESIS:
        @hypothesis.given(hnp.arrays(np.uint16, st.integers(1, 300)))
        @hypothesis.settings(max_examples=40, deadline=None)
        def test_property_lossless_with_capacity(self, bits):
            """With sufficient escape capacity the codec round-trips
            ARBITRARY bf16 bit patterns exactly — including ±0, subnormals,
            ±inf and NaN payloads (the codec never interprets the value)."""
            xj = jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)
            ct = fixed.compress(xj, k=4, esc_capacity=bits.size + 8)
            xr = fixed.decompress(ct)
            assert jnp.array_equal(
                jax.lax.bitcast_convert_type(xr, jnp.uint16),
                jax.lax.bitcast_convert_type(xj, jnp.uint16))

    def test_corpus_lossless_with_capacity(self):
        for bits in _corpus_arrays(np.uint16, 300):
            xj = jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)
            ct = fixed.compress(xj, k=4, esc_capacity=bits.size + 8)
            xr = fixed.decompress(ct)
            assert jnp.array_equal(
                jax.lax.bitcast_convert_type(xr, jnp.uint16),
                jax.lax.bitcast_convert_type(xj, jnp.uint16))

    def test_wire_ratio(self):
        x = jnp.asarray(RNG.normal(0, 1, 100_000), jnp.bfloat16)
        ct = fixed.compress(x)
        assert 1.15 <= ct.ratio() <= 1.35  # k=5 => ~1.2x

    def test_compress_jits_and_vmaps(self):
        x = jnp.asarray(RNG.normal(0, 1, (4, 2048)), jnp.bfloat16)
        cts = jax.vmap(lambda v: fixed.compress(v, k=5))(x)
        xr = jax.vmap(fixed.decompress)(cts)
        assert jnp.array_equal(
            jax.lax.bitcast_convert_type(xr, jnp.uint16),
            jax.lax.bitcast_convert_type(x, jnp.uint16))


# ---------------------------------------------------------------------------
# profiling / Fig-1 claims
# ---------------------------------------------------------------------------

class TestEntropyProfile:
    def test_fig1_claims(self):
        st_ = entropy.profile_exponents(RNG.normal(0, 0.02, 500_000))
        assert st_.exp_entropy_bits < 3.0          # paper: < 3 bits
        assert st_.distinct_exponents < 32         # paper: < 32 values
        assert st_.man_entropy_bits > 6.5          # mantissa incompressible
        assert st_.top32_coverage > 0.9999
        assert 2.8 < st_.exp_cr < 3.5              # ~3.1x
        assert 1.4 < st_.overall_cr < 1.6          # ~1.5x whole-value

    def test_jnp_field_helpers_match_numpy(self):
        x = RNG.normal(0, 0.1, 4096).astype(np.float32)
        u16 = entropy.to_bf16_u16(x)
        xj = jnp.asarray(x).astype(jnp.bfloat16)
        u16j = entropy.jnp_to_u16(xj)
        assert np.array_equal(np.asarray(u16j), u16)
        hist = entropy.jnp_exponent_histogram(
            ((u16j >> 7) & 0xFF).astype(jnp.uint8))
        assert np.array_equal(np.asarray(hist),
                              entropy.exponent_histogram(
                                  entropy.split_fields(u16)[1]).astype(int))


class TestLexiF32:
    """Beyond-paper: exponent-only coding applied to float32 (checkpointed
    optimizer states)."""

    @pytest.mark.parametrize("gen", ["normal", "tiny", "squared"])
    def test_roundtrip_bit_exact(self, gen):
        rng = np.random.default_rng(3)
        x = {"normal": rng.normal(0, 0.02, 50_000),
             "tiny": rng.normal(0, 1e-5, 50_000),
             "squared": rng.normal(0, 1e-2, 50_000) ** 2}[gen]
        x = x.astype(np.float32)
        blob = bitstream.compress_f32(x)
        back = bitstream.decompress_f32(blob)
        assert np.array_equal(back.view(np.uint32), x.view(np.uint32))
        assert len(blob) < x.nbytes          # actually compresses

    if HAVE_HYPOTHESIS:
        @hypothesis.given(hnp.arrays(np.uint32, st.integers(1, 200)))
        @hypothesis.settings(max_examples=25, deadline=None)
        def test_property_any_bits(self, bits):
            x = bits.view(np.float32)
            back = bitstream.decompress_f32(bitstream.compress_f32(x))
            assert np.array_equal(back.view(np.uint32), bits)

    def test_corpus_any_bits(self):
        for bits in _corpus_arrays(np.uint32, 200):
            x = bits.view(np.float32)
            back = bitstream.decompress_f32(bitstream.compress_f32(x))
            assert np.array_equal(back.view(np.uint32), bits)

    def test_checkpoint_integration(self, tmp_path):
        import jax
        from repro.train import checkpoint as ckpt
        state = {"w": jnp.asarray(np.random.default_rng(0).normal(
            0, 0.02, (128, 64)).astype(np.float32))}
        ckpt.save(str(tmp_path), 1, state)
        sz = ckpt.stored_size(str(tmp_path), 1)
        assert sz["stored_bytes"] < sz["raw_bytes"] * 0.9
        back = ckpt.restore(str(tmp_path), state)
        assert np.array_equal(np.asarray(back["w"]), np.asarray(state["w"]))
