"""Multi-process page transport tests: socket framing is loud on
truncation/corruption, the hello handshake refuses version/config
mismatches, mid-stream disconnects leave the receiving pool untouched, the
receiver-side digest store is LRU-bounded with eviction/re-send
accounting, and a DisaggEngine driving a decode replica over
SocketTransport — in-process (threaded host) AND across two OS processes —
serves token streams byte-identical to the monolithic engine."""

import dataclasses
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.configs.base import ModelConfig, RunConfig
from repro.core.collectives import CodecConfig
from repro.serve import (DecodeReplica, DigestStore, DisaggEngine,
                         LoopbackTransport, PageHost, Request, ServeEngine,
                         SocketTransport)
from repro.serve.net import framing as fr
from repro.serve.transport import (_page_digest, pack_chunk, unpack_chunk)

RNG = np.random.default_rng(11)

CFG = ModelConfig(name="t1", family="dense", n_layers=2, d_model=64,
                  n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=500,
                  head_dim=16)
MAXLEN = 64


def _run_cfg(codec_on=True):
    codec = (CodecConfig(cache_block=4) if codec_on
             else dataclasses.replace(CodecConfig.off(), cache_block=4))
    return RunConfig(codec=dataclasses.replace(codec, decode_backend="jax"))


def _requests(n=4):
    a = RNG.integers(0, 500, (12,)).astype(np.int32)
    prompts = [a, RNG.integers(0, 500, (9,)).astype(np.int32), a.copy(),
               RNG.integers(0, 500, (16,)).astype(np.int32)]
    return [Request(uid=i, prompt=prompts[i % 4], max_new_tokens=3 + i % 3)
            for i in range(n)]


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------


def test_frame_roundtrip_and_truncation():
    a, b = socket.socketpair()
    try:
        fr.send_frame(a, fr.MSG_STEP, b"payload")
        msg, payload = fr.recv_frame(b)
        assert (msg, payload) == (fr.MSG_STEP, b"payload")
        # a frame cut mid-payload is loud, not a short read
        full = struct.pack("<IB", 101, fr.MSG_SEQ) + b"x" * 50
        a.sendall(full)
        a.close()
        with pytest.raises(fr.FrameError, match="mid-frame"):
            fr.recv_frame(b)
    finally:
        b.close()


def test_frame_oversize_length_rejected():
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("<IB", fr.MAX_FRAME + 1, fr.MSG_STEP))
        with pytest.raises(fr.FrameError, match="length"):
            fr.recv_frame(b)
        with pytest.raises(fr.FrameError):
            fr.send_frame(a, fr.MSG_STEP, b"x" * fr.MAX_FRAME)
    finally:
        a.close()
        b.close()


def test_chunk_pack_unpack_and_corruption():
    entries = [(0, 0, 0, b"abcdef" * 10), (1, 1, 2, b"zyxw" * 12)]
    data, inline, refs = pack_chunk(7, entries, known=None)
    assert len(inline) == 2 and not refs
    seq_id, out = unpack_chunk(data)
    assert seq_id == 7
    assert [(t, l, c) for t, l, c, _, _, _ in out] == \
           [(0, 0, 0), (1, 1, 2)]
    assert all(tag == 0 and _page_digest(body) == digest
               for _, _, _, tag, digest, body in out)
    # known digests become refs
    data2, inline2, refs2 = pack_chunk(
        8, entries, known={_page_digest(entries[0][3])})
    assert len(inline2) == 1 and len(refs2) == 1
    # corrupted payload length / truncation / magic / version: all loud
    with pytest.raises(ValueError, match="magic"):
        unpack_chunk(b"XXXX" + data[4:])
    with pytest.raises(ValueError, match="version"):
        unpack_chunk(data[:4] + bytes([99]) + data[5:])
    with pytest.raises(ValueError, match="truncated|overruns"):
        unpack_chunk(data[:-5])
    with pytest.raises(ValueError, match="truncated"):
        unpack_chunk(data[:6])
    # bump the first entry's payload length field past the frame end
    hdr_end = 4 + 1 + 4 + 2          # magic, version, seq_id, n_entries
    len_off = hdr_end + 7 + 12       # entry header + digest
    bad = (data[:len_off] + struct.pack("<I", 10_000)
           + data[len_off + 4:])
    with pytest.raises(ValueError, match="overruns"):
        unpack_chunk(bad)


def test_digest_store_lru_pins_and_verification():
    store = DigestStore(max_pages=3)
    payloads = [bytes([i]) * 8 for i in range(5)]
    digests = [_page_digest(p) for p in payloads]
    for d, p in zip(digests[:3], payloads[:3]):
        store[d] = p
    store.pin(1, digests[0])          # in-flight stream protects entry 0
    store[digests[3]] = payloads[3]
    store[digests[4]] = payloads[4]
    assert store.trim() == 2          # bounded again, pinned survived
    assert len(store) == 3 and digests[0] in store
    assert digests[1] not in store and digests[2] not in store
    store.release(1)
    store[digests[1]] = payloads[1]
    assert store.trim() == 1          # now entry 0 is evictable
    assert digests[0] not in store
    assert store.n_evicted == 3
    # corrupted payloads are rejected at ingest
    with pytest.raises(ValueError, match="digest"):
        store[digests[0]] = b"not the payload"


def test_loopback_store_eviction_and_resend_accounting():
    """A too-small receiver store forgets pages; the sender's next
    transfer re-inlines them and the stats ledger shows both sides."""
    run = _run_cfg(True)
    eng = ServeEngine(CFG, run, tp=1, n_slots=2, max_len=MAXLEN, seed=1)
    from repro.serve.disagg import PrefillReplica
    pr = PrefillReplica(eng)
    pr.submit(Request(uid=0, prompt=RNG.integers(0, 500, (16,)
                                                 ).astype(np.int32),
                      max_new_tokens=4))
    eng._admit_phase(pr.ls)
    blob = pr._export_blob(0)
    assert blob.n_valid_pages > 1
    tr = LoopbackTransport(max_store_pages=1)
    d1 = tr.send(blob, "d")
    tr.recv(d1, "d")
    assert len(tr.store("d")) == 1            # trimmed at the boundary
    assert tr.stats.store_evicted == blob.n_valid_pages - 1
    d2 = tr.send(blob, "d")
    tr.recv(d2, "d")
    st = tr.stats
    assert st.pages_resent == blob.n_valid_pages - 1
    assert st.pages_ref == 1                  # only the survivor deduped
    # big store: second send is all refs, nothing resent
    tr2 = LoopbackTransport(max_store_pages=4096)
    tr2.recv(tr2.send(blob, "d"), "d")
    tr2.recv(tr2.send(blob, "d"), "d")
    assert tr2.stats.pages_resent == 0
    assert tr2.stats.pages_ref == blob.n_valid_pages


# ---------------------------------------------------------------------------
# socket sessions (threaded host in-process)
# ---------------------------------------------------------------------------


def _fingerprint(run, tp=1, n_slots=2, max_len=MAXLEN, seed=1):
    return fr.config_fingerprint(CFG, run.codec, tp, n_slots, max_len, seed)


def _start_host(run, once=True, seed=1, max_store_pages=4096):
    eng = ServeEngine(CFG, run, tp=1, n_slots=2, max_len=MAXLEN, seed=seed)
    host = PageHost(DecodeReplica(eng), _fingerprint(run, seed=seed),
                    max_store_pages=max_store_pages)
    listener = socket.create_server(("127.0.0.1", 0))
    port = listener.getsockname()[1]

    def serve():
        try:
            host.serve_forever(listener, once=once)
        except OSError:
            pass                     # listener closed by the test

    t = threading.Thread(target=serve, daemon=True)
    t.start()
    return host, listener, port, eng


def test_socket_disagg_identity_threaded():
    """DisaggEngine over SocketTransport (host in a thread, full TCP
    framing): streams byte-identical to the monolithic engine; wire
    accounting matches what loopback meters for the same transfers."""
    run = _run_cfg(True)
    reqs = _requests()
    mono = ServeEngine(CFG, run, tp=1, n_slots=2, max_len=MAXLEN, seed=1)
    res_m, _ = mono.run(reqs)
    loop = DisaggEngine(CFG, run, tp=1, n_prefill=1, n_decode=1, n_slots=2,
                        max_len=MAXLEN, seed=1, streaming=True)
    res_l, st_l = loop.run(reqs)

    host, listener, port, dec_eng = _start_host(run)
    tr = SocketTransport()
    dis = DisaggEngine(CFG, run, tp=1, n_prefill=1, n_slots=2,
                       max_len=MAXLEN, seed=1, transport=tr, streaming=True,
                       decode_addrs=[f"127.0.0.1:{port}"])
    res_s, st_s = dis.run(reqs)
    tr.close()
    listener.close()
    for x, y, z in zip(res_m, res_s, res_l):
        assert x.tokens == y.tokens == z.tokens, x.uid
        assert x.stop_reason == y.stop_reason
    # same sequences, same dedup decisions -> identical data-plane bytes
    assert st_s.wire_bytes == st_l.wire_bytes
    assert st_s.pages_streamed == st_l.pages_streamed
    assert st_s.decode_prefix_hits == st_l.decode_prefix_hits
    dec_eng.drop_cache()
    assert dec_eng._pages_in_use() == 0


def test_socket_hello_mismatches_refused():
    """Version/magic/fingerprint mismatches kill the session before any
    page moves; the host keeps serving afterwards."""
    run = _run_cfg(True)
    host, listener, port, dec_eng = _start_host(run, once=False)
    try:
        # config fingerprint mismatch (e.g. different seed) -> refused
        tr = SocketTransport()
        with pytest.raises(RuntimeError, match="fingerprint"):
            tr.connect("d", "127.0.0.1", port,
                       _fingerprint(run, seed=999))
        # wire-version mismatch inside the hello -> refused
        with socket.create_connection(("127.0.0.1", port)) as s:
            bad = fr._HELLO.pack(fr.PROTO_MAGIC, fr.PROTO_VERSION,
                                 fr.WIRE_VERSION + 1,
                                 _fingerprint(run))
            fr.send_frame(s, fr.MSG_HELLO, bad)
            msg, payload = fr.recv_frame(s)
            assert msg == fr.MSG_ERROR
            assert b"wire-format" in payload
        # protocol magic mismatch -> refused
        with socket.create_connection(("127.0.0.1", port)) as s:
            bad = fr._HELLO.pack(b"NOPE", fr.PROTO_VERSION,
                                 fr.WIRE_VERSION, _fingerprint(run))
            fr.send_frame(s, fr.MSG_HELLO, bad)
            msg, payload = fr.recv_frame(s)
            assert msg == fr.MSG_ERROR and b"magic" in payload
        # a good session still works after all those refusals
        tr2 = SocketTransport()
        tr2.connect("d", "127.0.0.1", port, _fingerprint(run))
        assert tr2.inventory("d") == set()
        tr2.close()
        assert dec_eng._pages_in_use() == 0
    finally:
        listener.close()


def test_socket_midstream_disconnect_pool_untouched():
    """A driver that dies mid-stream (chunks sent, no closing blob) leaves
    the decode pool untouched; its pins are released so the staged pages
    become ordinary LRU content, and the next session serves normally."""
    run = _run_cfg(True)
    host, listener, port, dec_eng = _start_host(run, once=False)
    try:
        with socket.create_connection(("127.0.0.1", port)) as s:
            fr.send_frame(s, fr.MSG_HELLO, fr.pack_hello(_fingerprint(run)))
            msg, _ = fr.recv_frame(s)
            assert msg == fr.MSG_HELLO_OK
            data, _, _ = pack_chunk(1, [(0, 0, 0, b"payload" * 16)])
            fr.send_frame(s, fr.MSG_PAGE_CHUNK, data)
            msg, _ = fr.recv_frame(s)
            assert msg == fr.MSG_CHUNK_OK
            # a corrupted chunk answers ERROR and the session survives
            fr.send_frame(s, fr.MSG_PAGE_CHUNK, b"garbage")
            msg, payload = fr.recv_frame(s)
            assert msg == fr.MSG_ERROR and b"chunk" in payload
            fr.send_frame(s, fr.MSG_STATUS_REQ)
            msg, payload = fr.recv_frame(s)
            assert msg == fr.MSG_STATUS
            # die abruptly, mid-stream: no BYE, no closing blob
        assert dec_eng._pages_in_use() == 0
        # the staged page is unpinned at session teardown (the host thread
        # notices the dead socket asynchronously)
        deadline = time.time() + 10
        while host.store._pin_count and time.time() < deadline:
            time.sleep(0.05)
        assert not host.store._pin_count
        # next session: a full serving run against the same host
        reqs = _requests()
        mono = ServeEngine(CFG, run, tp=1, n_slots=2, max_len=MAXLEN,
                           seed=1)
        res_m, _ = mono.run(reqs)
        tr = SocketTransport()
        dis = DisaggEngine(CFG, run, tp=1, n_prefill=1, n_slots=2,
                           max_len=MAXLEN, seed=1, transport=tr,
                           streaming=True,
                           decode_addrs=[f"127.0.0.1:{port}"])
        res_s, _ = dis.run(reqs)
        tr.close()
        for x, y in zip(res_m, res_s):
            assert x.tokens == y.tokens, x.uid
        dec_eng.drop_cache()
        assert dec_eng._pages_in_use() == 0
    finally:
        listener.close()


def test_pack_pages_roundtrip_and_corruption():
    """The FETCH_OK payload codec is lossless and loud on truncation or
    trailing garbage."""
    pages = {_page_digest(b"a" * 9): b"a" * 9, _page_digest(b"bb"): b"bb"}
    data = fr.pack_pages(pages)
    assert fr.unpack_pages(data) == pages
    assert fr.unpack_pages(fr.pack_pages({})) == {}
    with pytest.raises(fr.FrameError, match="overruns"):
        fr.unpack_pages(data[:-1])
    with pytest.raises(fr.FrameError, match="trailing"):
        fr.unpack_pages(data + b"x")


def test_socket_fetch_by_digest():
    """FETCH pulls pages back OUT of the host's digest store (the remote
    tier of the tiered PageCache): the reply is the held subset — a
    missing digest is not an error — and the transport meters the fetch;
    STATUS reports store occupancy and capacity."""
    run = _run_cfg(True)
    host, listener, port, dec_eng = _start_host(run, once=False)
    try:
        tr = SocketTransport()
        tr.connect("d", "127.0.0.1", port, _fingerprint(run))
        st = tr.status("d")
        assert st["store_pages"] == 0 and st["store_capacity"] == 4096
        # stage two pages into the host store via a streamed chunk
        bodies = [b"payload-a" * 8, b"payload-b" * 8]
        data, _, _ = pack_chunk(3, [(0, 0, i, b)
                                    for i, b in enumerate(bodies)])
        fr.send_frame(tr._socks["d"], fr.MSG_PAGE_CHUNK, data)
        msg, _ = fr.recv_frame(tr._socks["d"])
        assert msg == fr.MSG_CHUNK_OK
        digests = [_page_digest(b) for b in bodies]
        missing = _page_digest(b"never shipped")
        got = tr.fetch("d", digests + [missing])
        assert got == dict(zip(digests, bodies))
        assert tr.stats.pages_fetched == 2
        assert tr.stats.fetch_bytes == sum(len(b) for b in bodies)
        assert int(tr.status("d")["store_pages"]) == 2
        # the host-side replica's remote tier reads the same store
        assert host._fetch_pages([digests[0]]) == {digests[0]: bodies[0]}
        tr.close()
    finally:
        listener.close()


def test_socket_import_failure_keeps_pool_and_session():
    """A blob the receiver cannot resolve (unknown digest: its store was
    built by a DIFFERENT session) answers ERROR with the pool untouched."""
    run = _run_cfg(True)
    eng = ServeEngine(CFG, run, tp=1, n_slots=2, max_len=MAXLEN, seed=1)
    from repro.serve.disagg import PrefillReplica
    pr = PrefillReplica(eng)
    pr.submit(Request(uid=0, prompt=RNG.integers(0, 500, (12,)
                                                 ).astype(np.int32),
                      max_new_tokens=2))
    eng._admit_phase(pr.ls)
    blob = pr._export_blob(0)
    data_refs, _, refs = blob.to_wire(
        {d for _, _, _, p in blob.page_entries()
         for d in [_page_digest(p)]})
    assert refs                               # all pages are references
    host, listener, port, dec_eng = _start_host(run, once=False)
    try:
        tr = SocketTransport()
        tr.connect("d", "127.0.0.1", port, _fingerprint(run))
        meta = {"uid": 0, "prompt": [int(t) for t in pr.ls.slot_req[0].prompt],
                "max_new_tokens": 2, "eos_id": None, "stop_seqs": None,
                "seq_id": None}
        sock = tr._socks["d"]
        fr.send_frame(sock, fr.MSG_SEQ, fr.pack_seq(meta, data_refs))
        msg, payload = fr.recv_frame(sock)
        assert msg == fr.MSG_ERROR and b"unknown page digest" in payload
        assert dec_eng._pages_in_use() == 0
        assert not any(dec_eng._slot_busy)
        # the same session can still import the blob shipped inline
        from repro.serve.disagg import Handoff
        slot = tr.deliver(Handoff(req=pr.ls.slot_req[0], blob=blob,
                                  admit_t=0.0), "d")
        assert dec_eng._pages_in_use() > 0
        assert dec_eng.state is not None and slot == 0
        tr.close()
    finally:
        listener.close()


# ---------------------------------------------------------------------------
# two OS processes
# ---------------------------------------------------------------------------


def test_two_process_socket_identity():
    """The acceptance bar for the transport subsystem: a decode host in a
    SEPARATE OS process (spawned via repro.launch.disagg_host) serves
    token streams byte-identical to the monolithic engine, with streaming
    export and receiver-side dedup on."""
    from repro.launch.disagg_host import (spawn_decode_host,
                                          tiny_bench_config)
    cfg = tiny_bench_config()
    run = RunConfig(codec=dataclasses.replace(CodecConfig(cache_block=8),
                                              decode_backend="jax"))
    rng = np.random.default_rng(0)
    base = rng.integers(0, 512, (24,)).astype(np.int32)
    reqs = [Request(uid=0, prompt=base, max_new_tokens=6),
            Request(uid=1, prompt=rng.integers(0, 512, (16,)
                                               ).astype(np.int32),
                    max_new_tokens=3),
            Request(uid=2, prompt=base.copy(), max_new_tokens=4)]
    mono = ServeEngine(cfg, run, tp=1, n_slots=2, max_len=96, seed=1)
    res_m, _ = mono.run(reqs)
    proc, port = spawn_decode_host(
        ["--model", "tiny-bench", "--codec", "on", "--cache-block", "8",
         "--tp", "1", "--slots", "2", "--max-len", "96", "--seed", "1",
         "--decode-backend", "jax"])
    try:
        tr = SocketTransport()
        dis = DisaggEngine(cfg, run, tp=1, n_prefill=1, n_slots=2,
                           max_len=96, seed=1, transport=tr,
                           streaming=True,
                           decode_addrs=[f"127.0.0.1:{port}"])
        res_s, st = dis.run(reqs)
        tr.close()
        for x, y in zip(res_m, res_s):
            assert x.tokens == y.tokens, x.uid
            assert x.stop_reason == y.stop_reason
        assert st.n_transfers == len(reqs)
        assert st.pages_streamed > 0
        assert st.wire_bytes < st.wire_raw_bytes
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except Exception:
            proc.kill()
