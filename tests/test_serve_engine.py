"""Continuous-batching engine tests: the paged, slot-based decode path must
be token-identical to the fixed-batch prefill+decode baseline for a fixed
request set — with the LEXI cache codec on and off, across dense / hybrid /
MoE tiny configs — while exercising mid-flight admission, eviction and page
reuse (more requests than slots, mixed prompt lengths)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import (MeshConfig, ModelConfig, MoEConfig,
                                RunConfig, SSMConfig)
from repro.core import collectives as cl
from repro.core.collectives import CodecConfig
from repro.models import cache as cache_mod
from repro.models import lm, params as PM
from repro.serve import Request, ServeEngine, engine

RNG = np.random.default_rng(0)

TP = 4
MAXLEN = 64

CASES = {
    "dense": ModelConfig(name="t2", family="dense", n_layers=2, d_model=64,
                         n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=500,
                         head_dim=16),
    "hybrid": ModelConfig(
        name="h", family="hybrid", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=500, head_dim=16,
        parallel_hybrid=True, attn_layout="hymba_3global", window=16,
        ssm=SSMConfig(d_state=16, headdim=8, chunk=16), sub_quadratic=True),
    "moe": ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=500,
                       head_dim=16,
                       moe=MoEConfig(n_experts=8, top_k=2, d_ff=32,
                                     n_shared=1, capacity_factor=4.0)),
}


def _run_cfg(codec_on: bool) -> RunConfig:
    import dataclasses
    codec = (CodecConfig(cache_block=4) if codec_on
             else dataclasses.replace(CodecConfig.off(), cache_block=4))
    return RunConfig(codec=codec)


def _requests():
    # mixed lengths + more requests than slots -> admission mid-flight,
    # eviction, page reuse
    specs = [(8, 5), (16, 3), (8, 6), (12, 4)]
    return [Request(uid=i, prompt=RNG.integers(0, 500, (s,)).astype(np.int32),
                    max_new_tokens=n) for i, (s, n) in enumerate(specs)]


def _baseline_tokens(cfg, run, params, req, tp=TP):
    """Fixed-batch B=1 prefill + decode loop — the reference output."""
    mesh_cfg = MeshConfig(data=1, model=tp, pod=1)
    mesh = jax.make_mesh((1, tp), ("data", "model"))
    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    pspecs = PM.param_pspecs(table)

    def f(pp, toks):
        lg, st = engine.prefill(cfg, run, pp, dims, toks, MAXLEN, tp)
        tok = engine.greedy_token(cfg, lg, tp)
        outs = [tok]
        for _ in range(req.max_new_tokens - 1):
            lg, st = engine.decode_step(cfg, run, pp, dims, st, tok, tp)
            tok = engine.greedy_token(cfg, lg, tp)
            outs.append(tok)
        return jnp.concatenate(outs, axis=1)

    fj = jax.jit(cl.shmap(f, mesh, (pspecs, P(None, None)), P(None, None)))
    return np.asarray(fj(params, jnp.asarray(req.prompt)[None]))[0].tolist()


@pytest.mark.parametrize("codec_on", [True, False], ids=["codec", "raw"])
@pytest.mark.parametrize("case", sorted(CASES))
def test_continuous_matches_fixed_batch(case, codec_on):
    cfg = CASES[case]
    run = _run_cfg(codec_on)
    eng = ServeEngine(cfg, run, tp=TP, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = _requests()
    results, stats = eng.run(reqs)

    assert stats.n_requests == len(reqs)
    assert stats.decode_steps > 0
    if cfg.n_heads > 0:
        assert stats.peak_pages > 0
        if codec_on:  # compressed pages must be smaller than raw bf16
            assert stats.peak_cache_bytes < stats.peak_cache_raw_bytes
        else:
            assert stats.peak_cache_bytes == stats.peak_cache_raw_bytes

    for req, res in zip(reqs, results):
        assert len(res.tokens) == req.max_new_tokens
        want = _baseline_tokens(cfg, run, eng.params, req)
        assert res.tokens == want, (case, codec_on, req.uid)


def test_pages_released_after_run():
    """Eviction frees every page except the retained (hot-tier) prefix
    columns; dropping the cache drains the pool completely."""
    cfg = CASES["dense"]
    eng = ServeEngine(cfg, _run_cfg(True), tp=TP, n_slots=2, max_len=MAXLEN,
                      seed=1)
    results, stats = eng.run(_requests())
    assert stats.peak_pages > 0
    # the aligned 16-token prompt leaves its prefix column retained
    assert eng.cache.retained() > 0
    assert int(np.asarray(eng.state.kv.page_used).sum()) > 0
    eng.drop_cache()
    assert int(np.asarray(eng.state.kv.page_used).sum()) == 0
    assert int(np.asarray(eng.state.active).sum()) == 0


def test_scheduler_validation():
    cfg = CASES["dense"]
    eng = ServeEngine(cfg, _run_cfg(True), tp=TP, n_slots=2, max_len=MAXLEN)
    too_short = Request(uid=0, prompt=np.zeros((TP - 1,), np.int32),
                        max_new_tokens=2)
    with pytest.raises(ValueError, match=">= tp"):
        eng.scheduler.submit(too_short)
    unaligned = Request(uid=3, prompt=np.zeros((7,), np.int32),
                        max_new_tokens=2)
    eng.scheduler.submit(unaligned)      # bucketing: no % tp requirement
    assert len(eng.scheduler) == 1
    eng.scheduler.pop()
    too_long = Request(uid=1, prompt=np.zeros((60,), np.int32),
                       max_new_tokens=16)
    with pytest.raises(ValueError):
        eng.scheduler.submit(too_long)
    dup = [Request(uid=7, prompt=np.zeros((8,), np.int32), max_new_tokens=2),
           Request(uid=7, prompt=np.zeros((8,), np.int32), max_new_tokens=2)]
    with pytest.raises(ValueError, match="unique"):
        eng.run(dup)


def test_page_pool_oversubscription_rejected():
    cfg = CASES["dense"]
    run = _run_cfg(True)
    with pytest.raises(ValueError, match="oversubscription"):
        cache_mod.empty_paged_kv(cfg, run, n_slots=2, max_len=MAXLEN,
                                 tp=TP, n_pages=1)


def test_analytic_page_count_matches_device():
    """The scheduler's host-side page metric must mirror the device's
    flush rule exactly (one admitted request, no decode steps yet)."""
    cfg = CASES["dense"]
    eng = ServeEngine(cfg, _run_cfg(True), tp=TP, n_slots=2, max_len=MAXLEN,
                      seed=1)
    prompt = jnp.asarray(RNG.integers(0, 500, (16,)), jnp.int32)[None]
    fn = eng._admit_for(16, 1)
    _, eng.state = fn(eng.params, eng.state, prompt,
                      jnp.asarray([0], jnp.int32))
    want = eng._pages_for_length(16)
    assert want > 0
    assert eng._pages_in_use() == want


def test_page_bytes_accounting():
    cfg = CASES["dense"]
    stored, raw = cache_mod.page_bytes(cfg, _run_cfg(True))
    assert stored < raw
    stored_off, raw_off = cache_mod.page_bytes(cfg, _run_cfg(False))
    assert stored_off == raw_off


# ---------------------------------------------------------------------------
# PR 2: fused multi-step decode, EOS termination, prompt bucketing,
# decode-backend parity
# ---------------------------------------------------------------------------

TP2 = 2


def _tp2_requests(n=3, max_new=6):
    specs = [(8, max_new), (12, max_new - 1), (8, max_new)][:n]
    return [Request(uid=i, prompt=RNG.integers(0, 500, (s,)).astype(np.int32),
                    max_new_tokens=m) for i, (s, m) in enumerate(specs)]


def test_multi_step_scan_token_identity():
    """K-fused decode dispatches emit byte-identical streams to the
    one-dispatch-per-token loop, with fewer dispatches than steps."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    reqs = _tp2_requests()
    fused = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    res_f, st_f = fused.run(reqs)
    stepped = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN,
                          seed=1, max_fuse_steps=1)
    res_s, st_s = stepped.run([Request(uid=r.uid, prompt=r.prompt,
                                       max_new_tokens=r.max_new_tokens)
                               for r in reqs])
    for a, b in zip(res_f, res_s):
        assert a.tokens == b.tokens, a.uid
        assert a.stop_reason == b.stop_reason == "budget"
    assert st_s.n_dispatches == st_s.decode_steps
    assert st_f.n_dispatches < st_f.decode_steps    # >1 step per dispatch
    assert st_f.decode_steps >= st_s.decode_steps   # window may overshoot EOS


def test_eos_termination():
    """A slot evicts on eos_id; the result reports the stop reason and the
    stream is the budget-run prefix up to (and including) the EOS."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    probe = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = _tp2_requests(n=1, max_new=6)
    (full,), _ = probe.run(reqs)
    assert full.stop_reason == "budget"
    eos = full.tokens[2]                 # force a mid-stream stop
    eng = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1,
                      eos_id=eos)
    (res,), _ = eng.run([Request(uid=9, prompt=reqs[0].prompt,
                                 max_new_tokens=6)])
    stop = full.tokens.index(eos)
    assert res.stop_reason == "eos"
    assert res.tokens == full.tokens[:stop + 1]
    assert int(np.asarray(eng.state.active).sum()) == 0   # slot evicted
    # per-request override beats the engine default (no EOS -> budget)
    (res2,), _ = eng.run([Request(uid=10, prompt=reqs[0].prompt,
                                  max_new_tokens=4, eos_id=-1)])
    assert res2.stop_reason == "budget" and len(res2.tokens) == 4


def test_prompt_bucketing_matches_trunk_tail_baseline():
    """Unaligned prompts (len % tp != 0) admit and match the fixed-batch
    trunk + per-token-tail reference exactly."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    eng = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = [Request(uid=0, prompt=RNG.integers(0, 500, (9,)).astype(np.int32),
                    max_new_tokens=4),
            Request(uid=1, prompt=RNG.integers(0, 500, (13,)).astype(np.int32),
                    max_new_tokens=3)]
    results, stats = eng.run(reqs)
    assert stats.n_requests == 2

    mesh = jax.make_mesh((1, TP2), ("data", "model"))
    mesh_cfg = MeshConfig(data=1, model=TP2, pod=1)
    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    pspecs = PM.param_pspecs(table)

    def baseline(req):
        s = len(req.prompt)
        s0 = (s // TP2) * TP2

        def f(pp, toks):
            lg, st = engine.prefill(cfg, run, pp, dims, toks[:, :s0],
                                    MAXLEN, TP2)
            for j in range(s - s0):
                lg, st = engine.decode_step(cfg, run, pp, dims, st,
                                            toks[:, s0 + j:s0 + j + 1], TP2)
            tok = engine.greedy_token(cfg, lg, TP2)
            outs = [tok]
            for _ in range(req.max_new_tokens - 1):
                lg, st = engine.decode_step(cfg, run, pp, dims, st, tok, TP2)
                tok = engine.greedy_token(cfg, lg, TP2)
                outs.append(tok)
            return jnp.concatenate(outs, axis=1)

        fj = jax.jit(cl.shmap(f, mesh, (pspecs, P(None, None)),
                              P(None, None)))
        return np.asarray(fj(eng.params,
                             jnp.asarray(req.prompt)[None]))[0].tolist()

    for req, res in zip(reqs, results):
        assert res.tokens == baseline(req), req.uid


# ---------------------------------------------------------------------------
# PR 3: batched multi-slot admission + refcounted prefix-shared pages
# ---------------------------------------------------------------------------


def _shared_mix():
    """A prefix-heavy stream: a base prompt A, an exact duplicate, a fork
    sharing A's first two page columns, and an unrelated B — more requests
    than slots, staggered budgets so eviction interleaves with sharing
    (B evicts while A still holds its prefix pages; the duplicate admits
    into B's slot and maps A's pages; A then releases while shared).
    Deterministic: runs must be repeatable across engines."""
    rng = np.random.default_rng(42)
    a = rng.integers(0, 500, (24,)).astype(np.int32)
    b = rng.integers(0, 500, (12,)).astype(np.int32)
    fork = np.concatenate([a[:16], rng.integers(0, 500, (6,)).astype(np.int32)])
    prompts = [a, b, a.copy(), fork, a.copy()]
    budgets = [5, 3, 4, 4, 3]
    return [Request(uid=i, prompt=p, max_new_tokens=m)
            for i, (p, m) in enumerate(zip(prompts, budgets))]


@pytest.mark.parametrize("codec_on", [True, False], ids=["codec", "raw"])
@pytest.mark.parametrize("case", sorted(CASES))
def test_prefix_sharing_token_identity(case, codec_on):
    """Serving a shared-prefix mix with page sharing ON is token-identical
    to the sharing-OFF engine, across dense/hybrid/MoE and codec on/off —
    with hits and fewer admit prefills where sharing applies.  Hybrids
    share via SSM snapshots at page boundaries; MoE auto-disables (its
    decode float path is not bit-equal to prefill)."""
    cfg = CASES[case]
    run = _run_cfg(codec_on)
    eng_on = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    res_on, st_on = eng_on.run(_shared_mix())
    eng_off = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN,
                          seed=1, prefix_sharing=False)
    res_off, st_off = eng_off.run(_shared_mix())
    for x, y in zip(res_on, res_off):
        assert x.tokens == y.tokens, (case, codec_on, x.uid)
    assert st_off.shared_page_hits == 0
    if case == "dense":
        assert st_on.shared_page_hits > 0
        assert st_on.n_admit_dispatches < st_on.n_requests
    elif case == "hybrid":
        # the page-aligned duplicates of A restore pages + SSM snapshot
        # without any re-prefill
        assert st_on.shared_page_hits > 0
        assert eng_on.prefix_sharing
    else:
        # MoE (decode float path != prefill) auto-disables sharing:
        # streams unchanged, hits zero
        assert st_on.shared_page_hits == 0
        assert not eng_on.prefix_sharing
    # release RETAINS indexed prefix columns (hot tier); dropping the
    # cache drains the pool and empties the index
    if eng_on.prefix_sharing:
        assert eng_on.cache.retained() > 0
    eng_on.drop_cache()
    if cfg.n_heads > 0:
        assert eng_on._pages_in_use() == 0
    assert not eng_on._prefix_index and not eng_on._prefix_ref
    assert not eng_on._slot_busy.any()


def test_shared_mix_matches_fixed_batch_baseline():
    """The shared-prefix stream (sharing ON) is token-identical to the
    per-request fixed-batch prefill+decode reference."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    eng = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = _shared_mix()
    results, stats = eng.run(reqs)
    assert stats.shared_page_hits > 0
    for req, res in zip(reqs, results):
        assert res.tokens == _baseline_tokens(cfg, run, eng.params, req,
                                              tp=TP2), req.uid


def test_prefix_sharing_interpret_backend_identity():
    """Sharing through the fused-kernel (Pallas interpret) decode backend
    serves the same streams as the pure-JAX backend, with hits on both."""
    import dataclasses
    cfg = CASES["dense"]
    run_jax = _run_cfg(True)
    eng_j = ServeEngine(cfg, run_jax, tp=TP2, n_slots=2, max_len=MAXLEN,
                        seed=1)
    res_j, st_j = eng_j.run(_shared_mix())
    run_k = dataclasses.replace(run_jax, codec=dataclasses.replace(
        run_jax.codec, decode_backend="interpret"))
    eng_k = ServeEngine(cfg, run_k, tp=TP2, n_slots=2, max_len=MAXLEN,
                        seed=1)
    res_k, st_k = eng_k.run(_shared_mix())
    assert st_k.decode_backend == "interpret"
    assert st_j.shared_page_hits > 0
    assert st_k.shared_page_hits == st_j.shared_page_hits
    for x, y in zip(res_j, res_k):
        assert x.tokens == y.tokens, x.uid


def test_batched_admission_one_dispatch():
    """Same-bucket cold requests admit in ONE vmapped-prefill dispatch and
    each stream matches its per-request fixed-batch baseline."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    eng = ServeEngine(cfg, run, tp=TP2, n_slots=4, max_len=MAXLEN, seed=1)
    reqs = [Request(uid=i,
                    prompt=RNG.integers(0, 500, (16,)).astype(np.int32),
                    max_new_tokens=3) for i in range(4)]
    results, stats = eng.run(reqs)
    assert stats.n_admit_dispatches == 1          # one dispatch, 4 slots
    assert stats.n_admit_compiles == 1
    assert stats.shared_page_hits == 0            # distinct prompts
    for req, res in zip(reqs, results):
        assert res.tokens == _baseline_tokens(cfg, run, eng.params, req,
                                              tp=TP2), req.uid


def test_admit_cache_bucket_keyed():
    """The admit-fn cache is keyed by (trunk bucket, batch size), so the
    compile count stops growing with distinct prompt lengths."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    eng = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = [Request(uid=i,
                    prompt=RNG.integers(0, 500, (8 + i,)).astype(np.int32),
                    max_new_tokens=2) for i in range(8)]      # lens 8..15
    _, st = eng.run(reqs)
    # every length lands in trunk bucket 8; batch sizes only 1..2 exist
    assert set(eng._admit_cache) <= {(8, 1), (8, 2)}
    assert st.n_admit_compiles == len(eng._admit_cache)
    reqs2 = [Request(uid=100 + i,
                     prompt=RNG.integers(0, 500, (9 + 2 * i,)
                                         ).astype(np.int32),
                     max_new_tokens=2) for i in range(3)]     # lens 9,11,13
    _, st2 = eng.run(reqs2)
    assert set(eng._admit_cache) <= {(8, 1), (8, 2)}          # no growth
    assert st2.n_admit_compiles <= 2


def test_page_refcount_lifecycle():
    """Refcounted sharing end to end, driven at the engine internals:
    owner registration, zero-copy mapping, release-while-shared keeps the
    pages, double release is rejected loudly, last release drains."""
    cfg = CASES["dense"]
    eng = ServeEngine(cfg, _run_cfg(True), tp=TP2, n_slots=2,
                      max_len=MAXLEN, seed=1)
    a = RNG.integers(0, 500, (16,)).astype(np.int32)   # 2 page columns
    fn = eng._admit_for(16, 1)
    _, eng.state = fn(eng.params, eng.state, jnp.asarray(a)[None],
                      jnp.asarray([0], jnp.int32))
    eng._slot_busy[0] = True
    eng._register_prefixes([(0, a, 16)])
    assert len(eng._prefix_index) == 2
    assert all(r == 1 for r in eng._prefix_ref.values())
    owner_pages = eng._pages_in_use()
    assert owner_pages == eng._pages_for_length(16) > 0

    # a matcher whose prompt extends A maps BOTH columns, zero page copies
    a_ext = np.concatenate([a, RNG.integers(0, 500, (4,)).astype(np.int32)])
    m, keys, warm = eng._prefix_match_cols(a_ext)
    assert m == 2 and warm == []
    ids = np.zeros((TP2, eng._maxp), np.int32)
    for c, key in enumerate(keys):
        ids[:, c] = eng._prefix_index[key]
        eng._prefix_ref[key] += 1
        eng._slot_keys[1].append(key)
    eng.state = eng._map_shared_for()(
        eng.state, jnp.asarray(1, jnp.int32), jnp.asarray(ids),
        jnp.asarray(m, jnp.int32), jnp.asarray(16, jnp.int32))
    eng._slot_busy[1] = True
    assert eng._pages_in_use() == owner_pages          # nothing allocated
    assert eng._shared_page_overcount() == 2 * TP2 * cfg.n_layers

    eng._free_slots([0])                   # release the OWNER while shared
    assert eng._pages_in_use() == owner_pages          # refs keep pages
    assert len(eng._prefix_index) == 2
    with pytest.raises(RuntimeError, match="double release"):
        eng._free_slots([0])
    eng._free_slots([1])                   # last reference: retain, spill
    # the hot tier keeps the columns resident (LRU, ref 0) and the last
    # release spilled their compressed payloads to the warm tier
    assert eng._pages_in_use() == owner_pages
    assert eng.cache.retained() == 2
    assert all(eng.cache.has_warm(k) for k in keys)
    assert eng.cache.spilled_pages > 0
    # re-acquiring from the hot tier pins the column again (a hit)...
    page = eng.cache.acquire(keys[0])
    assert eng.cache.hot_hits == 1 and eng.cache.retained() == 1
    eng.cache.release(keys[0])
    # ...and dropping the cache drains the pool and empties the index
    eng.drop_cache()
    assert eng._pages_in_use() == 0
    assert not eng._prefix_index and not eng._prefix_ref


def test_sharing_oversubscription_stress():
    """Shared admissions + evictions on an exactly-sized pool never leak or
    oversubscribe pages: identical long prompts stream through 2 slots."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    eng = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    a = RNG.integers(0, 500, (40,)).astype(np.int32)
    reqs = [Request(uid=i, prompt=a.copy(), max_new_tokens=4)
            for i in range(4)]
    results, st = eng.run(reqs)
    assert st.shared_page_hits > 0
    toks0 = results[0].tokens
    for r in results[1:]:                 # identical prompts, same stream
        assert r.tokens == toks0
    eng.drop_cache()
    assert eng._pages_in_use() == 0
    assert not eng._prefix_index


# ---------------------------------------------------------------------------
# PR 4: stop-string termination (host-side rolling suffix match)
# ---------------------------------------------------------------------------


def _first_stop_match(tokens, ss):
    """Index of the first token completing a rolling suffix match of ``ss``
    (what the engine's host-side check fires on), or None."""
    n = len(ss)
    for i in range(n - 1, len(tokens)):
        if tuple(tokens[i - n + 1:i + 1]) == tuple(ss):
            return i
    return None


def test_stop_string_termination():
    """A slot finishes when its emitted tokens end with a stop sequence:
    the stream is the budget-run prefix through the FIRST rolling match,
    the result reports stop_reason="stop_string", and the slot is
    evicted.  (Tiny-model streams repeat tokens, so the expected match
    position is computed, not assumed.)"""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    probe = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = _tp2_requests(n=1, max_new=8)
    (full,), _ = probe.run(reqs)
    assert full.stop_reason == "budget"
    ss = tuple(full.tokens[2:4])        # some 2-gram of the stream
    i = _first_stop_match(full.tokens, ss)
    assert i is not None
    eng = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1,
                      stop_seqs=[ss])
    (res,), _ = eng.run([Request(uid=9, prompt=reqs[0].prompt,
                                 max_new_tokens=8)])
    assert res.stop_reason == "stop_string"
    assert res.tokens == full.tokens[:i + 1]
    assert int(np.asarray(eng.state.active).sum()) == 0   # slot evicted

    # per-request override: () disables the engine default...
    (res2,), _ = eng.run([Request(uid=10, prompt=reqs[0].prompt,
                                  max_new_tokens=8, stop_seqs=())])
    assert res2.stop_reason == "budget" and res2.tokens == full.tokens
    # ...and a request-level sequence beats it
    v = full.tokens[1]
    j = full.tokens.index(v)            # first match of the 1-gram (v,)
    (res3,), _ = eng.run([Request(uid=11, prompt=reqs[0].prompt,
                                  max_new_tokens=8, stop_seqs=[(v,)])])
    assert res3.stop_reason == "stop_string"
    assert res3.tokens == full.tokens[:j + 1]


def test_stop_string_budget_eos_interplay():
    """Priority on the same token is eos > stop_string > budget; a stop
    sequence that would only complete past the budget never fires."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    probe = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = _tp2_requests(n=1, max_new=8)
    prompt = reqs[0].prompt
    (full,), _ = probe.run(reqs)

    # stop seq completes exactly at the budget boundary -> stop_string
    ss = tuple(full.tokens[2:4])
    i = _first_stop_match(full.tokens, ss)   # first completion position
    assert i is not None and i >= 1
    eng = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1,
                      stop_seqs=[ss])
    (res,), _ = eng.run([Request(uid=0, prompt=prompt,
                                 max_new_tokens=i + 1)])
    assert res.stop_reason == "stop_string" and len(res.tokens) == i + 1

    # budget one short of the first completion -> budget wins
    (res2,), _ = eng.run([Request(uid=1, prompt=prompt, max_new_tokens=i)])
    assert res2.stop_reason == "budget" and res2.tokens == full.tokens[:i]

    # EOS and a 1-token stop seq firing on the SAME token -> eos wins
    v = full.tokens[0]
    j = full.tokens.index(v)
    eng2 = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1,
                       stop_seqs=[(v,)], eos_id=v)
    (res3,), _ = eng2.run([Request(uid=2, prompt=prompt,
                                   max_new_tokens=8)])
    assert res3.stop_reason == "eos" and res3.tokens == full.tokens[:j + 1]

    with pytest.raises(ValueError, match="non-empty"):
        ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN,
                    stop_seqs=[()])
    # a malformed per-request override is rejected at SUBMIT, before the
    # request can occupy a slot (a mid-loop raise would leak its pages)
    with pytest.raises(ValueError, match="non-empty"):
        eng.run([Request(uid=5, prompt=prompt, max_new_tokens=2,
                         stop_seqs=[()])])
    assert int(np.asarray(eng.state.active).sum()) == 0
    eng.drop_cache()
    assert eng._pages_in_use() == 0


def test_stop_string_across_window_boundary():
    """A stop sequence split across two fused windows still matches (the
    suffix match is rolling over the whole emitted stream)."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    probe = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = _tp2_requests(n=1, max_new=8)
    (full,), _ = probe.run(reqs)
    ss = tuple(full.tokens[1:5])        # spans 2-step fused windows
    i = _first_stop_match(full.tokens, ss)
    assert i is not None and i >= 3     # needs >= 4 emitted tokens
    eng = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1,
                      stop_seqs=[ss], max_fuse_steps=2)
    (res,), _ = eng.run([Request(uid=0, prompt=reqs[0].prompt,
                                 max_new_tokens=8)])
    assert res.stop_reason == "stop_string"
    assert res.tokens == full.tokens[:i + 1]


def test_interpret_backend_serving_token_identity():
    """The fused-kernel decode path (Pallas interpret mode) serves token-
    identical streams to the pure-JAX backend — the acceptance bar for
    routing both stores through the kernels."""
    import dataclasses
    cfg = CASES["dense"]
    run_jax = _run_cfg(True)
    reqs = _tp2_requests(n=2, max_new=4)
    eng_jax = ServeEngine(cfg, run_jax, tp=TP2, n_slots=2, max_len=MAXLEN,
                          seed=1)
    res_jax, st_jax = eng_jax.run(reqs)
    assert st_jax.decode_backend == "jax"

    run_k = dataclasses.replace(run_jax, codec=dataclasses.replace(
        run_jax.codec, decode_backend="interpret"))
    eng_k = ServeEngine(cfg, run_k, tp=TP2, n_slots=2, max_len=MAXLEN,
                        seed=1)
    res_k, st_k = eng_k.run([Request(uid=r.uid, prompt=r.prompt,
                                     max_new_tokens=r.max_new_tokens)
                             for r in reqs])
    assert st_k.decode_backend == "interpret"
    for a, b in zip(res_jax, res_k):
        assert a.tokens == b.tokens, a.uid
