"""Continuous-batching engine tests: the paged, slot-based decode path must
be token-identical to the fixed-batch prefill+decode baseline for a fixed
request set — with the LEXI cache codec on and off, across dense / hybrid /
MoE tiny configs — while exercising mid-flight admission, eviction and page
reuse (more requests than slots, mixed prompt lengths)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import (MeshConfig, ModelConfig, MoEConfig,
                                RunConfig, SSMConfig)
from repro.core import collectives as cl
from repro.core.collectives import CodecConfig
from repro.models import cache as cache_mod
from repro.models import lm, params as PM
from repro.serve import Request, ServeEngine, engine

RNG = np.random.default_rng(0)

TP = 4
MAXLEN = 64

CASES = {
    "dense": ModelConfig(name="t2", family="dense", n_layers=2, d_model=64,
                         n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=500,
                         head_dim=16),
    "hybrid": ModelConfig(
        name="h", family="hybrid", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=500, head_dim=16,
        parallel_hybrid=True, attn_layout="hymba_3global", window=16,
        ssm=SSMConfig(d_state=16, headdim=8, chunk=16), sub_quadratic=True),
    "moe": ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=500,
                       head_dim=16,
                       moe=MoEConfig(n_experts=8, top_k=2, d_ff=32,
                                     n_shared=1, capacity_factor=4.0)),
}


def _run_cfg(codec_on: bool) -> RunConfig:
    import dataclasses
    codec = (CodecConfig(cache_block=4) if codec_on
             else dataclasses.replace(CodecConfig.off(), cache_block=4))
    return RunConfig(codec=codec)


def _requests():
    # mixed lengths + more requests than slots -> admission mid-flight,
    # eviction, page reuse
    specs = [(8, 5), (16, 3), (8, 6), (12, 4)]
    return [Request(uid=i, prompt=RNG.integers(0, 500, (s,)).astype(np.int32),
                    max_new_tokens=n) for i, (s, n) in enumerate(specs)]


def _baseline_tokens(cfg, run, params, req):
    """Fixed-batch B=1 prefill + decode loop — the reference output."""
    mesh_cfg = MeshConfig(data=1, model=TP, pod=1)
    mesh = jax.make_mesh((1, TP), ("data", "model"))
    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    pspecs = PM.param_pspecs(table)

    def f(pp, toks):
        lg, st = engine.prefill(cfg, run, pp, dims, toks, MAXLEN, TP)
        tok = engine.greedy_token(cfg, lg, TP)
        outs = [tok]
        for _ in range(req.max_new_tokens - 1):
            lg, st = engine.decode_step(cfg, run, pp, dims, st, tok, TP)
            tok = engine.greedy_token(cfg, lg, TP)
            outs.append(tok)
        return jnp.concatenate(outs, axis=1)

    fj = jax.jit(cl.shmap(f, mesh, (pspecs, P(None, None)), P(None, None)))
    return np.asarray(fj(params, jnp.asarray(req.prompt)[None]))[0].tolist()


@pytest.mark.parametrize("codec_on", [True, False], ids=["codec", "raw"])
@pytest.mark.parametrize("case", sorted(CASES))
def test_continuous_matches_fixed_batch(case, codec_on):
    cfg = CASES[case]
    run = _run_cfg(codec_on)
    eng = ServeEngine(cfg, run, tp=TP, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = _requests()
    results, stats = eng.run(reqs)

    assert stats.n_requests == len(reqs)
    assert stats.decode_steps > 0
    if cfg.n_heads > 0:
        assert stats.peak_pages > 0
        if codec_on:  # compressed pages must be smaller than raw bf16
            assert stats.peak_cache_bytes < stats.peak_cache_raw_bytes
        else:
            assert stats.peak_cache_bytes == stats.peak_cache_raw_bytes

    for req, res in zip(reqs, results):
        assert len(res.tokens) == req.max_new_tokens
        want = _baseline_tokens(cfg, run, eng.params, req)
        assert res.tokens == want, (case, codec_on, req.uid)


def test_pages_released_after_run():
    """Eviction returns every page to the pool."""
    cfg = CASES["dense"]
    eng = ServeEngine(cfg, _run_cfg(True), tp=TP, n_slots=2, max_len=MAXLEN,
                      seed=1)
    results, stats = eng.run(_requests())
    assert stats.peak_pages > 0
    assert int(np.asarray(eng.state.kv.page_used).sum()) == 0
    assert int(np.asarray(eng.state.active).sum()) == 0


def test_scheduler_validation():
    cfg = CASES["dense"]
    eng = ServeEngine(cfg, _run_cfg(True), tp=TP, n_slots=2, max_len=MAXLEN)
    bad_len = Request(uid=0, prompt=np.zeros((7,), np.int32),
                      max_new_tokens=2)
    with pytest.raises(ValueError):
        eng.scheduler.submit(bad_len)
    too_long = Request(uid=1, prompt=np.zeros((60,), np.int32),
                       max_new_tokens=16)
    with pytest.raises(ValueError):
        eng.scheduler.submit(too_long)
    dup = [Request(uid=7, prompt=np.zeros((8,), np.int32), max_new_tokens=2),
           Request(uid=7, prompt=np.zeros((8,), np.int32), max_new_tokens=2)]
    with pytest.raises(ValueError, match="unique"):
        eng.run(dup)


def test_page_pool_oversubscription_rejected():
    cfg = CASES["dense"]
    run = _run_cfg(True)
    with pytest.raises(ValueError, match="oversubscription"):
        cache_mod.empty_paged_kv(cfg, run, n_slots=2, max_len=MAXLEN,
                                 tp=TP, n_pages=1)


def test_analytic_page_count_matches_device():
    """The scheduler's host-side page metric must mirror the device's
    flush rule exactly (one admitted request, no decode steps yet)."""
    cfg = CASES["dense"]
    eng = ServeEngine(cfg, _run_cfg(True), tp=TP, n_slots=2, max_len=MAXLEN,
                      seed=1)
    prompt = jnp.asarray(RNG.integers(0, 500, (16,)), jnp.int32)[None]
    fn = eng._admit_for(16)
    _, eng.state = fn(eng.params, eng.state, prompt,
                      jnp.asarray(0, jnp.int32))
    want = eng._pages_for_length(16)
    assert want > 0
    assert eng._pages_in_use() == want


def test_page_bytes_accounting():
    cfg = CASES["dense"]
    stored, raw = cache_mod.page_bytes(cfg, _run_cfg(True))
    assert stored < raw
    stored_off, raw_off = cache_mod.page_bytes(cfg, _run_cfg(False))
    assert stored_off == raw_off
