"""Continuous-batching engine tests: the paged, slot-based decode path must
be token-identical to the fixed-batch prefill+decode baseline for a fixed
request set — with the LEXI cache codec on and off, across dense / hybrid /
MoE tiny configs — while exercising mid-flight admission, eviction and page
reuse (more requests than slots, mixed prompt lengths)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import (MeshConfig, ModelConfig, MoEConfig,
                                RunConfig, SSMConfig)
from repro.core import collectives as cl
from repro.core.collectives import CodecConfig
from repro.models import cache as cache_mod
from repro.models import lm, params as PM
from repro.serve import Request, ServeEngine, engine

RNG = np.random.default_rng(0)

TP = 4
MAXLEN = 64

CASES = {
    "dense": ModelConfig(name="t2", family="dense", n_layers=2, d_model=64,
                         n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=500,
                         head_dim=16),
    "hybrid": ModelConfig(
        name="h", family="hybrid", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=500, head_dim=16,
        parallel_hybrid=True, attn_layout="hymba_3global", window=16,
        ssm=SSMConfig(d_state=16, headdim=8, chunk=16), sub_quadratic=True),
    "moe": ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=500,
                       head_dim=16,
                       moe=MoEConfig(n_experts=8, top_k=2, d_ff=32,
                                     n_shared=1, capacity_factor=4.0)),
}


def _run_cfg(codec_on: bool) -> RunConfig:
    import dataclasses
    codec = (CodecConfig(cache_block=4) if codec_on
             else dataclasses.replace(CodecConfig.off(), cache_block=4))
    return RunConfig(codec=codec)


def _requests():
    # mixed lengths + more requests than slots -> admission mid-flight,
    # eviction, page reuse
    specs = [(8, 5), (16, 3), (8, 6), (12, 4)]
    return [Request(uid=i, prompt=RNG.integers(0, 500, (s,)).astype(np.int32),
                    max_new_tokens=n) for i, (s, n) in enumerate(specs)]


def _baseline_tokens(cfg, run, params, req):
    """Fixed-batch B=1 prefill + decode loop — the reference output."""
    mesh_cfg = MeshConfig(data=1, model=TP, pod=1)
    mesh = jax.make_mesh((1, TP), ("data", "model"))
    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    pspecs = PM.param_pspecs(table)

    def f(pp, toks):
        lg, st = engine.prefill(cfg, run, pp, dims, toks, MAXLEN, TP)
        tok = engine.greedy_token(cfg, lg, TP)
        outs = [tok]
        for _ in range(req.max_new_tokens - 1):
            lg, st = engine.decode_step(cfg, run, pp, dims, st, tok, TP)
            tok = engine.greedy_token(cfg, lg, TP)
            outs.append(tok)
        return jnp.concatenate(outs, axis=1)

    fj = jax.jit(cl.shmap(f, mesh, (pspecs, P(None, None)), P(None, None)))
    return np.asarray(fj(params, jnp.asarray(req.prompt)[None]))[0].tolist()


@pytest.mark.parametrize("codec_on", [True, False], ids=["codec", "raw"])
@pytest.mark.parametrize("case", sorted(CASES))
def test_continuous_matches_fixed_batch(case, codec_on):
    cfg = CASES[case]
    run = _run_cfg(codec_on)
    eng = ServeEngine(cfg, run, tp=TP, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = _requests()
    results, stats = eng.run(reqs)

    assert stats.n_requests == len(reqs)
    assert stats.decode_steps > 0
    if cfg.n_heads > 0:
        assert stats.peak_pages > 0
        if codec_on:  # compressed pages must be smaller than raw bf16
            assert stats.peak_cache_bytes < stats.peak_cache_raw_bytes
        else:
            assert stats.peak_cache_bytes == stats.peak_cache_raw_bytes

    for req, res in zip(reqs, results):
        assert len(res.tokens) == req.max_new_tokens
        want = _baseline_tokens(cfg, run, eng.params, req)
        assert res.tokens == want, (case, codec_on, req.uid)


def test_pages_released_after_run():
    """Eviction returns every page to the pool."""
    cfg = CASES["dense"]
    eng = ServeEngine(cfg, _run_cfg(True), tp=TP, n_slots=2, max_len=MAXLEN,
                      seed=1)
    results, stats = eng.run(_requests())
    assert stats.peak_pages > 0
    assert int(np.asarray(eng.state.kv.page_used).sum()) == 0
    assert int(np.asarray(eng.state.active).sum()) == 0


def test_scheduler_validation():
    cfg = CASES["dense"]
    eng = ServeEngine(cfg, _run_cfg(True), tp=TP, n_slots=2, max_len=MAXLEN)
    too_short = Request(uid=0, prompt=np.zeros((TP - 1,), np.int32),
                        max_new_tokens=2)
    with pytest.raises(ValueError, match=">= tp"):
        eng.scheduler.submit(too_short)
    unaligned = Request(uid=3, prompt=np.zeros((7,), np.int32),
                        max_new_tokens=2)
    eng.scheduler.submit(unaligned)      # bucketing: no % tp requirement
    assert len(eng.scheduler) == 1
    eng.scheduler.pop()
    too_long = Request(uid=1, prompt=np.zeros((60,), np.int32),
                       max_new_tokens=16)
    with pytest.raises(ValueError):
        eng.scheduler.submit(too_long)
    dup = [Request(uid=7, prompt=np.zeros((8,), np.int32), max_new_tokens=2),
           Request(uid=7, prompt=np.zeros((8,), np.int32), max_new_tokens=2)]
    with pytest.raises(ValueError, match="unique"):
        eng.run(dup)


def test_page_pool_oversubscription_rejected():
    cfg = CASES["dense"]
    run = _run_cfg(True)
    with pytest.raises(ValueError, match="oversubscription"):
        cache_mod.empty_paged_kv(cfg, run, n_slots=2, max_len=MAXLEN,
                                 tp=TP, n_pages=1)


def test_analytic_page_count_matches_device():
    """The scheduler's host-side page metric must mirror the device's
    flush rule exactly (one admitted request, no decode steps yet)."""
    cfg = CASES["dense"]
    eng = ServeEngine(cfg, _run_cfg(True), tp=TP, n_slots=2, max_len=MAXLEN,
                      seed=1)
    prompt = jnp.asarray(RNG.integers(0, 500, (16,)), jnp.int32)[None]
    fn = eng._admit_for(16)
    _, eng.state = fn(eng.params, eng.state, prompt,
                      jnp.asarray(0, jnp.int32))
    want = eng._pages_for_length(16)
    assert want > 0
    assert eng._pages_in_use() == want


def test_page_bytes_accounting():
    cfg = CASES["dense"]
    stored, raw = cache_mod.page_bytes(cfg, _run_cfg(True))
    assert stored < raw
    stored_off, raw_off = cache_mod.page_bytes(cfg, _run_cfg(False))
    assert stored_off == raw_off


# ---------------------------------------------------------------------------
# PR 2: fused multi-step decode, EOS termination, prompt bucketing,
# decode-backend parity
# ---------------------------------------------------------------------------

TP2 = 2


def _tp2_requests(n=3, max_new=6):
    specs = [(8, max_new), (12, max_new - 1), (8, max_new)][:n]
    return [Request(uid=i, prompt=RNG.integers(0, 500, (s,)).astype(np.int32),
                    max_new_tokens=m) for i, (s, m) in enumerate(specs)]


def test_multi_step_scan_token_identity():
    """K-fused decode dispatches emit byte-identical streams to the
    one-dispatch-per-token loop, with fewer dispatches than steps."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    reqs = _tp2_requests()
    fused = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    res_f, st_f = fused.run(reqs)
    stepped = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN,
                          seed=1, max_fuse_steps=1)
    res_s, st_s = stepped.run([Request(uid=r.uid, prompt=r.prompt,
                                       max_new_tokens=r.max_new_tokens)
                               for r in reqs])
    for a, b in zip(res_f, res_s):
        assert a.tokens == b.tokens, a.uid
        assert a.stop_reason == b.stop_reason == "budget"
    assert st_s.n_dispatches == st_s.decode_steps
    assert st_f.n_dispatches < st_f.decode_steps    # >1 step per dispatch
    assert st_f.decode_steps >= st_s.decode_steps   # window may overshoot EOS


def test_eos_termination():
    """A slot evicts on eos_id; the result reports the stop reason and the
    stream is the budget-run prefix up to (and including) the EOS."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    probe = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = _tp2_requests(n=1, max_new=6)
    (full,), _ = probe.run(reqs)
    assert full.stop_reason == "budget"
    eos = full.tokens[2]                 # force a mid-stream stop
    eng = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1,
                      eos_id=eos)
    (res,), _ = eng.run([Request(uid=9, prompt=reqs[0].prompt,
                                 max_new_tokens=6)])
    stop = full.tokens.index(eos)
    assert res.stop_reason == "eos"
    assert res.tokens == full.tokens[:stop + 1]
    assert int(np.asarray(eng.state.active).sum()) == 0   # slot evicted
    # per-request override beats the engine default (no EOS -> budget)
    (res2,), _ = eng.run([Request(uid=10, prompt=reqs[0].prompt,
                                  max_new_tokens=4, eos_id=-1)])
    assert res2.stop_reason == "budget" and len(res2.tokens) == 4


def test_prompt_bucketing_matches_trunk_tail_baseline():
    """Unaligned prompts (len % tp != 0) admit and match the fixed-batch
    trunk + per-token-tail reference exactly."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    eng = ServeEngine(cfg, run, tp=TP2, n_slots=2, max_len=MAXLEN, seed=1)
    reqs = [Request(uid=0, prompt=RNG.integers(0, 500, (9,)).astype(np.int32),
                    max_new_tokens=4),
            Request(uid=1, prompt=RNG.integers(0, 500, (13,)).astype(np.int32),
                    max_new_tokens=3)]
    results, stats = eng.run(reqs)
    assert stats.n_requests == 2

    mesh = jax.make_mesh((1, TP2), ("data", "model"))
    mesh_cfg = MeshConfig(data=1, model=TP2, pod=1)
    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    pspecs = PM.param_pspecs(table)

    def baseline(req):
        s = len(req.prompt)
        s0 = (s // TP2) * TP2

        def f(pp, toks):
            lg, st = engine.prefill(cfg, run, pp, dims, toks[:, :s0],
                                    MAXLEN, TP2)
            for j in range(s - s0):
                lg, st = engine.decode_step(cfg, run, pp, dims, st,
                                            toks[:, s0 + j:s0 + j + 1], TP2)
            tok = engine.greedy_token(cfg, lg, TP2)
            outs = [tok]
            for _ in range(req.max_new_tokens - 1):
                lg, st = engine.decode_step(cfg, run, pp, dims, st, tok, TP2)
                tok = engine.greedy_token(cfg, lg, TP2)
                outs.append(tok)
            return jnp.concatenate(outs, axis=1)

        fj = jax.jit(cl.shmap(f, mesh, (pspecs, P(None, None)),
                              P(None, None)))
        return np.asarray(fj(eng.params,
                             jnp.asarray(req.prompt)[None]))[0].tolist()

    for req, res in zip(reqs, results):
        assert res.tokens == baseline(req), req.uid


def test_interpret_backend_serving_token_identity():
    """The fused-kernel decode path (Pallas interpret mode) serves token-
    identical streams to the pure-JAX backend — the acceptance bar for
    routing both stores through the kernels."""
    import dataclasses
    cfg = CASES["dense"]
    run_jax = _run_cfg(True)
    reqs = _tp2_requests(n=2, max_new=4)
    eng_jax = ServeEngine(cfg, run_jax, tp=TP2, n_slots=2, max_len=MAXLEN,
                          seed=1)
    res_jax, st_jax = eng_jax.run(reqs)
    assert st_jax.decode_backend == "jax"

    run_k = dataclasses.replace(run_jax, codec=dataclasses.replace(
        run_jax.codec, decode_backend="interpret"))
    eng_k = ServeEngine(cfg, run_k, tp=TP2, n_slots=2, max_len=MAXLEN,
                        seed=1)
    res_k, st_k = eng_k.run([Request(uid=r.uid, prompt=r.prompt,
                                     max_new_tokens=r.max_new_tokens)
                             for r in reqs])
    assert st_k.decode_backend == "interpret"
    for a, b in zip(res_jax, res_k):
        assert a.tokens == b.tokens, a.uid
