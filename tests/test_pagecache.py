"""Tiered PageCache retention-lifecycle tests.

Pure-host randomized schedule sweeps (fixed seeds) drive every
insert/acquire/release/evict/spill/fetch interleaving against a shadow
model — refcounts, LRU residency and index consistency must hold after
every step, double-release and double-register fail loudly — plus
deterministic spill → store-eviction → remote-fetch → re-prefill fallback
coverage, and engine-level randomized admit/share/evict interleavings
that must never change a token stream.
"""

import numpy as np
import pytest

from repro.configs.base import ModelConfig, RunConfig
from repro.core.collectives import CodecConfig
from repro.serve import PageCache, Request, ServeEngine
from repro.serve.digest import page_digest

RNG = np.random.default_rng(5)

CFG = ModelConfig(name="t1", family="dense", n_layers=2, d_model=64,
                  n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=500,
                  head_dim=16)
MAXLEN = 64


def _run_cfg():
    import dataclasses
    return RunConfig(codec=dataclasses.replace(CodecConfig(cache_block=4),
                                               decode_backend="jax"))


# ---------------------------------------------------------------------------
# pure-host lifecycle (no engine, no device state)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_randomized_schedule_invariants(seed):
    """A fixed-seed random interleaving of every cache operation keeps the
    ledger consistent with a shadow model at every step: refcounts match,
    the LRU holds exactly the zero-ref indexed columns, page ids never
    alias, and hot hits are counted exactly once per retained revival."""
    rng = np.random.default_rng(seed)
    cache = PageCache(max_store_pages=8)
    shadow = {}                  # key -> refcount (indexed columns only)
    payload_of = {}              # key -> its immutable page payloads
    next_page = 0
    want_hits = 0

    def check():
        assert set(cache.index) == set(cache.ref) == set(shadow)
        assert all(cache.ref[k] == r for k, r in shadow.items())
        assert set(cache.lru) == {k for k, r in shadow.items() if r == 0}
        assert cache.retained() == len(cache.lru)
        ids = [int(cache.index[k][0]) for k in cache.index]
        assert len(ids) == len(set(ids))          # no column aliasing

    for _ in range(400):
        op = int(rng.integers(0, 5))
        held = [k for k, r in shadow.items() if r > 0]
        retained = [k for k in shadow if shadow[k] == 0]
        if op == 0 or not shadow:
            key = rng.bytes(12)
            if key in shadow:
                continue
            cache.insert(key, np.array([next_page]))
            next_page += 1
            shadow[key] = 1
            payload_of[key] = [rng.bytes(24), rng.bytes(24)]
        elif op == 1:
            key = list(shadow)[int(rng.integers(0, len(shadow)))]
            if shadow[key] == 0:
                want_hits += 1
            np.testing.assert_array_equal(cache.acquire(key),
                                          cache.index[key])
            shadow[key] += 1
        elif op == 2 and held:
            key = held[int(rng.integers(0, len(held)))]
            if shadow[key] == 1 and not cache.has_warm(key):
                cache.spill(key, payload_of[key])     # last release spills
            cache.release(key)
            shadow[key] -= 1
        elif op == 3 and retained:
            key, ids = cache.evict_lru()
            assert shadow.pop(key) == 0
            assert key not in cache.index
        elif op == 4:
            gone = [k for k in payload_of
                    if k not in shadow and cache.has_warm(k)]
            if gone:
                key = gone[int(rng.integers(0, len(gone)))]
                got = cache.fetch_warm(key)
                # the bounded store may have evicted the payloads (no
                # remote tier wired here): that is a counted re-prefill
                # and the dead warm entry is dropped
                if got is None:
                    assert not cache.has_warm(key)
                else:
                    assert got == payload_of[key]
        check()

    assert cache.hot_hits == want_hits
    assert cache.fetched_pages + cache.reprefill_cols + \
        cache.spilled_pages >= 0
    # drain: release everything, then drop_retained empties the ledger
    for key, r in list(shadow.items()):
        for _ in range(r):
            cache.release(key)
    dropped = cache.drop_retained()
    assert len(dropped) == len(shadow)
    assert not cache.index and not cache.ref and not cache.lru
    assert not cache.warm and len(cache.store) == 0


def test_underflow_and_double_register_loud():
    cache = PageCache()
    cache.insert(b"K" * 12, np.array([0]))
    with pytest.raises(AssertionError, match="registered twice"):
        cache.insert(b"K" * 12, np.array([1]))
    cache.release(b"K" * 12)
    with pytest.raises(RuntimeError, match="underflow"):
        cache.release(b"K" * 12)
    with pytest.raises(RuntimeError, match="underflow"):
        cache.release(b"?" * 12)              # never-registered key


def test_spill_fetch_remote_fallback_and_reprefill():
    """Warm payloads evicted from the bounded local store restore from the
    remote tier (digest-verified, re-warmed locally, counted); when every
    tier misses, the caller is told to re-prefill exactly once."""
    peer = {}
    calls = []

    def remote(digests):
        calls.append(list(digests))
        return {d: peer[d] for d in digests if d in peer}

    cache = PageCache(max_store_pages=1, remote_fetch=remote)
    pa, pb = [b"a" * 32, b"b" * 32], [b"c" * 32, b"d" * 32]
    cache.insert(b"A" * 12, np.array([0]))
    cache.insert(b"B" * 12, np.array([1]))
    cache.spill(b"A" * 12, pa)
    cache.spill(b"B" * 12, pb)                # store cap 1: A's bytes gone
    assert cache.spilled_pages == 4 and cache.spilled_bytes == 128
    for p in pa + pb:
        peer[page_digest(p)] = p
    assert cache.fetch_warm(b"A" * 12) == pa
    assert calls and cache.remote_pages > 0
    assert cache.fetched_pages == 2

    # a remote payload that does not hash to its digest is loud
    bad = PageCache(max_store_pages=1,
                    remote_fetch=lambda ds: {d: b"corrupt" for d in ds})
    bad.insert(b"A" * 12, np.array([0]))
    bad.insert(b"B" * 12, np.array([1]))
    bad.spill(b"A" * 12, pa)
    bad.spill(b"B" * 12, pb)
    with pytest.raises(ValueError, match="hash"):
        bad.fetch_warm(b"A" * 12)

    # every tier misses: None, warm entry dropped, re-prefill counted
    lost = PageCache(max_store_pages=1)
    lost.insert(b"A" * 12, np.array([0]))
    lost.insert(b"B" * 12, np.array([1]))
    lost.spill(b"A" * 12, pa)
    lost.spill(b"B" * 12, pb)
    assert lost.fetch_warm(b"A" * 12) is None
    assert not lost.has_warm(b"A" * 12)
    assert lost.reprefill_cols == 1
    assert lost.fetch_warm(b"Z" * 12) is None       # never spilled: no count
    assert lost.reprefill_cols == 1


def test_snapshot_lru_bound():
    cache = PageCache(max_snapshots=3)
    for i in range(4):
        cache.put_snapshot(bytes([i]) * 12, {"g0": i})
    assert cache.get_snapshot(bytes([0]) * 12) is None      # oldest evicted
    assert cache.get_snapshot(bytes([1]) * 12) == {"g0": 1}  # refreshed
    cache.put_snapshot(bytes([9]) * 12, {"g0": 9})
    assert cache.get_snapshot(bytes([2]) * 12) is None      # 1 outlived 2
    assert cache.get_snapshot(bytes([1]) * 12) == {"g0": 1}


# ---------------------------------------------------------------------------
# engine-level: randomized interleavings + evict/spill/restore identity
# ---------------------------------------------------------------------------


def test_evict_spill_restore_identity():
    """The acceptance path: release retains + spills, pool pressure evicts
    the hot columns, and a re-admission restores the prefix from the warm
    store WITHOUT re-prefill — token stream unchanged, bytes counted."""
    run = _run_cfg()
    eng = ServeEngine(CFG, run, tp=1, n_slots=2, max_len=MAXLEN, seed=1)
    a = RNG.integers(0, 500, (16,)).astype(np.int32)   # 4 aligned columns
    (r1,), st1 = eng.run([Request(uid=0, prompt=a, max_new_tokens=4)])
    assert eng.cache.retained() > 0
    keys = list(eng.cache.index)
    assert all(eng.cache.has_warm(k) for k in keys)    # spilled at release
    assert st1.cache_spilled_pages > 0

    eng._ensure_free_pages(1 << 30)          # evict every retained column
    assert eng.cache.retained() == 0
    assert eng.cache.evicted_cols == len(keys)
    assert eng._pages_in_use() == 0

    (r2,), st2 = eng.run([Request(uid=1, prompt=a.copy(),
                                  max_new_tokens=4)])
    assert r2.tokens == r1.tokens
    assert st2.shared_page_hits > 0                    # restored, not cold
    assert st2.cache_fetched_pages > st1.cache_fetched_pages
    assert st2.cache_fetched_bytes > 0
    eng.drop_cache()
    assert eng._pages_in_use() == 0


@pytest.mark.parametrize("seed", [13, 14])
def test_engine_randomized_interleaving_identity(seed):
    """Fixed-seed randomized admit/share/evict interleavings (duplicate
    prompts, forks, fresh prompts, forced evict-all between rounds) serve
    streams identical to the sharing-off engine, and the ledger drains."""
    rng = np.random.default_rng(seed)
    run = _run_cfg()
    bases = [rng.integers(0, 500, (16,)).astype(np.int32),
             rng.integers(0, 500, (16,)).astype(np.int32)]

    def mk(uid):
        kind = int(rng.integers(0, 3))
        if kind == 0:                        # exact duplicate
            p = bases[int(rng.integers(0, 2))].copy()
        elif kind == 1:                      # fork off a shared prefix
            b = bases[int(rng.integers(0, 2))]
            p = np.concatenate([b[:8], rng.integers(0, 500, (8,)
                                                    ).astype(np.int32)])
        else:                                # fresh prompt
            p = rng.integers(0, 500, (8,)).astype(np.int32)
        return Request(uid=uid, prompt=p,
                       max_new_tokens=int(rng.integers(2, 5)))

    eng_on = ServeEngine(CFG, run, tp=1, n_slots=2, max_len=MAXLEN, seed=1)
    eng_off = ServeEngine(CFG, run, tp=1, n_slots=2, max_len=MAXLEN,
                          seed=1, prefix_sharing=False)
    uid = 0
    for rnd in range(3):
        reqs = []
        for _ in range(4):
            reqs.append(mk(uid))
            uid += 1
        res_on, st_on = eng_on.run(reqs)
        res_off, _ = eng_off.run([Request(uid=r.uid, prompt=r.prompt,
                                          max_new_tokens=r.max_new_tokens)
                                  for r in reqs])
        for x, y in zip(res_on, res_off):
            assert x.tokens == y.tokens, (seed, rnd, x.uid)
        if rnd == 1:
            eng_on._ensure_free_pages(1 << 30)   # forced eviction storm
            assert eng_on.cache.retained() == 0
    eng_on.drop_cache()
    assert eng_on._pages_in_use() == 0
    assert not eng_on._prefix_index and not eng_on._prefix_ref
