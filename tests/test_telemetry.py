"""Request-lifecycle tracing + unified metrics registry: registry
snapshot/merge semantics, span-tree invariants on a served mix (every
submitted request closes exactly one root span; children nest inside it),
byte accounting by construction (summed trace bytes equal the stats
counters exactly), Chrome-trace schema round-trip through
``scripts/trace_summary.py``, the METRICS RPC snapshot merge across two
socket replicas, and the telemetry-off identity guarantee."""

import dataclasses
import importlib.util
import json
import math
import pathlib
import socket
import threading

import numpy as np
import pytest

from repro.configs.base import ModelConfig, RunConfig
from repro.core.collectives import CodecConfig
from repro.serve import (DecodeReplica, DisaggEngine, PageHost, Request,
                         ServeEngine, SocketTransport)
from repro.serve.net import framing as fr
from repro.serve.telemetry import (SNAPSHOT_VERSION, MetricsRegistry,
                                   Tracer, sum_counters,
                                   summarize_latencies)

RNG = np.random.default_rng(23)

CFG = ModelConfig(name="t1", family="dense", n_layers=2, d_model=64,
                  n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=500,
                  head_dim=16)
MAXLEN = 64


def _run_cfg():
    return RunConfig(codec=dataclasses.replace(CodecConfig(cache_block=4),
                                               decode_backend="jax"))


def _requests(n=4):
    a = RNG.integers(0, 500, (12,)).astype(np.int32)
    prompts = [a, RNG.integers(0, 500, (9,)).astype(np.int32), a.copy(),
               RNG.integers(0, 500, (16,)).astype(np.int32)]
    return [Request(uid=i, prompt=prompts[i % 4], max_new_tokens=3 + i % 3)
            for i in range(n)]


def _load_trace_summary():
    path = (pathlib.Path(__file__).resolve().parents[1]
            / "scripts" / "trace_summary.py")
    spec = importlib.util.spec_from_file_location("trace_summary", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _span_bytes(tracer, names, key):
    return sum(int(ev["args"].get(key, 0)) for ev in tracer.events
               if ev["name"] in names)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_kinds_and_values():
    reg = MetricsRegistry()
    reg.counter("a.n").inc(3)
    reg.counter("a.n").inc()
    reg.gauge("a.peak", agg="max").set(7)
    reg.histogram("a.lat").observe(0.5)
    reg.histogram("a.lat").observe(1.5)
    assert reg.value("a.n") == 4
    assert reg.value("a.peak") == 7
    assert reg.value("a.missing", default=-1) == -1
    assert reg.values_of("a.lat") == [0.5, 1.5]
    assert reg.values_of("a.n") == []        # not a histogram
    # one name, one kind
    with pytest.raises(TypeError):
        reg.gauge("a.n")
    with pytest.raises(TypeError):
        reg.counter("a.lat")


def test_snapshot_load_merge():
    def make(n, peak, lat):
        r = MetricsRegistry()
        r.counter("serve.tokens").inc(n)
        r.gauge("serve.peak_pages", agg="max").set(peak)
        r.gauge("serve.wall_s", agg="sum").set(n * 0.25)
        r.histogram("latency.request_s").observe(lat)
        return r

    s1, s2 = make(10, 4, 0.1).snapshot(), make(6, 9, 0.7).snapshot()
    assert s1["version"] == SNAPSHOT_VERSION
    # load() inverts snapshot()
    back = MetricsRegistry().load(s1)
    assert back.snapshot() == s1
    merged = MetricsRegistry.merge([s1, s2])
    assert merged["version"] == SNAPSHOT_VERSION
    assert merged["counters"]["serve.tokens"] == 16
    assert merged["gauges"]["serve.peak_pages"]["value"] == 9      # max
    assert merged["gauges"]["serve.wall_s"]["value"] == 4.0        # sum
    assert sorted(merged["hists"]["latency.request_s"]["values"]) == \
        [0.1, 0.7]


def test_latency_and_counter_helpers():
    vals = [0.4, 0.1, 0.9, 0.2]
    s = summarize_latencies(vals)
    assert math.isclose(s["mean"], float(np.mean(vals)))
    assert math.isclose(s["p50"], float(np.percentile(vals, 50)))
    assert math.isclose(s["p95"], float(np.percentile(vals, 95)))
    assert summarize_latencies([]) == {"mean": 0.0, "p50": 0.0, "p95": 0.0}
    total = sum_counters([{"a": 1, "b": 2}, {"a": 3, "c": 5}])
    assert total == {"a": 4, "b": 2, "c": 5}


# ---------------------------------------------------------------------------
# span tree + byte accounting (monolithic engine)
# ---------------------------------------------------------------------------


def test_monolithic_span_tree_and_byte_accounting():
    run = _run_cfg()
    reqs = _requests()
    tracer = Tracer(enabled=True)
    eng = ServeEngine(CFG, run, tp=1, n_slots=2, max_len=MAXLEN, seed=1,
                     tracer=tracer)
    results, st = eng.run(reqs)
    assert len(results) == len(reqs)
    # every submitted request closed exactly one root span
    assert tracer.open_requests() == []
    roots = [ev for ev in tracer.events if ev["name"] == "request"]
    assert sorted(ev["args"]["uid"] for ev in roots) == \
        [r.uid for r in reqs]
    # the structural invariants are the ones trace_summary enforces
    ts = _load_trace_summary()
    errors = []
    spans = [dict(ev, ts=ev["ts"] / 1e3, dur=ev["dur"] / 1e3)
             for ev in tracer.events]
    ts.validate(spans, errors)
    assert errors == []
    # byte accounting by construction: summed span bytes == counters
    reg = eng.registry
    assert st.cache_spilled_bytes > 0
    assert _span_bytes(tracer, ("cache_spill",), "bytes") == \
        reg.value("cache.spilled_bytes") == st.cache_spilled_bytes
    assert _span_bytes(tracer, ("cache_fetch",), "bytes") == \
        reg.value("cache.fetched_bytes") == st.cache_fetched_bytes
    assert _span_bytes(tracer, ("decode_window",), "weight_bytes") == \
        reg.value("weights.hbm_bytes") == \
        st.decode_steps * st.weight_bytes_per_step
    # span-derived latency summaries made it into the stats view
    assert st.ttft_p95_s >= st.ttft_p50_s > 0
    assert reg.values_of("latency.ttft_s")
    assert len(reg.values_of("latency.request_s")) == len(reqs)


# ---------------------------------------------------------------------------
# disagg wire accounting + chrome trace round-trip
# ---------------------------------------------------------------------------


def test_disagg_wire_bytes_and_trace_roundtrip(tmp_path):
    run = _run_cfg()
    reqs = _requests()
    tracer = Tracer(enabled=True)
    eng = DisaggEngine(CFG, run, tp=1, n_prefill=1, n_decode=2, n_slots=2,
                       max_len=MAXLEN, seed=1, streaming=True,
                       tracer=tracer)
    results, st = eng.run(reqs)
    assert len(results) == len(reqs)
    assert tracer.open_requests() == []
    names = {ev["name"] for ev in tracer.events}
    assert {"request", "admit", "export", "wire", "import",
            "decode"} <= names
    assert "wire_chunk" in names            # streaming shipped chunks
    # trace wire bytes == transport registry == stats, exactly
    wire = _span_bytes(tracer, ("wire", "wire_chunk"), "wire_bytes")
    assert wire == eng.transport.registry.value("transport.wire_bytes")
    assert wire == st.wire_bytes > 0
    assert st.ttft_p95_s >= st.ttft_p50_s > 0
    assert all(r.ttft_s > 0 for r in results)
    # chrome-trace JSON round-trips through the summarizer's checker
    ts = _load_trace_summary()
    path = tmp_path / "trace.json"
    tracer.write(str(path))
    trace = json.loads(path.read_text())
    assert {e["ph"] for e in trace["traceEvents"]} == {"M", "X"}
    assert ts.main([str(path), "--check"]) == 0
    assert ts.main([str(path)]) == 0        # summary table mode
    # a duplicated root span is caught
    bad = dict(trace)
    root = next(e for e in trace["traceEvents"]
                if e["ph"] == "X" and e["name"] == "request")
    bad["traceEvents"] = trace["traceEvents"] + [dict(root)]
    badp = tmp_path / "bad.json"
    badp.write_text(json.dumps(bad))
    assert ts.main([str(badp), "--check"]) == 1
    assert ts.main([str(tmp_path / "missing.json"), "--check"]) == 1


# ---------------------------------------------------------------------------
# METRICS RPC across two socket replicas
# ---------------------------------------------------------------------------


def _start_host(run, seed=1):
    eng = ServeEngine(CFG, run, tp=1, n_slots=2, max_len=MAXLEN, seed=seed)
    fp = fr.config_fingerprint(CFG, run.codec, 1, 2, MAXLEN, seed)
    host = PageHost(DecodeReplica(eng), fp, max_store_pages=4096)
    listener = socket.create_server(("127.0.0.1", 0))
    port = listener.getsockname()[1]

    def serve():
        try:
            host.serve_forever(listener, once=True)
        except OSError:
            pass

    threading.Thread(target=serve, daemon=True).start()
    return listener, port


def test_metrics_rpc_two_replica_merge():
    run = _run_cfg()
    reqs = _requests()
    l1, p1 = _start_host(run)
    l2, p2 = _start_host(run)
    addrs = [f"127.0.0.1:{p1}", f"127.0.0.1:{p2}"]
    tr = SocketTransport()
    eng = DisaggEngine(CFG, run, tp=1, n_prefill=1, n_slots=2,
                       max_len=MAXLEN, seed=1, transport=tr,
                       streaming=True, decode_addrs=addrs)
    try:
        results, st = eng.run(reqs)
        snaps = [tr.metrics(d) for d in ("decode0", "decode1")]
        for s in snaps:
            assert s["version"] == SNAPSHOT_VERSION
        # both replicas decoded something; the merge sums their counters
        per = [s["counters"].get("serve.tokens", 0) for s in snaps]
        assert all(n > 0 for n in per)
        merged = MetricsRegistry.merge(snaps)
        assert merged["counters"]["serve.tokens"] == sum(per)
        # fleet snapshot = prefills + remote replicas + transport registry
        fleet = eng.metrics_snapshot()
        assert fleet["version"] == SNAPSHOT_VERSION
        assert fleet["counters"]["transport.wire_bytes"] == st.wire_bytes
        assert fleet["counters"]["serve.tokens"] >= sum(per)
        assert fleet["hists"]["latency.transfer_s"]["values"]
        assert json.loads(json.dumps(fleet)) == fleet   # JSON-clean
    finally:
        tr.close()
        l1.close()
        l2.close()


# ---------------------------------------------------------------------------
# telemetry off: identical streams, identical stats, zero cost
# ---------------------------------------------------------------------------


def test_disabled_tracer_identity():
    run = _run_cfg()
    reqs = _requests()                   # ONE draw: both engines see the
    res_off, st_off = ServeEngine(CFG, run, tp=1, n_slots=2,  # same mix
                                  max_len=MAXLEN, seed=1).run(reqs)
    tracer = Tracer(enabled=True)
    eng_on = ServeEngine(CFG, run, tp=1, n_slots=2, max_len=MAXLEN, seed=1,
                         tracer=tracer)
    res_on, st_on = eng_on.run(reqs)
    for a, b in zip(res_off, res_on):
        assert a.tokens == b.tokens and a.stop_reason == b.stop_reason
    deterministic = [
        "n_requests", "n_tokens", "decode_steps", "n_dispatches",
        "n_admit_dispatches", "n_replay_dispatches", "n_admit_compiles",
        "shared_page_hits", "peak_pages", "peak_cache_bytes",
        "peak_cache_raw_bytes", "decode_backend", "cache_hot_hits",
        "cache_spilled_pages", "cache_spilled_bytes", "cache_fetched_pages",
        "cache_fetched_bytes", "cache_reprefill_cols", "cache_evicted_cols",
        "weights_compressed", "weight_backend", "weight_bytes_per_step",
        "weight_raw_bytes_per_step"]
    for f in deterministic:
        assert getattr(st_off, f) == getattr(st_on, f), f
    # the off tracer records nothing and never reads the clock
    off = Tracer(enabled=False)
    assert not off.enabled and off.now() == 0
    off.request_begin(0, pid="x")
    off.stage(0, "admit")
    off.request_end(0)
    assert off.events == [] and off.open_requests() == []
