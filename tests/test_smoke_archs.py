"""Per-architecture smoke tests (deliverable f): every assigned arch at a
REDUCED config runs one train (or prefill+decode) step on CPU with correct
output shapes and no NaNs.  Full configs are exercised only by the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import (ASSIGNED_ARCHS, PAPER_ARCHS, get_config,
                           make_reduced)
from repro.configs.base import MeshConfig, RunConfig
from repro.core import collectives as cl
from repro.core.collectives import CodecConfig
from repro.models import lm, params as PM
from repro.serve import engine
from repro.train import train_step as TS

RNG = np.random.default_rng(0)
MESH_SHAPE = (2, 4)


def _setup(arch):
    cfg = make_reduced(get_config(arch), tp=MESH_SHAPE[1])
    mesh_cfg = MeshConfig(data=MESH_SHAPE[0], model=MESH_SHAPE[1], pod=1)
    run = RunConfig(codec=CodecConfig(cache_block=8))
    mesh = jax.make_mesh(MESH_SHAPE, ("data", "model"))
    table = lm.lm_table(cfg, mesh_cfg, run)
    return cfg, mesh_cfg, run, mesh, table


def _batch(cfg, B=4, S=32):
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    specs = {"tokens": P("data"), "labels": P("data")}
    if cfg.frontend == "vision_stub":
        batch["front_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (B, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.bfloat16)
        specs["front_embeds"] = P("data")
    if cfg.encdec:
        batch["enc_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (B, S, cfg.d_model)), jnp.bfloat16)
        specs["enc_embeds"] = P("data")
    return batch, specs


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_train_step_smoke(arch):
    cfg, mesh_cfg, run, mesh, table = _setup(arch)
    st = TS.init_state(table, seed=0)
    f = TS.make_shard_mapped_step(cfg, run, mesh_cfg, table, mesh)
    batch, _ = _batch(cfg)
    st, metrics = f(st, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    assert abs(loss - np.log(cfg.vocab_size)) < 1.5
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(st.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_serve_smoke(arch):
    """prefill + 2 decode steps, logits sane (decode applies to every
    assigned arch; encoder-only would skip, none assigned)."""
    cfg, mesh_cfg, run, mesh, table = _setup(arch)
    dims = lm.lm_fsdp_dims(table)
    p = PM.init_params(table, jax.random.key(0))
    pspecs = PM.param_pspecs(table)
    tp = mesh_cfg.model
    batch, especs = _batch(cfg)
    B, S = batch["tokens"].shape

    def serve(pp, bb):
        lg, st = engine.prefill(cfg, run, pp, dims, bb["tokens"], 96, tp,
                                front_embeds=bb.get("front_embeds"),
                                enc_embeds=bb.get("enc_embeds"))
        tok = engine.greedy_token(cfg, lg, tp)
        for _ in range(2):
            lg, st = engine.decode_step(cfg, run, pp, dims, st, tok, tp)
            tok = engine.greedy_token(cfg, lg, tp)
        return lg, tok

    f = jax.jit(cl.shmap(serve, mesh, (pspecs, especs),
                         (P("data", None, "model"), P("data"))))
    logits, tok = f(p, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab(tp))
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert bool(jnp.all((tok >= 0) & (tok < cfg.vocab_size))), arch
