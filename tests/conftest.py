"""Test harness config.

The SPMD tests need a multi-device CPU topology; 8 fake devices keeps the
suite fast.  (The dry-run's 512-device setting stays confined to
``repro.launch.dryrun`` per the assignment — never set it here.)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402  (after XLA_FLAGS)
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from jax.sharding import PartitionSpec as P  # noqa: E402


@pytest.fixture(scope="session")
def mesh24():
    return jax.make_mesh((2, 4), ("data", "model"))


@pytest.fixture(scope="session")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def mesh8():
    return jax.make_mesh((8,), ("model",))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def normal_bf16(rng, shape, std=0.05):
    import jax.numpy as jnp
    return jax.numpy.asarray(rng.normal(0, std, shape), jnp.bfloat16)
