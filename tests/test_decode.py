"""Serving-path tests: prefill + N decode steps must reproduce the logits of
a single full prefill (cache transition, block compression, ring append,
context-parallel merge all on the line)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import (MLAConfig, MeshConfig, ModelConfig,
                                MoEConfig, RunConfig, SSMConfig)
from repro.core import collectives as cl
from repro.core.collectives import CodecConfig
from repro.models import lm, params as PM
from repro.serve import engine

RNG = np.random.default_rng(0)

CASES = {
    "dense_row_kv": ModelConfig(name="t", family="dense", n_layers=2,
                                d_model=64, n_heads=4, n_kv_heads=2,
                                d_ff=128, vocab_size=500, head_dim=16,
                                qkv_bias=True, qk_norm=True),
    "dense_col_kv": ModelConfig(name="t2", family="dense", n_layers=2,
                                d_model=64, n_heads=8, n_kv_heads=4,
                                d_ff=128, vocab_size=500, head_dim=16),
    "padded_heads": ModelConfig(name="p", family="dense", n_layers=2,
                                d_model=64, n_heads=5, n_kv_heads=2,
                                d_ff=128, vocab_size=500, head_dim=16),
    "mla": ModelConfig(name="dv", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=500,
                       head_dim=16,
                       mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16,
                                     qk_rope_dim=8, v_dim=16)),
    "ssm": ModelConfig(name="s", family="ssm", n_layers=2, d_model=64,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=500,
                       ssm=SSMConfig(d_state=16, headdim=8, chunk=16),
                       sub_quadratic=True),
    "hybrid_windowed": ModelConfig(
        name="h", family="hybrid", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=500, head_dim=16,
        parallel_hybrid=True, attn_layout="hymba_3global", window=16,
        ssm=SSMConfig(d_state=16, headdim=8, chunk=16), sub_quadratic=True),
    "moe": ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=500,
                       head_dim=16,
                       moe=MoEConfig(n_experts=8, top_k=2, d_ff=32,
                                     n_shared=1, capacity_factor=4.0)),
    "encdec": ModelConfig(name="e", family="encdec", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=500,
                          head_dim=16, encdec=True, frontend="audio_stub"),
}


def _compare(cfg, mesh_shape=(2, 4), B=4, S=32, NDEC=8):
    mesh_cfg = MeshConfig(data=mesh_shape[0], model=mesh_shape[1], pod=1)
    run = RunConfig(codec=CodecConfig(cache_block=4))
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    tp = mesh_cfg.model
    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    p = PM.init_params(table, jax.random.key(1))
    pspecs = PM.param_pspecs(table)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S + NDEC)),
                       jnp.int32)
    extras = {}
    especs = {}
    if cfg.encdec:
        extras["enc_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (B, S + NDEC, cfg.d_model)), jnp.bfloat16)
        especs["enc_embeds"] = P("data")
    MAXLEN = 128

    def e2e(pp, t, ex):
        enc = ex.get("enc_embeds")
        enc_s = None if enc is None else enc[:, :S]
        lg, st = engine.prefill(cfg, run, pp, dims, t[:, :S], MAXLEN, tp,
                                enc_embeds=enc_s)
        for i in range(NDEC):
            lg, st = engine.decode_step(cfg, run, pp, dims, st,
                                        t[:, S + i:S + i + 1], tp)
        return lg

    def ref(pp, t, ex):
        enc = ex.get("enc_embeds")
        # enc length must track decoder length for the seq-sharded trunk
        lg, st = engine.prefill(cfg, run, pp, dims, t, MAXLEN, tp,
                                enc_embeds=enc)
        return lg

    f1 = jax.jit(cl.shmap(e2e, mesh, (pspecs, P("data"), especs),
                          P("data", None, "model")))
    f2 = jax.jit(cl.shmap(ref, mesh, (pspecs, P("data"), especs),
                          P("data", None, "model")))
    l1 = np.asarray(f1(p, toks, extras)).reshape(B, -1)
    l2 = np.asarray(f2(p, toks, extras)).reshape(B, -1)
    return np.max(np.abs(l1 - l2))


@pytest.mark.parametrize("case", sorted(CASES))
def test_decode_matches_prefill(case):
    if case == "encdec":
        pytest.skip("cross-attn memory differs between the two prefill "
                    "lengths by construction; covered by test_encdec_decode")
    err = _compare(CASES[case])
    # MoE: prefill dispatches with per-shard capacities while decode routes
    # locally — drop patterns differ slightly by construction.
    tol = 0.15 if case == "moe" else 0.05
    assert err < tol, (case, err)


def test_encdec_decode():
    """Enc-dec: decode with a FIXED encoder memory must match a reference
    decoder prefill against the same memory."""
    cfg = CASES["encdec"]
    mesh_cfg = MeshConfig(data=2, model=4, pod=1)
    run = RunConfig(codec=CodecConfig(cache_block=4))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    tp = 4
    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    p = PM.init_params(table, jax.random.key(1))
    pspecs = PM.param_pspecs(table)
    B, S, NDEC = 4, 32, 8
    toks = jnp.asarray(RNG.integers(0, 500, (B, S + NDEC)), jnp.int32)
    # IMPORTANT: same encoder input for both paths (length S+NDEC)
    enc = jnp.asarray(RNG.normal(0, 1, (B, S + NDEC, 64)), jnp.bfloat16)
    MAXLEN = 128

    def e2e(pp, t, ex):
        lg, st = engine.prefill(cfg, run, pp, dims, t[:, :S], MAXLEN, tp,
                                enc_embeds=ex)
        for i in range(NDEC):
            lg, st = engine.decode_step(cfg, run, pp, dims, st,
                                        t[:, S + i:S + i + 1], tp)
        return lg

    def ref(pp, t, ex):
        lg, _ = engine.prefill(cfg, run, pp, dims, t, MAXLEN, tp,
                               enc_embeds=ex)
        return lg

    f1 = jax.jit(cl.shmap(e2e, mesh, (pspecs, P("data"), P("data")),
                          P("data", None, "model")))
    f2 = jax.jit(cl.shmap(ref, mesh, (pspecs, P("data"), P("data")),
                          P("data", None, "model")))
    l1 = np.asarray(f1(p, toks, enc)).reshape(B, -1)
    l2 = np.asarray(f2(p, toks, enc)).reshape(B, -1)
    assert np.max(np.abs(l1 - l2)) < 0.05


def test_codec_off_matches_on():
    """Compressed caches are lossless: decode logits identical on/off."""
    cfg = CASES["dense_col_kv"]
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    mesh_cfg = MeshConfig(data=2, model=4, pod=1)
    tp = 4
    B, S = 4, 32
    toks = jnp.asarray(RNG.integers(0, 500, (B, S + 4)), jnp.int32)
    outs = []
    for codec in (CodecConfig(cache_block=4),
                  CodecConfig.off()):
        run = RunConfig(codec=codec if codec.cache else
                        CodecConfig(enabled=False, weights=False,
                                    cache=False, grads=False, cache_block=4))
        table = lm.lm_table(cfg, mesh_cfg, run)
        dims = lm.lm_fsdp_dims(table)
        p = PM.init_params(table, jax.random.key(1))
        pspecs = PM.param_pspecs(table)

        def e2e(pp, t):
            lg, st = engine.prefill(cfg, run, pp, dims, t[:, :S], 128, tp)
            for i in range(4):
                lg, st = engine.decode_step(cfg, run, pp, dims, st,
                                            t[:, S + i:S + i + 1], tp)
            return lg

        f = jax.jit(cl.shmap(e2e, mesh, (pspecs, P("data")),
                             P("data", None, "model")))
        outs.append(np.asarray(f(p, toks)))
    assert np.array_equal(outs[0], outs[1])


def test_greedy_token(mesh24):
    cfg = CASES["dense_col_kv"]
    logits = jnp.asarray(RNG.normal(0, 1, (4, 1, 512)), jnp.float32)

    def pick(lg):
        tp = 4
        v_loc = lg.shape[-1] // tp
        ti = jax.lax.axis_index("model")
        loc = jax.lax.dynamic_slice_in_dim(lg, ti * v_loc, v_loc, axis=2)
        return engine.greedy_token(cfg, loc, tp)

    got = jax.jit(cl.shmap(pick, mesh24, P("data", None, None),
                           P("data")))(logits)
    want = np.asarray(logits[..., 0, :]).argmax(-1)[:, None]
    assert np.array_equal(np.asarray(got), want)
