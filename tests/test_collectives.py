"""Compressed-collective tests: bit-exact equivalence with the plain
collectives (forward) and with JAX AD semantics (custom VJPs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import collectives as C
from repro.core import weights as W
from repro.core.collectives import CodecConfig

RNG = np.random.default_rng(0)
CFG = CodecConfig()
OFF = CodecConfig.off()


def data(shape=(64, 32, 16)):
    return jnp.asarray(RNG.normal(0, 1, shape), jnp.bfloat16)


def run(mesh8, f, x, inspec=P("model"), outspec=P("model")):
    return jax.jit(C.shmap(f, mesh8, inspec, outspec))(x)


class TestForwardEquivalence:
    def test_all_gather(self, mesh8):
        x = data()
        got = run(mesh8, lambda v: C.compressed_all_gather(v, "model", CFG),
                  x, P("model"), P(None))
        want = run(mesh8, lambda v: jax.lax.all_gather(
            v, "model", axis=0, tiled=True), x, P("model"), P(None))
        assert jnp.array_equal(got, want)

    @pytest.mark.parametrize("gaxis", [1, 2, -1])
    def test_all_gather_nonzero_axis(self, mesh8, gaxis):
        x = data((4, 8, 16))
        got = run(mesh8, lambda v: C.compressed_all_gather(
            v, "model", CFG, gather_axis=gaxis), x, P(None), P(None))
        want = run(mesh8, lambda v: jax.lax.all_gather(
            v, "model", axis=gaxis % 3, tiled=True), x, P(None), P(None))
        assert jnp.array_equal(got, want)

    def test_psum_bit_exact(self, mesh8):
        x = data()
        got = run(mesh8, lambda v: C.compressed_psum(v, "model", CFG), x)
        want = run(mesh8, lambda v: C.compressed_psum(v, "model", OFF), x)
        assert jnp.array_equal(got, want)

    def test_psum_fallback_no_divisible_axis(self, mesh8):
        x = data((7, 5))   # nothing divides 8 -> silent plain-psum fallback
        got = run(mesh8, lambda v: C.compressed_psum(v, "model", CFG), x,
                  P(None), P(None))
        want = run(mesh8, lambda v: jax.lax.psum(v, "model"), x,
                   P(None), P(None))
        assert jnp.array_equal(got, want)

    def test_all_to_all(self, mesh8):
        x = data()
        got = run(mesh8, lambda v: C.compressed_all_to_all(v, "model", CFG),
                  x)
        want = run(mesh8, lambda v: jax.lax.all_to_all(
            v, "model", 0, 0, tiled=True), x)
        assert jnp.array_equal(got, want)

    def test_ppermute(self, mesh8):
        perm = tuple((i, (i + 1) % 8) for i in range(8))
        x = data()
        got = run(mesh8, lambda v: C.compressed_ppermute(
            v, "model", perm, CFG), x)
        want = run(mesh8, lambda v: jax.lax.ppermute(v, "model", perm), x)
        assert jnp.array_equal(got, want)

    def test_sync_gradients(self, mesh8):
        g = {"a": data((16, 8)), "b": data((5, 7))}
        f_on = jax.jit(C.shmap(
            lambda t: C.sync_gradients(t, ("model",), CFG),
            mesh8, P(), P()))
        f_off = jax.jit(C.shmap(
            lambda t: C.sync_gradients(t, ("model",), OFF),
            mesh8, P(), P()))
        for a, b in zip(jax.tree.leaves(f_on(g)), jax.tree.leaves(f_off(g))):
            assert jnp.array_equal(a, b)


class TestVJPs:
    def _grads(self, mesh8, loss, x):
        """x must be SHARED between the two compared losses (fresh draws per
        call bit us once: ppermute's constant grad hid the bug)."""
        return jax.jit(C.shmap(jax.grad(loss), mesh8,
                               P("model"), P("model")))(x)

    def test_all_gather_grad(self, mesh8):
        x = data((64, 32))
        g1 = self._grads(mesh8, lambda v: jnp.sum(
            C.lexi_all_gather(v, "model", CFG, 0).astype(jnp.float32) ** 2),
            x)
        g2 = self._grads(mesh8, lambda v: jnp.sum(
            jax.lax.all_gather(v, "model", axis=0, tiled=True)
            .astype(jnp.float32) ** 2), x)
        assert jnp.array_equal(g1, g2)

    def test_all_to_all_grad(self, mesh8):
        x = data((64, 32))
        g1 = self._grads(mesh8, lambda v: jnp.sum(
            C.lexi_all_to_all(v, "model", CFG).astype(jnp.float32) ** 2), x)
        g2 = self._grads(mesh8, lambda v: jnp.sum(
            jax.lax.all_to_all(v, "model", 0, 0, tiled=True)
            .astype(jnp.float32) ** 2), x)
        assert jnp.array_equal(g1, g2)

    def test_ppermute_grad(self, mesh8):
        perm = tuple((i, (i + 3) % 8) for i in range(8))
        x = data((64, 32))
        g1 = self._grads(mesh8, lambda v: jnp.sum(
            C.lexi_ppermute(v, "model", perm, CFG).astype(jnp.float32) * 3),
            x)
        g2 = self._grads(mesh8, lambda v: jnp.sum(
            jax.lax.ppermute(v, "model", perm).astype(jnp.float32) * 3), x)
        assert jnp.array_equal(g1, g2)

    def test_psum_grad(self, mesh8):
        # RS+AG vs tree-allreduce may round differently in bf16: tolerance.
        x = data((64, 32))
        g1 = self._grads(mesh8, lambda v: jnp.sum(
            C.lexi_psum(v, "model", CFG).astype(jnp.float32) ** 2), x)
        g2 = self._grads(mesh8, lambda v: jnp.sum(
            jax.lax.psum(v, "model").astype(jnp.float32) ** 2), x)
        np.testing.assert_allclose(np.asarray(g1, np.float32),
                                   np.asarray(g2, np.float32),
                                   rtol=2e-2, atol=2e-2)


class TestWeightStore:
    def test_roundtrip_and_size(self):
        params = {"w": data((512, 64)), "scale": jnp.ones((64,), jnp.float32)}
        cp = W.compress_params(params, CFG)
        back = W.decompress_params(cp)
        assert jnp.array_equal(back["w"], params["w"])
        assert jnp.array_equal(back["scale"], params["scale"])
        assert W.stored_bytes(cp) < W.param_bytes(params)

    def test_small_leaves_stay_raw(self):
        params = {"tiny": data((8,))}
        cp = W.compress_params(params, CFG)
        assert not cp["tiny"].compressed
