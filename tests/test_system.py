"""End-to-end behaviour tests: the example trainer (with failure injection +
restart) and the serving loop, run through the public CLI entry points."""

import numpy as np
import pytest


def test_train_cli_with_failure_and_restart(tmp_path):
    from repro.launch import train as train_cli
    rc = train_cli.main([
        "--arch", "qwen3-4b", "--steps", "12", "--mesh", "2x4",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "4", "--simulate-failure", "6",
    ])
    assert rc == 0
    from repro.train import checkpoint as ckpt
    assert ckpt.latest_step(str(tmp_path)) == 12


def test_train_cli_loss_decreases(tmp_path):
    from repro.launch.train import train_loop
    from repro.configs import get_config, make_reduced
    from repro.configs.base import MeshConfig, RunConfig, ShapeConfig
    cfg = make_reduced(get_config("granite-moe-1b-a400m"), tp=4)
    out = train_loop(cfg, ShapeConfig("t", 32, 8, "train"),
                     MeshConfig(2, 4, 1), RunConfig(warmup_steps=2),
                     steps=16, ckpt_dir=None, ckpt_every=0, resume=False,
                     log=lambda *_: None)
    assert out["final_loss"] < out["first_loss"]


def test_serve_cli():
    from repro.launch import serve as serve_cli
    rc = serve_cli.main([
        "--arch", "qwen3-4b", "--batch", "2", "--prompt-len", "32",
        "--new-tokens", "4", "--mesh", "2x4",
    ])
    assert rc == 0
