"""Decode-backend dispatch parity: ``attend_cache`` / ``attend_paged`` must
produce matching results whether they route through the fused Pallas kernels
(interpret mode on CPU) or the pure-JAX block/page scan — across GQA/MQA,
windowed and full attention, codec on/off, MLA, and tp in {1, 2}.

The stores are built through the real write paths (``fill_from_prefill`` /
``paged_insert_many`` equivalents would drag in the whole engine; instead we
drive ``append_token``/``append_token_paged`` inside shard_map so ring
state, block flushes and page allocation are all the production article).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import MLAConfig, ModelConfig, RunConfig
from repro.core import collectives as cl
from repro.core.collectives import CodecConfig
from repro.kernels import ops as kops
from repro.models import cache as cache_mod
from repro.models import layers

RNG = np.random.default_rng(7)
BLK = 4


def _cfg(n_heads, n_kv_heads, mla=False):
    return ModelConfig(
        name="t", family="dense", n_layers=1, d_model=32, n_heads=n_heads,
        n_kv_heads=n_kv_heads, d_ff=64, vocab_size=128, head_dim=8,
        mla=MLAConfig(kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=4,
                      v_dim=8) if mla else None)


def _run(codec_on, backend):
    codec = CodecConfig(cache_block=BLK, decode_backend=backend) if codec_on \
        else dataclasses.replace(CodecConfig.off(), cache_block=BLK,
                                 decode_backend=backend)
    return RunConfig(codec=codec)


def _mesh(tp):
    return jax.make_mesh((tp,), ("model",))


def _attend_fixed(cfg, run, tp, q, stream, length, spec, window):
    """Build a fixed store by appending ``stream`` tokens, then attend."""
    mesh = _mesh(tp)

    def f(q_, vals):
        kv = cache_mod.empty_kv(cfg, run, q_.shape[0], 32 * tp, tp)

        def body(kv_c, v):
            return cache_mod.append_token(cfg, run, kv_c, v, tp), None

        kv, _ = jax.lax.scan(body, kv, vals)
        return cache_mod.attend_cache(cfg, run, kv, q_, spec, tp,
                                      window=window)

    fj = jax.jit(cl.shmap(f, mesh, (P(), P()), P()))
    return np.asarray(fj(q, stream))


def _attend_paged_fn(cfg, run, tp, n_slots, q, stream, lengths, spec,
                     window):
    """Drive per-slot appends (ragged via the active mask), then attend."""
    mesh = _mesh(tp)
    max_len = 32 * tp

    def f(q_, vals, lens):
        pkv = cache_mod.empty_paged_kv(cfg, run, n_slots, max_len, tp)
        n_tok = vals.shape[0]

        def body(carry, v):
            pkv_c, cur = carry
            active = cur < lens
            pkv_c = cache_mod.append_token_paged(cfg, run, pkv_c, v, cur,
                                                 active, tp)
            return (pkv_c, cur + active.astype(jnp.int32)), None

        (pkv, _), _ = jax.lax.scan(body, (pkv, jnp.zeros_like(lens)), vals)
        return cache_mod.attend_paged(cfg, run, pkv, q_, lens, spec, tp,
                                      window=window)

    fj = jax.jit(cl.shmap(f, mesh, (P(), P(), P()), P()))
    return np.asarray(fj(q, stream, lengths))


@pytest.mark.parametrize("tp", [1, 2])
@pytest.mark.parametrize("codec_on", [True, False], ids=["codec", "raw"])
@pytest.mark.parametrize("heads", [(4, 2), (3, 1)], ids=["gqa", "mqa"])
@pytest.mark.parametrize("window", [None, 5], ids=["full", "windowed"])
def test_attend_paged_backend_parity(tp, codec_on, heads, window):
    cfg = _cfg(*heads)
    n_slots = 3
    hq = cfg.padded_heads(tp)
    w = cache_mod.kv_width(cfg)
    n_tok = 3 * BLK * tp + 2
    lengths = jnp.asarray([n_tok, BLK * tp + 1, 0], jnp.int32)
    stream = jnp.asarray(RNG.normal(0, 0.5, (n_tok, n_slots, w)),
                         jnp.bfloat16)
    q = jnp.asarray(RNG.normal(0, 1, (n_slots, hq, 1, cfg.head_dim)),
                    jnp.bfloat16)
    spec = layers.AttnSpec(causal=True, windowed=window is not None)
    outs = {}
    for backend in ("jax", "interpret"):
        run = _run(codec_on, backend)
        outs[backend] = _attend_paged_fn(cfg, run, tp, n_slots, q, stream,
                                         lengths, spec, window)
    np.testing.assert_allclose(
        np.asarray(outs["jax"], np.float32),
        np.asarray(outs["interpret"], np.float32), rtol=2e-2, atol=2e-2)
    # empty slot produces all-zero attention on both paths
    assert np.all(np.asarray(outs["interpret"], np.float32)[2] == 0.0)


@pytest.mark.parametrize("tp", [1, 2])
@pytest.mark.parametrize("codec_on", [True, False], ids=["codec", "raw"])
def test_attend_cache_backend_parity(tp, codec_on):
    cfg = _cfg(4, 2)
    b = 2
    hq = cfg.padded_heads(tp)
    w = cache_mod.kv_width(cfg)
    n_tok = 2 * BLK * tp + 3
    stream = jnp.asarray(RNG.normal(0, 0.5, (n_tok, b, w)), jnp.bfloat16)
    q = jnp.asarray(RNG.normal(0, 1, (b, hq, 1, cfg.head_dim)), jnp.bfloat16)
    spec = layers.AttnSpec(causal=True, softcap=30.0)
    outs = {}
    for backend in ("jax", "interpret"):
        run = _run(codec_on, backend)
        outs[backend] = _attend_fixed(cfg, run, tp, q, stream, n_tok, spec,
                                      None)
    np.testing.assert_allclose(
        np.asarray(outs["jax"], np.float32),
        np.asarray(outs["interpret"], np.float32), rtol=2e-2, atol=2e-2)


def test_attend_paged_mla_backend_parity():
    cfg = _cfg(4, 4, mla=True)
    tp, n_slots = 2, 2
    hq = cfg.padded_heads(tp)
    w = cache_mod.kv_width(cfg)                 # lora + rope latent
    hd_q = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
    n_tok = BLK * tp + 3
    lengths = jnp.asarray([n_tok, 2], jnp.int32)
    stream = jnp.asarray(RNG.normal(0, 0.5, (n_tok, n_slots, w)),
                         jnp.bfloat16)
    q = jnp.asarray(RNG.normal(0, 1, (n_slots, hq, 1, hd_q)), jnp.bfloat16)
    spec = layers.AttnSpec(
        causal=True,
        scale=(cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim) ** -0.5)
    outs = {}
    for backend in ("jax", "interpret"):
        run = _run(True, backend)
        outs[backend] = _attend_paged_fn(cfg, run, tp, n_slots, q, stream,
                                         lengths, spec, None)
    assert outs["jax"].shape[-1] == cfg.mla.kv_lora_rank
    np.testing.assert_allclose(
        np.asarray(outs["jax"], np.float32),
        np.asarray(outs["interpret"], np.float32), rtol=2e-2, atol=2e-2)


def test_resolve_decode_backend():
    assert kops.resolve_decode_backend(CodecConfig()) == "jax"  # CPU auto
    assert kops.resolve_decode_backend(
        CodecConfig(decode_backend="interpret")) == "interpret"
    with pytest.raises(ValueError, match="decode_backend"):
        kops.resolve_decode_backend(CodecConfig(decode_backend="nope"))
