"""docs/ARCHITECTURE.md must exist, be linked from README + ROADMAP, and
every `path:symbol` code reference in it must resolve against the tree —
the same check CI runs standalone (scripts/check_docs.py), enforced here so
`make verify` catches doc rot too."""

import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_architecture_doc_references_resolve():
    r = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_docs.py")],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "references resolved" in r.stdout


def test_architecture_doc_is_linked():
    assert (ROOT / "docs" / "ARCHITECTURE.md").is_file()
    for name in ("README.md", "ROADMAP.md"):
        text = (ROOT / name).read_text()
        assert "docs/ARCHITECTURE.md" in text, name
