"""Model-family tests: SPMD decomposition equivalence (2x4 mesh vs single
device), train-step consistency (mesh / FSDP / codec), loss decrease."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import (MLAConfig, MeshConfig, ModelConfig,
                                MoEConfig, RunConfig, SSMConfig)
from repro.core import collectives as cl
from repro.core.collectives import CodecConfig
from repro.models import lm, params as PM
from repro.train import train_step as TS

RNG = np.random.default_rng(0)

FAMILIES = {
    "dense": ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=500,
                         head_dim=16, qkv_bias=True, qk_norm=True),
    "gemma2like": ModelConfig(name="g", family="dense", n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                              vocab_size=500, head_dim=16, post_norm=True,
                              attn_softcap=50.0, final_softcap=30.0,
                              scale_embeddings=True, tie_embeddings=True,
                              attn_layout="alternating_local", window=16),
    "moe": ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=500,
                       head_dim=16,
                       moe=MoEConfig(n_experts=8, top_k=2, d_ff=32,
                                     n_shared=1)),
    "mla_moe": ModelConfig(name="dv", family="moe", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=500,
                           head_dim=16,
                           mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16,
                                         qk_rope_dim=8, v_dim=16),
                           moe=MoEConfig(n_experts=8, top_k=2, d_ff=32)),
    "ssm": ModelConfig(name="s", family="ssm", n_layers=2, d_model=64,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=500,
                       ssm=SSMConfig(d_state=16, headdim=8, chunk=16),
                       sub_quadratic=True),
    "hybrid": ModelConfig(name="h", family="hybrid", n_layers=3, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=500,
                          head_dim=16, parallel_hybrid=True,
                          attn_layout="hymba_3global", window=16,
                          ssm=SSMConfig(d_state=16, headdim=8, chunk=16),
                          sub_quadratic=True),
    "encdec": ModelConfig(name="e", family="encdec", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=500,
                          head_dim=16, encdec=True, frontend="audio_stub"),
    "vlm": ModelConfig(name="v", family="vlm", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=500,
                       head_dim=16, frontend="vision_stub",
                       n_frontend_tokens=8),
}


def _loss_for(cfg, mesh_shape, B=4, S=64, fsdp=False,
              codec=CodecConfig.off()):
    mesh_cfg = MeshConfig(data=mesh_shape[0], model=mesh_shape[1], pod=1)
    run = RunConfig(codec=codec, fsdp=fsdp)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    p = PM.init_params(table, jax.random.key(1))
    pspecs = PM.param_pspecs(table)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    bspecs = {"tokens": P("data"), "labels": P("data")}
    if cfg.frontend == "vision_stub":
        batch["front_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (B, 8, cfg.d_model)), jnp.bfloat16)
        bspecs["front_embeds"] = P("data")
    if cfg.encdec:
        batch["enc_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (B, S, cfg.d_model)), jnp.bfloat16)
        bspecs["enc_embeds"] = P("data")

    def local_loss(pp, bb):
        return lm.train_loss(cfg, run, pp, bb, mesh_cfg.model, ("data",),
                             dims=dims)

    def global_loss(pp, bb):
        return jax.lax.psum(local_loss(pp, bb), ("data", "model"))

    f = jax.jit(cl.shmap(global_loss, mesh, (pspecs, bspecs), P()))
    return float(f(p, batch))


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_spmd_matches_single_device(family):
    cfg = FAMILIES[family]
    l_par = _loss_for(cfg, (2, 4))
    l_ref = _loss_for(cfg, (1, 1))
    assert np.isfinite(l_par) and np.isfinite(l_ref)
    assert abs(l_par - l_ref) < 0.06, (family, l_par, l_ref)
    assert abs(l_ref - np.log(cfg.vocab_size)) < 0.25  # sane init loss


class TestTrainStep:
    def _run(self, mesh_shape, fsdp, codec, steps=4):
        cfg = FAMILIES["dense"]
        mesh_cfg = MeshConfig(data=mesh_shape[0], model=mesh_shape[1], pod=1)
        run = RunConfig(codec=codec, fsdp=fsdp)
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        table = lm.lm_table(cfg, mesh_cfg, run)
        st = TS.init_state(table, seed=1)
        f = TS.make_shard_mapped_step(cfg, run, mesh_cfg, table, mesh)
        # fixed batch: runs being compared must see identical data
        toks = jnp.asarray(
            np.random.default_rng(7).integers(0, 500, (4, 32)), jnp.int32)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        losses = []
        for _ in range(steps):
            st, m = f(st, batch)
            losses.append(float(m["loss"]))
        return st, losses

    def test_loss_decreases(self):
        _, losses = self._run((2, 4), False, CodecConfig.off(), steps=8)
        assert losses[-1] < losses[0]

    def test_fsdp_bit_identical(self):
        st_a, _ = self._run((2, 4), False, CodecConfig.off())
        st_b, _ = self._run((2, 4), True, CodecConfig.off())
        for a, b in zip(jax.tree.leaves(st_a.params),
                        jax.tree.leaves(st_b.params)):
            assert np.array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))

    def test_codec_bit_identical(self):
        st_a, _ = self._run((2, 4), True, CodecConfig.off())
        st_b, _ = self._run((2, 4), True, CodecConfig())
        for a, b in zip(jax.tree.leaves(st_a.params),
                        jax.tree.leaves(st_b.params)):
            assert np.array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))

    def test_mesh_consistent(self):
        st_a, _ = self._run((1, 1), False, CodecConfig.off())
        st_b, _ = self._run((2, 4), False, CodecConfig.off())
        for a, b in zip(jax.tree.leaves(st_a.params),
                        jax.tree.leaves(st_b.params)):
            d = np.max(np.abs(np.asarray(a, np.float32)
                              - np.asarray(b, np.float32)))
            assert d < 2e-2
