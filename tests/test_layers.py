"""Layer-level unit tests: flash attention vs naive softmax, SSD vs naive
recurrence, MoE dispatch conservation, rope/norm primitives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers
from repro.models.layers import AttnSpec

RNG = np.random.default_rng(1)


def bf16(shape, std=1.0):
    return jnp.asarray(RNG.normal(0, std, shape), jnp.bfloat16)


def naive_attention(q, k, v, causal=True, window=None, cap=None):
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    b, hq, s, hd = qf.shape
    hkv = kf.shape[1]
    g = hq // hkv
    kf = jnp.repeat(kf, g, axis=1)
    vf = jnp.repeat(vf, g, axis=1)
    s_ = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * hd ** -0.5
    if cap is not None:
        s_ = jnp.tanh(s_ / cap) * cap
    pos = jnp.arange(s)
    m = jnp.ones((s, s), bool)
    if causal:
        m &= pos[None, :] <= pos[:, None]
    if window is not None:
        m &= pos[None, :] > pos[:, None] - window
    s_ = jnp.where(m, s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf)


class TestFlashAttention:
    @pytest.mark.parametrize("chunks", [(32, 32), (64, 32), (128, 128)])
    def test_causal_matches_naive(self, chunks):
        q, k, v = bf16((2, 4, 128, 16)), bf16((2, 2, 128, 16)), \
            bf16((2, 2, 128, 16))
        pos = jnp.arange(128)
        out = layers.flash_attention(q, k, v, pos, pos, AttnSpec(causal=True),
                                     chunk_q=chunks[0], chunk_kv=chunks[1])
        ref = naive_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), atol=0.02)

    def test_windowed(self):
        q, k, v = bf16((1, 2, 128, 16)), bf16((1, 2, 128, 16)), \
            bf16((1, 2, 128, 16))
        pos = jnp.arange(128)
        out = layers.flash_attention(
            q, k, v, pos, pos, AttnSpec(causal=True, windowed=True),
            window=jnp.int32(17), chunk_q=32, chunk_kv=32)
        ref = naive_attention(q, k, v, window=17)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), atol=0.02)

    def test_softcap(self):
        q, k, v = bf16((1, 2, 64, 16)), bf16((1, 2, 64, 16)), \
            bf16((1, 2, 64, 16))
        pos = jnp.arange(64)
        out = layers.flash_attention(
            q, k, v, pos, pos, AttnSpec(causal=True, softcap=5.0),
            chunk_q=32, chunk_kv=32)
        ref = naive_attention(q, k, v, cap=5.0)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), atol=0.02)

    def test_merge_partials_equals_whole(self, mesh8):
        """Sharded partial attention + logsumexp merge == unsharded."""
        from jax.sharding import PartitionSpec as P
        from repro.core import collectives as cl
        b, h, L, hd = 2, 4, 64, 16
        q = bf16((b, h, 1, hd))
        k, v = bf16((b, h, L, hd)), bf16((b, h, L, hd))
        valid = jnp.ones((b, L), bool)

        def sharded(q_, k_, v_, val):
            o, m, l = layers.attention_partial(q_, k_, v_, val,
                                               AttnSpec(causal=False))
            return layers.merge_partials(o, m, l, "model")

        got = jax.jit(cl.shmap(
            sharded, mesh8,
            (P(None), P(None, None, "model"), P(None, None, "model"),
             P(None, "model")), P(None)))(q, k, v, valid)
        o, m, l = layers.attention_partial(q, k, v, valid,
                                           AttnSpec(causal=False))
        want = (o / jnp.maximum(l, 1e-30)[..., None]).astype(jnp.bfloat16)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=0.02)


class TestSSD:
    def test_chunked_matches_recurrence(self):
        """Chunked SSD == naive per-token recurrence (same math)."""
        from repro.models.ssm import ssd_chunked
        b, s, h, p, n = 1, 48, 2, 8, 4
        x = jnp.asarray(RNG.normal(0, 1, (b, s, h, p)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
        a = -jnp.asarray(RNG.uniform(0.1, 1.0, (h,)), jnp.float32)
        bb = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
        cc = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
        y, state = ssd_chunked(x, dt, a, bb, cc, chunk=16)

        # naive recurrence
        hstate = np.zeros((b, h, p, n))
        ys = np.zeros((b, s, h, p))
        for t in range(s):
            decay = np.exp(np.asarray(dt[:, t]) * np.asarray(a))  # (b,h)
            upd = np.einsum("bh,bhp,bn->bhpn", np.asarray(dt[:, t]),
                            np.asarray(x[:, t]), np.asarray(bb[:, t]))
            hstate = hstate * decay[..., None, None] + upd
            ys[:, t] = np.einsum("bhpn,bn->bhp", hstate, np.asarray(cc[:, t]))
        np.testing.assert_allclose(np.asarray(y, np.float32), ys,
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(state), hstate,
                                   rtol=1e-3, atol=1e-3)

    def test_pad_tail_exact(self):
        from repro.models.ssm import ssd_chunked
        b, s, h, p, n = 1, 40, 2, 8, 4   # 40 % 16 != 0 -> padded internally
        x = jnp.asarray(RNG.normal(0, 1, (b, s, h, p)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
        a = -jnp.ones((h,), jnp.float32)
        bb = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
        cc = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
        y16, st16 = ssd_chunked(x, dt, a, bb, cc, chunk=16)
        y40, st40 = ssd_chunked(x, dt, a, bb, cc, chunk=40)
        np.testing.assert_allclose(np.asarray(y16, np.float32),
                                   np.asarray(y40, np.float32),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(st16), np.asarray(st40),
                                   rtol=1e-3, atol=1e-3)


class TestMoEDispatch:
    def test_no_drop_conservation(self, mesh8):
        """With ample capacity, MoE output == dense sum of chosen experts."""
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import ModelConfig, MoEConfig, RunConfig
        from repro.core import collectives as cl
        from repro.core.collectives import CodecConfig
        from repro.models import moe as moe_mod
        from repro.models.params import init_params

        cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                          n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=100,
                          moe=MoEConfig(n_experts=8, top_k=2, d_ff=16,
                                        capacity_factor=8.0))
        run = RunConfig(codec=CodecConfig.off())
        table = moe_mod.moe_table(cfg, 8)
        params = init_params(table, jax.random.key(0))
        x = bf16((2, 8, 32), 0.5)

        def f(p, xx):
            y, aux = moe_mod.moe_forward(cfg, run, p, xx, 8)
            return y

        pspecs = jax.tree_util.tree_map(
            lambda d: d.partition_spec(), table,
            is_leaf=lambda z: hasattr(z, "partition_spec"))
        got = jax.jit(cl.shmap(f, mesh8, (pspecs, P(None)), P(None)))(
            params, x)
        # dense reference: route, run experts, weighted-sum
        xt = np.asarray(x, np.float32).reshape(-1, 32)
        logits = xt @ np.asarray(params["router"], np.float32)
        probs = jax.nn.softmax(jnp.asarray(logits), -1)
        topv, topi = jax.lax.top_k(probs, 2)
        topv = np.asarray(topv / topv.sum(-1, keepdims=True))
        wg = np.asarray(params["w_gate"], np.float32)
        wu = np.asarray(params["w_up"], np.float32)
        wd = np.asarray(params["w_down"], np.float32)
        want = np.zeros_like(xt)
        for t in range(xt.shape[0]):
            for j in range(2):
                e = int(topi[t, j])
                hsw = (xt[t] @ wg[e])
                hsw = hsw / (1 + np.exp(-hsw)) * (xt[t] @ wu[e])
                want[t] += topv[t, j] * (hsw @ wd[e])
        np.testing.assert_allclose(np.asarray(got, np.float32).reshape(-1, 32),
                                   want, rtol=0.1, atol=0.05)


class TestPrimitives:
    def test_rope_orthogonal(self):
        x = bf16((1, 2, 16, 32))
        cos, sin = layers.rope_tables(jnp.arange(16), 32, 1e4)
        y = layers.apply_rope(x, cos, sin)
        # rotation preserves norms
        nx = np.linalg.norm(np.asarray(x, np.float32), axis=-1)
        ny = np.linalg.norm(np.asarray(y, np.float32), axis=-1)
        np.testing.assert_allclose(nx, ny, rtol=2e-2, atol=1e-2)

    def test_rope_position_zero_identity(self):
        x = bf16((1, 1, 1, 16))
        cos, sin = layers.rope_tables(jnp.zeros((1,)), 16, 1e4)
        y = layers.apply_rope(x, cos, sin)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(x, np.float32), atol=1e-6)

    def test_rmsnorm_unit_scale(self):
        x = bf16((4, 64), 3.0)
        y = layers.rms_norm(x, jnp.ones((64,)))
        rms = np.sqrt((np.asarray(y, np.float32) ** 2).mean(-1))
        np.testing.assert_allclose(rms, 1.0, atol=0.05)

    def test_softcap_bounds(self):
        x = jnp.asarray([-1e9, -1.0, 0.0, 1.0, 1e9])
        y = layers.softcap(x, 30.0)
        assert float(jnp.max(jnp.abs(y))) <= 30.0
        np.testing.assert_allclose(float(y[2]), 0.0, atol=1e-6)
