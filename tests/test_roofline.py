"""Roofline accounting tests: the jaxpr walker must count scan bodies by
trip count and collectives at per-shard operand bytes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import collectives as cl
from repro.roofline import analysis as RA


class TestJaxprWalker:
    def test_dot_flops(self):
        def f(a, b):
            return a @ b

        j = jax.make_jaxpr(f)(jnp.zeros((8, 16)), jnp.zeros((16, 32)))
        st = RA.analyze_jaxpr(j)
        assert st.flops == 2 * 8 * 16 * 32

    def test_scan_multiplies(self):
        w = jnp.zeros((4, 16, 16))

        def f(x):
            def body(c, wi):
                return c @ wi, None
            y, _ = jax.lax.scan(body, x, w)
            return y

        j = jax.make_jaxpr(f)(jnp.zeros((8, 16)))
        st = RA.analyze_jaxpr(j)
        assert st.flops == 4 * 2 * 8 * 16 * 16

    def test_grad_counts_backward(self):
        def f(a):
            return jnp.sum((a @ jnp.ones((16, 8))) ** 2)

        j = jax.make_jaxpr(jax.grad(f))(jnp.zeros((4, 16)))
        st = RA.analyze_jaxpr(j)
        assert st.flops >= 2 * 2 * 4 * 16 * 8   # fwd + bwd dots

    def test_collective_bytes_per_shard(self, mesh8):
        def f(x):
            return jax.lax.all_gather(x, "model", axis=0, tiled=True)

        g = cl.shmap(f, mesh8, P("model"), P(None))
        x = jnp.zeros((64, 32), jnp.bfloat16)
        st = RA.analyze_jaxpr(jax.make_jaxpr(jax.jit(g))(x),
                              {"model": 8})
        # per-shard operand: (8, 32) bf16 = 512 bytes; wire = (n-1)x operand
        assert st.coll_bytes["all_gather"] == 8 * 32 * 2
        assert st.wire_bytes["all_gather"] == 7 * 8 * 32 * 2
        assert st.coll_counts["all_gather"] == 1

    def test_scan_of_collectives(self, mesh8):
        def f(x):
            def body(c, _):
                return jax.lax.psum(c, "model"), None
            y, _ = jax.lax.scan(body, x, None, length=5)
            return y

        g = cl.shmap(f, mesh8, P(None), P(None))
        x = jnp.zeros((16,), jnp.float32)
        st = RA.analyze_jaxpr(jax.make_jaxpr(jax.jit(g))(x), {"model": 8})
        assert st.coll_counts["all_reduce"] == 5
        assert st.coll_bytes["all_reduce"] == 5 * 16 * 4
        assert abs(st.wire_bytes["all_reduce"]
                   - 5 * 16 * 4 * 2 * 7 / 8) < 1e-6


class TestRooflineModel:
    def test_terms_and_dominance(self):
        r = RA.Roofline(arch="a", shape="s", mesh="m", chips=256,
                        hlo_flops=256 * 197e12, hlo_bytes=256 * 819e9,
                        collective_bytes=25e9,
                        model_flops=128 * 197e12).finalize()
        assert abs(r.compute_s - 1.0) < 1e-9
        assert abs(r.memory_s - 1.0) < 1e-9
        assert abs(r.collective_s - 0.5) < 1e-9
        assert r.dominant in ("compute", "memory")
        assert abs(r.useful_ratio - 0.5) < 1e-9

    def test_decode_ideal_is_bandwidth(self):
        r = RA.Roofline(arch="a", shape="s", mesh="m", chips=256,
                        hlo_flops=1e10, hlo_bytes=256 * 819e9,
                        collective_bytes=0.0, model_flops=1e9,
                        min_bytes=819e9).finalize()
        assert abs(r.ideal_s - 1.0) < 1e-9     # memory floor, not compute
        assert abs(r.roofline_fraction - 1.0) < 1e-9

    def test_memory_model_codec_effect(self):
        from repro.configs import SHAPES, get_config
        from repro.configs.base import MeshConfig, RunConfig
        from repro.core.collectives import CodecConfig
        cfg = get_config("qwen3-4b")
        mesh = MeshConfig(16, 16, 1)
        on = RA.analytic_memory_bytes(cfg, SHAPES["decode_32k"], mesh,
                                      RunConfig(fsdp=False))
        off = RA.analytic_memory_bytes(cfg, SHAPES["decode_32k"], mesh,
                                       RunConfig(fsdp=False,
                                                 codec=CodecConfig.off()))
        assert on["params"] < off["params"]      # packed weights
        assert on["kv_cache"] < off["kv_cache"]  # packed cache


class TestFsdpStrategy:
    def test_matches_megatron(self, mesh24):
        from repro.configs.base import ModelConfig, MeshConfig, RunConfig
        from repro.core.collectives import CodecConfig
        from repro.models import lm, params as PM

        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=500,
                          head_dim=16)
        B, S = 8, 64
        toks = jnp.asarray(
            np.random.default_rng(0).integers(0, 500, (B, S)), jnp.int32)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

        def loss_for(strategy):
            mesh_cfg = MeshConfig(data=2, model=4, pod=1)
            run = RunConfig(codec=CodecConfig.off(), tp_strategy=strategy)
            table = lm.lm_table(cfg, mesh_cfg, run)
            dims = lm.lm_fsdp_dims(table)
            p = PM.init_params(table, jax.random.key(1))
            pspecs = PM.param_pspecs(table)

            def g(pp, bb):
                return jax.lax.psum(
                    lm.train_loss(cfg, run, pp, bb, 4, ("data",), dims=dims),
                    ("data", "model"))

            f = jax.jit(cl.shmap(g, mesh24,
                                 (pspecs, {"tokens": P("data"),
                                           "labels": P("data")}), P()))
            return float(f(p, batch))

        a, b = loss_for("megatron"), loss_for("fsdp")
        assert abs(a - b) < 0.02, (a, b)
