"""Pallas kernel tests: shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; the kernels target TPU BlockSpecs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixed
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def bf16(shape, std=0.1):
    return jnp.asarray(RNG.normal(0, std, shape), jnp.bfloat16)


def assert_bits_equal(a, b):
    assert jnp.array_equal(jax.lax.bitcast_convert_type(a, jnp.uint16),
                           jax.lax.bitcast_convert_type(b, jnp.uint16))


class TestHistogramKernel:
    @pytest.mark.parametrize("shape", [(4096,), (3, 4096), (2, 5, 4096),
                                       (1000,), (7, 321)])
    def test_matches_ref(self, shape):
        x = bf16(shape)
        assert jnp.array_equal(ops.histogram(x),
                               ref.histogram_ref(x.reshape(1, -1)))

    def test_extreme_values(self):
        x = jnp.asarray([0.0, -0.0, 1e38, -1e-38, 3.14] * 1000,
                        jnp.float32).astype(jnp.bfloat16)
        assert jnp.array_equal(ops.histogram(x),
                               ref.histogram_ref(x.reshape(1, -1)))


class TestPackUnpackKernels:
    @pytest.mark.parametrize("k", [4, 5, 6])
    @pytest.mark.parametrize("shape", [(8192,), (2, 3, 4096), (5000,)])
    def test_roundtrip(self, k, shape):
        x = bf16(shape)
        ct = ops.pack(x, k=k)
        assert_bits_equal(ops.unpack(ct), x)

    @pytest.mark.parametrize("k", [5, 6])
    def test_bit_compatible_with_fixed(self, k):
        """Kernel output is interchangeable with the pure-JAX codec."""
        x = bf16((3, 4096))
        ct_k = ops.pack(x, k=k)
        ct_f = fixed.compress(x, k=k)
        for name in ("signman", "planes", "dict_syms", "esc_pos", "esc_raw"):
            assert jnp.array_equal(getattr(ct_k, name), getattr(ct_f, name)), name
        # cross-decode: kernel-packed -> jnp decode and vice versa
        assert_bits_equal(fixed.decompress(ct_k), x)
        assert_bits_equal(ops.unpack(ct_f), x)

    def test_escapes_patch(self):
        x = np.asarray(bf16(8192), np.float32)
        x[::311] = RNG.uniform(1e28, 1e36, x[::311].shape)
        xj = jnp.asarray(x).astype(jnp.bfloat16)
        ct = ops.pack(xj, k=4)
        assert int(ct.n_escapes) >= 0
        assert_bits_equal(ops.unpack(ct), xj)


class TestDecompressMatmul:
    @pytest.mark.parametrize("mkn", [(128, 256, 512), (256, 128, 256),
                                     (64, 512, 128)])
    def test_matches_ref(self, mkn):
        m, k_, n = mkn
        x = bf16((m, k_), 1.0)
        w = bf16((k_, n), 0.02)
        sm, pl, d, nesc = ops.compress_weight(w)
        assert int(nesc) == 0
        out = ops.matmul_compressed(x, sm, pl, d, bm=64, bk=64, bn=128)
        want = ref.decompress_matmul_ref(x, sm, pl, d, 6)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-6, atol=2e-5)

    def test_bit_exact_single_kblock(self):
        x = bf16((64, 128), 1.0)
        w = bf16((128, 256), 0.05)
        sm, pl, d, _ = ops.compress_weight(w)
        out = ops.matmul_compressed(x, sm, pl, d, bm=64, bk=128, bn=256)
        want = jnp.dot(x, w, preferred_element_type=jnp.float32)
        assert jnp.array_equal(out, want)

    def test_weight_decode_lossless(self):
        w = bf16((128, 512), 0.02)
        sm, pl, d, _ = ops.compress_weight(w)
        ident = jnp.eye(128, dtype=jnp.bfloat16)
        out = ops.matmul_compressed(ident, sm, pl, d, bm=128, bk=128, bn=256)
        assert jnp.array_equal(out.astype(jnp.bfloat16), w)


class TestDecodeAttend:
    """Fused decompress+attend kernel vs the pure-jnp oracle."""

    @pytest.mark.parametrize("cfg", [(2, 4, 2, 16, 3, 32),
                                     (1, 5, 1, 16, 2, 32),
                                     (2, 8, 4, 32, 2, 64)])
    def test_matches_ref(self, cfg):
        b, h, hkv, hd, nblk, blk = cfg
        from repro.core import fixed
        from repro.kernels.decode_attend import decode_attend
        w = 2 * hkv * hd
        g = max(h // hkv, 1)
        kv_idx = tuple(min(i // g, hkv - 1) for i in range(h))
        scale = hd ** -0.5
        blocks = bf16((nblk, b, blk, w), 0.5)
        valid = jnp.asarray(RNG.random((nblk, blk)) > 0.2)
        valid = valid.at[0, 0].set(True)
        cts = jax.vmap(lambda v: fixed.compress(v, k=5))(blocks)
        assert int(cts.n_escapes.max()) == 0
        q = bf16((b, h, hd), 1.0)
        out, m, l = decode_attend(
            q, cts.signman.reshape(nblk, b, blk, w), cts.planes,
            cts.dict_syms, jnp.broadcast_to(valid[:, None], (nblk, b, blk)),
            k=5, hkv=hkv, hd=hd, kv_idx=kv_idx, scale=scale)
        ro, rm, rl = ref.decode_attend_ref(
            q, blocks, jnp.broadcast_to(valid[:, None], (nblk, b, blk)),
            kv_idx, scale)
        np.testing.assert_allclose(np.asarray(m), np.asarray(rm), atol=1e-5)
        np.testing.assert_allclose(np.asarray(l), np.asarray(rl),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ro),
                                   rtol=1e-4, atol=1e-4)
