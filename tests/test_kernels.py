"""Pallas kernel tests: shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; the kernels target TPU BlockSpecs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixed
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def bf16(shape, std=0.1):
    return jnp.asarray(RNG.normal(0, std, shape), jnp.bfloat16)


def narrow_bf16(shape, n_exp=8):
    """bf16 values spanning exactly ``n_exp`` exponents (deterministic) —
    packs escape-free even at k=4 (15-symbol dictionary)."""
    rng = np.random.default_rng(7)
    mag = 2.0 ** rng.integers(-n_exp, 0, shape).astype(np.float64)
    mant = 1.0 + rng.integers(0, 128, shape) / 128.0
    sgn = rng.choice([-1.0, 1.0], shape)
    return jnp.asarray(sgn * mag * mant, jnp.bfloat16)


def assert_bits_equal(a, b):
    assert jnp.array_equal(jax.lax.bitcast_convert_type(a, jnp.uint16),
                           jax.lax.bitcast_convert_type(b, jnp.uint16))


class TestHistogramKernel:
    @pytest.mark.parametrize("shape", [(4096,), (3, 4096), (2, 5, 4096),
                                       (1000,), (7, 321)])
    def test_matches_ref(self, shape):
        x = bf16(shape)
        assert jnp.array_equal(ops.histogram(x),
                               ref.histogram_ref(x.reshape(1, -1)))

    def test_extreme_values(self):
        x = jnp.asarray([0.0, -0.0, 1e38, -1e-38, 3.14] * 1000,
                        jnp.float32).astype(jnp.bfloat16)
        assert jnp.array_equal(ops.histogram(x),
                               ref.histogram_ref(x.reshape(1, -1)))


class TestPackUnpackKernels:
    @pytest.mark.parametrize("k", [4, 5, 6])
    @pytest.mark.parametrize("shape", [(8192,), (2, 3, 4096), (5000,)])
    def test_roundtrip(self, k, shape):
        x = bf16(shape)
        ct = ops.pack(x, k=k)
        assert_bits_equal(ops.unpack(ct), x)

    @pytest.mark.parametrize("k", [5, 6])
    def test_bit_compatible_with_fixed(self, k):
        """Kernel output is interchangeable with the pure-JAX codec."""
        x = bf16((3, 4096))
        ct_k = ops.pack(x, k=k)
        ct_f = fixed.compress(x, k=k)
        for name in ("signman", "planes", "dict_syms", "esc_pos", "esc_raw"):
            assert jnp.array_equal(getattr(ct_k, name), getattr(ct_f, name)), name
        # cross-decode: kernel-packed -> jnp decode and vice versa
        assert_bits_equal(fixed.decompress(ct_k), x)
        assert_bits_equal(ops.unpack(ct_f), x)

    def test_escapes_patch(self):
        x = np.asarray(bf16(8192), np.float32)
        x[::311] = RNG.uniform(1e28, 1e36, x[::311].shape)
        xj = jnp.asarray(x).astype(jnp.bfloat16)
        ct = ops.pack(xj, k=4)
        assert int(ct.n_escapes) >= 0
        assert_bits_equal(ops.unpack(ct), xj)


class TestDecompressMatmul:
    @pytest.mark.parametrize("mkn", [(128, 256, 512), (256, 128, 256),
                                     (64, 512, 128)])
    def test_matches_ref(self, mkn):
        m, k_, n = mkn
        x = bf16((m, k_), 1.0)
        w = bf16((k_, n), 0.02)
        sm, pl, d, nesc = ops.compress_weight(w)
        assert int(nesc) == 0
        out = ops.matmul_compressed(x, sm, pl, d, bm=64, bk=64, bn=128)
        want = ref.decompress_matmul_ref(x, sm, pl, d, 6)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-6, atol=2e-5)

    def test_bit_exact_single_kblock(self):
        x = bf16((64, 128), 1.0)
        w = bf16((128, 256), 0.05)
        sm, pl, d, _ = ops.compress_weight(w)
        out = ops.matmul_compressed(x, sm, pl, d, bm=64, bk=128, bn=256)
        want = jnp.dot(x, w, preferred_element_type=jnp.float32)
        assert jnp.array_equal(out, want)

    def test_weight_decode_lossless(self):
        w = bf16((128, 512), 0.02)
        sm, pl, d, _ = ops.compress_weight(w)
        ident = jnp.eye(128, dtype=jnp.bfloat16)
        out = ops.matmul_compressed(ident, sm, pl, d, bm=128, bk=128, bn=256)
        assert jnp.array_equal(out.astype(jnp.bfloat16), w)

    @pytest.mark.parametrize("k", [4, 5, 6])
    def test_k_sweep(self, k):
        """Small dictionaries: weights with few distinct exponents pack at
        k=4 without escapes and the kernel must track the ref bit-for-bit
        (single k-block -> one jnp.dot on both sides)."""
        x = bf16((16, 128), 1.0)
        w = narrow_bf16((128, 256))
        sm, pl, d, nesc = ops.compress_weight(w, k=k)
        assert int(nesc) == 0
        out = ops.matmul_compressed(x, sm, pl, d, k=k, bm=64, bk=128, bn=256)
        want = ref.decompress_matmul_ref(x, sm, pl, d, k)
        assert jnp.array_equal(out, want)

    @pytest.mark.parametrize("mkn", [(1, 128, 256),   # M=1 decode row
                                     (5, 100, 96),    # ragged M and K
                                     (33, 70, 64)])
    def test_nonmultiple_shapes(self, mkn):
        """Serving shapes don't align to kernel tiles: the wrapper pads M/K/N
        up to block multiples and slices the result (N still %32 — the packed
        layout's lane width)."""
        m, k_, n = mkn
        x = bf16((m, k_), 1.0)
        w = bf16((k_, n), 0.02)
        sm, pl, d, nesc = ops.compress_weight(w)
        assert int(nesc) == 0
        out = ops.matmul_compressed(x, sm, pl, d)
        assert out.shape == (m, n)
        want = ref.decompress_matmul_ref(x, sm, pl, d, 6)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-6, atol=2e-5)

    @pytest.mark.parametrize("tp", [1, 2])
    def test_inside_shard_map(self, tp):
        """Tensor-parallel serving slices packed weights along N (signman
        and planes shard on the model axis, the dictionary replicates)."""
        from jax.sharding import PartitionSpec as P
        from repro.core import collectives as cl
        x = bf16((4, 128), 1.0)
        w = narrow_bf16((128, 64 * tp))
        sm, pl, d, nesc = ops.compress_weight(w)
        assert int(nesc) == 0
        mesh = jax.make_mesh((tp,), ("model",))
        f = lambda x_, sm_, pl_, d_: ops.matmul_compressed(x_, sm_, pl_, d_)
        fj = jax.jit(cl.shmap(
            f, mesh,
            (P(), P(None, "model"), P(None, None, "model"), P()),
            P(None, "model")))
        out = fj(x, sm, pl, d)
        want = ref.decompress_matmul_ref(x, sm, pl, d, 6)
        assert jnp.array_equal(out, want)


def _normalized(out, l):
    return np.asarray(out) / np.maximum(np.asarray(l)[..., None], 1e-30)


class TestDecodeAttend:
    """Fused decompress+attend kernel (fixed store) vs the pure-jnp oracle.

    The kernel computes masks in-kernel from (length, ti, window) and fuses
    the raw ring as its final grid step, so the oracle receives the same
    scalars and the comparison covers the whole decode-attention semantics.
    """

    @pytest.mark.parametrize("cfg", [(2, 4, 2, 16, 3, 32),
                                     (1, 5, 1, 16, 2, 32),
                                     (2, 8, 4, 32, 2, 64)])
    @pytest.mark.parametrize("codec_on", [True, False], ids=["codec", "raw"])
    def test_matches_ref(self, cfg, codec_on):
        b, h, hkv, hd, nblk, blk = cfg
        from repro.core import fixed
        from repro.kernels.decode_attend import WINDOW_NONE, decode_attend
        w = 2 * hkv * hd
        g = max(h // hkv, 1)
        kv_idx = tuple(min(i // g, hkv - 1) for i in range(h))
        scale = hd ** -0.5
        blocks = bf16((nblk, b, blk, w), 0.5)
        ring = bf16((b, blk, w), 0.5)
        length = (nblk - 1) * blk + blk // 2   # nblk-1 full blocks + ring
        q = bf16((b, h, hd), 1.0)
        if codec_on:
            cts = jax.vmap(lambda v: fixed.compress(v, k=5))(blocks)
            args = (q, cts.signman.reshape(nblk, -1), cts.planes,
                    cts.dict_syms, cts.esc_raw, None, ring)
        else:
            args = (q, None, None, None, None, blocks, ring)
        out, m, l = decode_attend(*args, length, 0, WINDOW_NONE, k=5,
                                  hkv=hkv, hd=hd, kv_idx=kv_idx, scale=scale,
                                  tp=1, interpret=True)
        want = ref.decode_attend_ref(q, blocks, ring, length, kv_idx=kv_idx,
                                     scale=scale)
        np.testing.assert_allclose(_normalized(out, l), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_windowed_and_sharded_positions(self):
        b, h, hkv, hd, nblk, blk = 2, 4, 2, 16, 3, 8
        from repro.core import fixed
        from repro.kernels.decode_attend import decode_attend
        w = 2 * hkv * hd
        kv_idx = (0, 0, 1, 1)
        scale = hd ** -0.5
        blocks = bf16((nblk, b, blk, w), 0.5)
        ring = bf16((b, blk, w), 0.5)
        q = bf16((b, h, hd), 1.0)
        cts = jax.vmap(lambda v: fixed.compress(v, k=5))(blocks)
        for tp, ti, length, window in [(2, 0, 37, 11), (2, 1, 37, 11),
                                       (4, 3, 61, 5)]:
            out, m, l = decode_attend(
                q, cts.signman.reshape(nblk, -1), cts.planes, cts.dict_syms,
                cts.esc_raw, None, ring, length, ti, window, k=5, hkv=hkv,
                hd=hd, kv_idx=kv_idx, scale=scale, tp=tp, interpret=True)
            want = ref.decode_attend_ref(q, blocks, ring, length,
                                         kv_idx=kv_idx, scale=scale,
                                         window=window, tp=tp, ti=ti)
            np.testing.assert_allclose(_normalized(out, l), np.asarray(want),
                                       rtol=1e-4, atol=1e-4, err_msg=(tp, ti))

    def test_escapes_patched_in_kernel(self):
        """Values outside the k=4 dictionary recover via the side channel."""
        b, h, hkv, hd, nblk, blk = 1, 4, 2, 16, 2, 8
        from repro.core import fixed
        from repro.kernels.decode_attend import WINDOW_NONE, decode_attend
        w = 2 * hkv * hd
        x = np.asarray(bf16((nblk, b, blk, w), 0.5), np.float32)
        # deterministic block 0: 15 frequent exponents fill the k=4
        # dictionary exactly, then 4 rare huge values MUST take the escape
        # side channel (capacity max(n/128, 8) = 8 here) — guaranteed
        # 0 < n_escapes <= capacity regardless of RNG state
        base = np.float32(2.0) ** ((np.arange(blk * w) % 15) - 10)
        base[-4:] = np.float32(2.0) ** np.asarray([40, 45, 50, 55])
        x[0] = base.reshape(b, blk, w)
        blocks = jnp.asarray(x).astype(jnp.bfloat16)
        ring = bf16((b, blk, w), 0.5)
        q = bf16((b, h, hd), 1.0)
        cts = jax.vmap(lambda v: fixed.compress(v, k=4))(blocks)
        assert int(cts.n_escapes.max()) > 0
        out, m, l = decode_attend(
            q, cts.signman.reshape(nblk, -1), cts.planes, cts.dict_syms,
            cts.esc_raw, None, ring, nblk * blk + 2, 0, WINDOW_NONE, k=4,
            hkv=hkv, hd=hd, kv_idx=(0, 0, 1, 1), scale=hd ** -0.5, tp=1,
            interpret=True)
        want = ref.decode_attend_ref(q, blocks, ring, nblk * blk + 2,
                                     kv_idx=(0, 0, 1, 1), scale=hd ** -0.5)
        np.testing.assert_allclose(_normalized(out, l), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestDecodeAttendPaged:
    """Page-table kernel vs the pure-jnp oracle: per-slot lengths, unmapped
    pages, GQA vs MQA, windowed/full, codec on/off, MLA."""

    @pytest.mark.parametrize("heads", [(4, 2), (5, 1), (8, 8)],
                             ids=["gqa", "mqa", "mha"])
    @pytest.mark.parametrize("codec_on", [True, False], ids=["codec", "raw"])
    @pytest.mark.parametrize("window", [None, 9], ids=["full", "windowed"])
    def test_matches_ref(self, heads, codec_on, window):
        h, hkv = heads
        hd, blk, n_s, maxp, n_pages = 16, 8, 3, 3, 9
        from repro.core import fixed
        from repro.kernels.decode_attend import (WINDOW_NONE,
                                                 decode_attend_paged)
        w = 2 * hkv * hd
        g = max(h // hkv, 1)
        kv_idx = tuple(min(i // g, hkv - 1) for i in range(h))
        scale = hd ** -0.5
        tp, ti = 2, 1
        pages = bf16((n_pages, blk, w), 0.5)
        ring = bf16((n_s, blk, w), 0.5)
        pt = jnp.asarray(RNG.integers(0, n_pages, (n_s, maxp)), jnp.int32)
        pt = pt.at[1, 1:].set(-1)                # short slot: unmapped tail
        lengths = jnp.asarray([2 * blk * tp + 3, 2, maxp * blk * tp],
                              jnp.int32)
        q = bf16((n_s, h, hd), 1.0)
        if codec_on:
            cts = jax.vmap(lambda v: fixed.compress(v, k=5))(pages)
            args = (q, cts.signman, cts.planes, cts.dict_syms, cts.esc_raw,
                    None, ring)
        else:
            args = (q, None, None, None, None, pages, ring)
        win = WINDOW_NONE if window is None else window
        out, m, l = decode_attend_paged(
            *args, jnp.clip(pt, 0, None), lengths, ti, win, k=5, hkv=hkv,
            hd=hd, kv_idx=kv_idx, scale=scale, tp=tp, interpret=True)
        want = ref.paged_decode_attend_ref(
            q, pages, pt, lengths, ring, kv_idx=kv_idx, scale=scale,
            window=win, tp=tp, ti=ti)
        np.testing.assert_allclose(_normalized(out, l), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_mla_latent_payload(self):
        lora, rope, h, blk, n_s, maxp, n_pages = 24, 8, 4, 8, 2, 2, 5
        from repro.core import fixed
        from repro.kernels.decode_attend import (WINDOW_NONE,
                                                 decode_attend_paged)
        w = lora + rope
        pages = bf16((n_pages, blk, w), 0.5)
        ring = bf16((n_s, blk, w), 0.5)
        pt = jnp.asarray(RNG.integers(0, n_pages, (n_s, maxp)), jnp.int32)
        lengths = jnp.asarray([blk + 3, 2 * blk], jnp.int32)
        q = bf16((n_s, h, w), 1.0)
        cts = jax.vmap(lambda v: fixed.compress(v, k=5))(pages)
        out, m, l = decode_attend_paged(
            q, cts.signman, cts.planes, cts.dict_syms, cts.esc_raw, None,
            ring, jnp.clip(pt, 0, None), lengths, 0, WINDOW_NONE, k=5,
            hkv=1, hd=w, kv_idx=(), scale=w ** -0.5, mla_lora=lora, tp=1,
            interpret=True)
        want = ref.paged_decode_attend_ref(
            q, pages, pt, lengths, ring, kv_idx=(), scale=w ** -0.5,
            mla_lora=lora, tp=1, ti=0)
        assert out.shape == (n_s, h, lora)
        np.testing.assert_allclose(_normalized(out, l), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
