"""Disaggregated prefill tests: prefill replicas feeding decode replicas
through compressed page transfer must serve token streams byte-identical to
the monolithic engine (dense / hybrid / MoE x codec on/off x jax/interpret
backends), the export→import round trip must be bit-exact on the compressed
planes, imports must work against a permuted free list and fail loudly on
an oversubscribed pool, and the transport must meter (and dedup) wire
bytes correctly."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, RunConfig, SSMConfig
from repro.core.collectives import CodecConfig
from repro.models import cache as cache_mod
from repro.serve import (DisaggEngine, LoopbackTransport, Request,
                         SequenceBlob, ServeEngine)
from repro.serve.disagg import DecodeReplica, Handoff, PrefillReplica

RNG = np.random.default_rng(7)

TP = 2
MAXLEN = 64

CASES = {
    "dense": ModelConfig(name="t2", family="dense", n_layers=2, d_model=64,
                         n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=500,
                         head_dim=16),
    "hybrid": ModelConfig(
        name="h", family="hybrid", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=500, head_dim=16,
        parallel_hybrid=True, attn_layout="hymba_3global", window=16,
        ssm=SSMConfig(d_state=16, headdim=8, chunk=16), sub_quadratic=True),
    "moe": ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=500,
                       head_dim=16,
                       moe=MoEConfig(n_experts=8, top_k=2, d_ff=32,
                                     n_shared=1, capacity_factor=4.0)),
}


def _run_cfg(codec_on: bool, backend: str = "jax") -> RunConfig:
    codec = (CodecConfig(cache_block=4) if codec_on
             else dataclasses.replace(CodecConfig.off(), cache_block=4))
    return RunConfig(codec=dataclasses.replace(codec,
                                               decode_backend=backend))


def _requests():
    """Mixed lengths (incl. unaligned), shared prefixes, a budget-1
    request that must finish ON the prefill replica, more requests than
    decode slots."""
    a = RNG.integers(0, 500, (16,)).astype(np.int32)
    specs = [(a, 5), (RNG.integers(0, 500, (9,)).astype(np.int32), 3),
             (a.copy(), 4), (RNG.integers(0, 500, (12,)).astype(np.int32), 1),
             (a.copy(), 6)]
    return [Request(uid=i, prompt=p, max_new_tokens=n)
            for i, (p, n) in enumerate(specs)]


@pytest.mark.parametrize("codec_on", [True, False], ids=["codec", "raw"])
@pytest.mark.parametrize("case", sorted(CASES))
def test_disagg_matches_monolithic(case, codec_on):
    """The acceptance bar: decode-replica token streams are byte-identical
    to the monolithic ServeEngine across dense/hybrid/MoE x codec on/off
    (hybrids prove the SSM-state slots survive the wire)."""
    cfg = CASES[case]
    run = _run_cfg(codec_on)
    reqs = _requests()
    mono = ServeEngine(cfg, run, tp=TP, n_slots=2, max_len=MAXLEN, seed=1)
    res_m, _ = mono.run(reqs)
    dis = DisaggEngine(cfg, run, tp=TP, n_prefill=1, n_decode=1, n_slots=2,
                       max_len=MAXLEN, seed=1)
    res_d, st = dis.run(reqs)
    for x, y in zip(res_m, res_d):
        assert x.tokens == y.tokens, (case, codec_on, x.uid)
        assert x.stop_reason == y.stop_reason
    # the budget-1 request finished at admission: no transfer for it
    assert st.n_transfers == len(reqs) - 1
    assert st.wire_bytes > 0 and st.wire_raw_bytes > 0
    # every decode pool drains once its retained (hot-tier) columns drop
    for dr in dis.decodes:
        if dr.engine.state.kv is not None:
            dr.engine.drop_cache()
            assert dr.engine._pages_in_use() == 0


@pytest.mark.parametrize("case", ["dense", "hybrid"])
def test_disagg_streaming_matches_monolithic(case):
    """Streaming prefill export (full pages cross the link as admission
    fills them; the closing blob references them by digest) changes the
    wire SCHEDULE, never the bytes that land: token streams stay
    byte-identical to the monolithic engine, and the transport actually
    streamed pages ahead of the tails."""
    cfg = CASES[case]
    run = _run_cfg(True)
    reqs = _requests()
    mono = ServeEngine(cfg, run, tp=TP, n_slots=2, max_len=MAXLEN, seed=1)
    res_m, _ = mono.run(reqs)
    dis = DisaggEngine(cfg, run, tp=TP, n_prefill=1, n_decode=1, n_slots=2,
                       max_len=MAXLEN, seed=1, streaming=True)
    res_d, st = dis.run(reqs)
    for x, y in zip(res_m, res_d):
        assert x.tokens == y.tokens, (case, x.uid)
        assert x.stop_reason == y.stop_reason
    assert st.pages_streamed > 0
    assert st.stream_chunk_bytes > 0
    # streamed pages arrive as tag-1 refs in the closing blob
    assert st.dedup_page_refs >= st.pages_streamed
    for dr in dis.decodes:
        dr.engine.drop_cache()
        assert dr.engine._pages_in_use() == 0


def test_decode_prefix_reuse_across_imports():
    """Imported sequences register their full page columns in the decode
    replica's prefix index, so a duplicate prompt imported while the first
    is still resident maps the SAME pool pages (pure attention only) —
    streams unchanged, fewer pages resident, pool still drains to zero."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    # duplicates with staggered budgets so residency overlaps on the
    # decode replica; a third copy arrives after the first released
    a = RNG.integers(0, 500, (16,)).astype(np.int32)
    reqs = [Request(uid=0, prompt=a, max_new_tokens=8),
            Request(uid=1, prompt=a.copy(), max_new_tokens=4),
            Request(uid=2, prompt=a.copy(), max_new_tokens=3)]
    mono = ServeEngine(cfg, run, tp=TP, n_slots=2, max_len=MAXLEN, seed=1)
    res_m, _ = mono.run(reqs)
    dis = DisaggEngine(cfg, run, tp=TP, n_prefill=1, n_decode=1, n_slots=2,
                       max_len=MAXLEN, seed=1)
    res_d, st = dis.run(reqs)
    for x, y in zip(res_m, res_d):
        assert x.tokens == y.tokens, x.uid
    assert st.decode_prefix_hits > 0
    assert st.cache_hot_hits >= 0         # counters surfaced per replica
    dec = dis.decodes[0].engine
    assert dec.cache.retained() > 0       # released columns stay hot
    dec.drop_cache()
    assert dec._pages_in_use() == 0
    assert not dec._prefix_index          # drop deindexes everything
    # hybrids share too: the blob carries the SSM state, so an imported
    # duplicate maps the resident KV columns AND restores recurrence
    dis_h = DisaggEngine(CASES["hybrid"], run, tp=TP, n_prefill=1,
                         n_decode=1, n_slots=2, max_len=MAXLEN, seed=1)
    reqs_h = [Request(uid=i, prompt=a.copy(), max_new_tokens=3 + i)
              for i in range(3)]
    mono_h = ServeEngine(CASES["hybrid"], run, tp=TP, n_slots=2,
                         max_len=MAXLEN, seed=1)
    res_mh, _ = mono_h.run(reqs_h)
    res_dh, st_h = dis_h.run(reqs_h)
    for x, y in zip(res_mh, res_dh):
        assert x.tokens == y.tokens, x.uid
    assert st_h.decode_prefix_hits > 0


def test_disagg_interpret_backend_identity():
    """Imported pages decode identically under the fused-kernel (Pallas
    interpret) backend — the wire format is backend-agnostic."""
    cfg = CASES["dense"]
    reqs = _requests()
    res_j, _ = DisaggEngine(cfg, _run_cfg(True, "jax"), tp=TP, n_prefill=1,
                            n_decode=1, n_slots=2, max_len=MAXLEN,
                            seed=1).run(reqs)
    res_k, st_k = DisaggEngine(cfg, _run_cfg(True, "interpret"), tp=TP,
                               n_prefill=1, n_decode=1, n_slots=2,
                               max_len=MAXLEN, seed=1).run(reqs)
    assert st_k.decode_backend == "interpret"
    for x, y in zip(res_j, res_k):
        assert x.tokens == y.tokens, x.uid


def test_disagg_multi_replica_routing():
    """N=2 prefill -> M=2 decode with per-replica slot accounting: all
    requests complete with the monolithic streams, transfers spread across
    decode replicas, every pool drains."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    reqs = _requests() + [Request(uid=10 + i,
                                  prompt=RNG.integers(0, 500, (8,)
                                                      ).astype(np.int32),
                                  max_new_tokens=3) for i in range(3)]
    mono = ServeEngine(cfg, run, tp=TP, n_slots=2, max_len=MAXLEN, seed=1)
    res_m, _ = mono.run(reqs)
    dis = DisaggEngine(cfg, run, tp=TP, n_prefill=2, n_decode=2, n_slots=2,
                       max_len=MAXLEN, seed=1)
    res_d, st = dis.run(reqs)
    for x, y in zip(res_m, res_d):
        assert x.tokens == y.tokens, x.uid
    assert st.n_prefill_replicas == 2 and st.n_decode_replicas == 2
    assert st.n_transfers == len(reqs) - 1          # one budget-1 request
    used = [len(dr.ls.results) for dr in dis.decodes]
    assert sum(used) == len(reqs) - 1 and all(u > 0 for u in used)
    for dr in dis.decodes:
        dr.engine.drop_cache()
        assert dr.engine._pages_in_use() == 0
        assert not dr.engine._slot_busy.any()


# ---------------------------------------------------------------------------
# export -> import round trip (bit-exactness on the compressed planes)
# ---------------------------------------------------------------------------


def _admit_one(eng: ServeEngine, prompt: np.ndarray) -> PrefillReplica:
    """Drive a prefill replica to admit exactly one request and return it
    with the slot still live (no export)."""
    pr = PrefillReplica(eng)
    pr.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    eng._admit_phase(pr.ls)
    return pr


@pytest.mark.parametrize("codec_on", [True, False], ids=["codec", "raw"])
def test_export_import_roundtrip_bitexact(codec_on):
    """export_sequence -> import_sequence -> export_sequence reproduces the
    wire payload bit-for-bit: compressed planes, dictionaries, escape side
    channels, ring — not just the decoded tokens."""
    cfg = CASES["dense"]
    run = _run_cfg(codec_on)
    prompt = RNG.integers(0, 500, (19,)).astype(np.int32)  # unaligned
    pr = _admit_one(ServeEngine(cfg, run, tp=TP, n_slots=2, max_len=MAXLEN,
                                seed=1), prompt)
    blob = pr._export_blob(0)
    assert blob.length == 19
    assert blob.n_cols == cache_mod.export_n_cols(19, 4, TP)

    dec = DecodeReplica(ServeEngine(cfg, run, tp=TP, n_slots=2,
                                    max_len=MAXLEN, seed=1,
                                    params=pr.engine.params,
                                    prefix_sharing=False))
    req = pr.ls.slot_req[0]
    slot = dec.import_handoff(Handoff(req=req, blob=blob, admit_t=0.0))
    # re-export from the importing pool: page ids differ, bytes must not
    pr2 = PrefillReplica(dec.engine)
    pr2.ls = dec.ls
    blob2 = pr2._export_blob(slot)
    assert blob.kv.keys() == blob2.kv.keys()
    for f in blob.kv:
        np.testing.assert_array_equal(np.asarray(blob.kv[f]),
                                      np.asarray(blob2.kv[f]), err_msg=f)
    assert blob.to_wire(None)[0] == blob2.to_wire(None)[0]


def test_import_into_permuted_free_list():
    """Imports allocate from whatever free-page order the target pool has:
    admit+release to permute the free list, then import and check the
    stream continues exactly as on the source engine."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    reqs = _requests()
    mono = ServeEngine(cfg, run, tp=TP, n_slots=2, max_len=MAXLEN, seed=1)
    res_m, _ = mono.run(reqs)

    dis = DisaggEngine(cfg, run, tp=TP, n_prefill=1, n_decode=1, n_slots=2,
                       max_len=MAXLEN, seed=1)
    dec = dis.decodes[0]
    # churn the decode pool first: run an unrelated stream through it so
    # releases leave the free list permuted (argsort order != arange)
    churn = [Request(uid=100, prompt=RNG.integers(0, 500, (14,)
                                                  ).astype(np.int32),
                     max_new_tokens=6),
             Request(uid=101, prompt=RNG.integers(0, 500, (8,)
                                                  ).astype(np.int32),
                     max_new_tokens=2)]
    pr = PrefillReplica(ServeEngine(cfg, run, tp=TP, n_slots=2,
                                    max_len=MAXLEN, seed=1,
                                    params=dis.params))
    for r in churn:
        pr.submit(r)
    while not pr.idle():
        _, hoffs = pr.admit_step()
        for h in hoffs:
            dec.import_handoff(h)
        while dec.ls.live_slots():
            dec.step_window()
    # drop the churn's retained columns so only the permuted free list
    # (argsort order != arange) survives into the real run
    dec.engine.drop_cache()
    assert dec.engine._pages_in_use() == 0

    res_d, _ = dis.run(reqs)
    for x, y in zip(res_m, res_d):
        assert x.tokens == y.tokens, x.uid


def test_import_oversubscription_fails_loudly():
    """An import the pool cannot hold is rejected host-side BEFORE any
    device dispatch — the pool is not corrupted."""
    cfg = CASES["dense"]
    run = _run_cfg(True)
    prompt = RNG.integers(0, 500, (16,)).astype(np.int32)
    pr = _admit_one(ServeEngine(cfg, run, tp=TP, n_slots=2, max_len=MAXLEN,
                                seed=1), prompt)
    blob = pr._export_blob(0)
    req = pr.ls.slot_req[0]

    dec = DecodeReplica(ServeEngine(cfg, run, tp=TP, n_slots=2,
                                    max_len=MAXLEN, seed=1,
                                    params=pr.engine.params,
                                    prefix_sharing=False))
    # artificially exhaust the pool (mark every page used on one layer)
    kv = dec.engine.state.kv
    full = jnp.ones_like(kv.page_used)
    dec.engine.state = dec.engine.state._replace(
        kv=kv._replace(page_used=full))
    before = int(np.asarray(dec.engine.state.kv.page_used).sum())
    with pytest.raises(RuntimeError, match="oversubscribed"):
        dec.import_handoff(Handoff(req=req, blob=blob, admit_t=0.0))
    assert int(np.asarray(dec.engine.state.kv.page_used).sum()) == before
    assert dec.ls.slot_req == [None, None]          # nothing half-admitted

    # a sequence longer than the replica's page-table rows is a geometry
    # error, also pre-dispatch (length 40 -> 5 columns/shard; max_len 8
    # gives rows of 3)
    long_prompt = RNG.integers(0, 500, (40,)).astype(np.int32)
    pr2 = _admit_one(ServeEngine(cfg, run, tp=TP, n_slots=2,
                                 max_len=MAXLEN, seed=1,
                                 params=pr.engine.params), long_prompt)
    long_blob = pr2._export_blob(0)
    small = DecodeReplica(ServeEngine(cfg, run, tp=TP, n_slots=2,
                                      max_len=8, seed=1,
                                      params=pr.engine.params,
                                      prefix_sharing=False))
    with pytest.raises(ValueError, match="page columns"):
        small.import_handoff(Handoff(req=pr2.ls.slot_req[0],
                                     blob=long_blob, admit_t=0.0))

    # occupied slots are not importable either
    dec2 = DecodeReplica(ServeEngine(cfg, run, tp=TP, n_slots=1,
                                     max_len=MAXLEN, seed=1,
                                     params=pr.engine.params,
                                     prefix_sharing=False))
    dec2.import_handoff(Handoff(req=req, blob=blob, admit_t=0.0))
    other = Request(uid=1, prompt=prompt, max_new_tokens=2)
    with pytest.raises(RuntimeError, match="no free decode slot"):
        dec2.import_handoff(Handoff(req=other, blob=blob, admit_t=0.0))


# ---------------------------------------------------------------------------
# wire format + transport
# ---------------------------------------------------------------------------


def _blob_for_tests(codec_on=True):
    cfg = CASES["hybrid"]          # exercises the SSM section too
    run = _run_cfg(codec_on)
    prompt = RNG.integers(0, 500, (10,)).astype(np.int32)
    pr = _admit_one(ServeEngine(cfg, run, tp=TP, n_slots=2, max_len=MAXLEN,
                                seed=1), prompt)
    return pr._export_blob(0)


def test_wire_serialization_roundtrip():
    """to_wire/from_wire is lossless for every section (pages, ring, SSM
    state, emitted tokens) and rejects foreign/versioned-up blobs."""
    blob = _blob_for_tests()
    data, inline, refs = blob.to_wire(None)
    assert not refs and len(inline) == blob.n_valid_pages
    back = SequenceBlob.from_wire(data)
    assert back.to_wire(None)[0] == data
    assert back.length == blob.length
    assert back.emitted == blob.emitted
    for f in blob.kv:
        np.testing.assert_array_equal(np.asarray(blob.kv[f]),
                                      np.asarray(back.kv[f]), err_msg=f)
    for a, b in zip(blob.ssm, back.ssm):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    with pytest.raises(ValueError, match="magic"):
        SequenceBlob.from_wire(b"XXXX" + data[4:])
    with pytest.raises(ValueError, match="version"):
        SequenceBlob.from_wire(data[:4] + bytes([99]) + data[5:])


def test_transport_dedup_accounting():
    """Repeat transfers of the same content ship page references instead of
    payloads; receivers reconstruct bit-exactly; unknown references fail
    loudly; raw-vs-wire metering adds up."""
    blob = _blob_for_tests()
    tr = LoopbackTransport(dedup=True)
    d1 = tr.send(blob, "decode0")
    b1 = tr.recv(d1, "decode0")
    assert b1.to_wire(None)[0] == blob.to_wire(None)[0]
    d2 = tr.send(blob, "decode0")
    assert len(d2) < len(d1)                  # all pages deduped away
    b2 = tr.recv(d2, "decode0")
    assert b2.to_wire(None)[0] == blob.to_wire(None)[0]
    st = tr.stats
    assert st.n_transfers == 2
    assert st.pages_ref == blob.n_valid_pages
    assert st.pages_inline == blob.n_valid_pages
    assert st.wire_bytes == len(d1) + len(d2)
    assert st.wire_bytes_nodedup == 2 * len(d1)
    assert st.raw_bytes == 2 * blob.raw_bytes
    assert st.model_ns > 0 and st.model_ns_raw > st.model_ns

    # a different destination has its own store: full payloads again
    d3 = tr.send(blob, "decode1")
    assert len(d3) == len(d1)
    # a ref-bearing wire blob against an empty store fails loudly
    fresh = LoopbackTransport(dedup=True)
    with pytest.raises(ValueError, match="unknown page digest"):
        fresh.recv(d2, "decode0")


def test_transport_dedup_off_is_codec_only():
    blob = _blob_for_tests()
    tr = LoopbackTransport(dedup=False)
    d1 = tr.send(blob, "x")
    d2 = tr.send(blob, "x")
    assert len(d1) == len(d2)
    assert tr.stats.pages_ref == 0
    assert tr.stats.wire_bytes == tr.stats.wire_bytes_nodedup
