"""LEXI quickstart: the paper's observation and codec in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec, entropy, fixed
from repro.kernels import ops

rng = np.random.default_rng(0)

# --- 1. the observation (paper §3 / Fig 1) --------------------------------
weights = rng.normal(0, 0.02, 1_000_000).astype(np.float32)
prof = entropy.profile_exponents(weights)
print(f"BF16 exponent entropy : {prof.exp_entropy_bits:.2f} bits  "
      f"(paper: < 3)")
print(f"distinct exponents    : {prof.distinct_exponents}  (paper: < 32)")
print(f"mantissa entropy      : {prof.man_entropy_bits:.2f} bits "
      f"(incompressible)")
print(f"LEXI-H exponent CR    : {prof.exp_cr:.2f}x  (paper: ~3.1x)")
print(f"whole-value CR        : {prof.overall_cr:.2f}x")

# --- 2. Table 2: LEXI vs RLE vs BDI ----------------------------------------
crs = codec.measure_crs(weights)
print(f"\nTable 2 on this tensor: RLE {crs['rle']:.2f}x  "
      f"BDI {crs['bdi']:.2f}x  LEXI {crs['lexi']:.2f}x")

# --- 3. the deployment codec (LEXI-FW): lossless, jit-able -----------------
x = jnp.asarray(rng.normal(0, 1, (256, 1024)), jnp.bfloat16)
ct = fixed.compress(x)
xr = fixed.decompress(ct)
exact = bool(jnp.array_equal(jax.lax.bitcast_convert_type(x, jnp.uint16),
                             jax.lax.bitcast_convert_type(xr, jnp.uint16)))
print(f"\nLEXI-FW roundtrip bit-exact: {exact}; wire ratio "
      f"{ct.ratio():.3f}x; escapes {int(ct.n_escapes)}")

# --- 4. the Pallas kernels (interpret mode on CPU) -------------------------
hist = ops.histogram(x)
print(f"exp_histogram kernel: {int(hist.sum())} values binned "
      f"(== {x.size})")
w = jnp.asarray(rng.normal(0, 0.02, (256, 512)), jnp.bfloat16)
sm, pl, d, nesc = ops.compress_weight(w)
out = ops.matmul_compressed(x[:64, :256], sm, pl, d)
ref = jnp.dot(x[:64, :256], w, preferred_element_type=jnp.float32)
print(f"decompress_matmul max err vs plain matmul: "
      f"{float(jnp.max(jnp.abs(out - ref))):.2e} (K-block accum order only)")

# --- 5. compressed collective ----------------------------------------------
from jax.sharding import PartitionSpec as P
from repro.core import collectives as cl

if jax.device_count() >= 2:
    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("model",))
    xs = jnp.asarray(rng.normal(0, 1, (n * 8, 128)), jnp.bfloat16)
    f = jax.jit(cl.shmap(
        lambda v: cl.compressed_all_gather(v, "model", cl.CodecConfig()),
        mesh, P("model"), P(None)))
    print(f"compressed all_gather on {n} devices: "
          f"{bool(jnp.array_equal(f(xs), xs))} (bit-exact), wire bytes "
          f"~{1 / fixed.wire_ratio():.2f}x of raw")
else:
    print("single device: run with "
          "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
          "to demo compressed collectives")
