"""Batched serving with LEXI on every transport: compressed-at-rest weights,
compressed block KV cache, compressed activation collectives.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/serve_lm.py --arch gemma2-9b --mesh 2x4

With ``--continuous`` a request stream (mixed prompt lengths, more requests
than decode slots) runs through the continuous-batching engine instead,
reporting per-request latency and paged-cache bytes.

Prints per-transport compression accounting alongside throughput.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, make_reduced
from repro.configs.base import MeshConfig, RunConfig
from repro.core import collectives as cl
from repro.core import weights as W
from repro.core.collectives import CodecConfig
from repro.core.fixed import wire_ratio
from repro.launch.mesh import make_mesh_from_config
from repro.models import cache as cache_mod
from repro.models import lm, params as PM
from repro.serve import engine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--mesh", default="2x4")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--continuous", action="store_true",
                    help="run a request stream through the "
                         "continuous-batching engine")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable prefix-cache page sharing (continuous)")
    ap.add_argument("--disagg", action="store_true",
                    help="continuous mode through disaggregated prefill->"
                         "decode replicas over compressed page transfer")
    args = ap.parse_args()

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh_cfg = MeshConfig(data=d, model=m, pod=1)
    run = RunConfig(codec=CodecConfig(cache_block=32))
    cfg = make_reduced(get_config(args.arch), tp=m)
    tp = m
    B, S, N = args.batch, args.prompt_len, args.new_tokens
    rng = np.random.default_rng(0)

    if args.continuous or args.disagg:
        # the engine owns its own 1xTP mesh and params — skip the
        # fixed-path setup entirely
        from repro.serve import ServeEngine
        from repro.serve.scheduler import demo_serving_setup, format_stats
        run, max_len, reqs = demo_serving_setup(
            run, cfg.vocab_size, tp, S, N, args.requests)
        if args.disagg:
            from repro.serve.disagg import DisaggEngine, format_disagg_stats
            eng = DisaggEngine(cfg, run, tp=tp, n_prefill=1, n_decode=1,
                               n_slots=args.slots, max_len=max_len, seed=0)
            results, st = eng.run(reqs)
            print("[serve] disagg:", format_disagg_stats(st))
        else:
            eng = ServeEngine(cfg, run, tp=tp, n_slots=args.slots,
                              max_len=max_len, params=None, seed=0,
                              prefix_sharing=not args.no_prefix_sharing)
            results, st = eng.run(reqs)
            print("[serve] continuous:", format_stats(st))
        print("[serve] continuations[0][:10] =", results[0].tokens[:10])
        return 0

    mesh = make_mesh_from_config(mesh_cfg)
    table = lm.lm_table(cfg, mesh_cfg, run)
    dims = lm.lm_fsdp_dims(table)
    params = PM.init_params(table, jax.random.key(0))
    pspecs = PM.param_pspecs(table)

    # --- compression accounting ---------------------------------------
    cp = W.compress_params(params, run.codec)
    raw = W.param_bytes(params)
    stored = W.stored_bytes(cp)
    kvw = cache_mod.kv_width(cfg)
    cache_raw = B * (S + N) * kvw * 2 * cfg.n_layers
    print(f"[serve] weights at rest : {raw / 1e6:.1f} MB -> "
          f"{stored / 1e6:.1f} MB ({raw / stored:.2f}x, LEXI-FW)")
    print(f"[serve] KV cache (raw {cache_raw / 1e6:.2f} MB) stored packed "
          f"at ~{wire_ratio(run.codec.k):.2f}x")
    print(f"[serve] ICI activations packed at ~{wire_ratio(run.codec.k):.2f}x "
          f"on every all_gather/all_to_all")

    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    def serve(pp, toks):
        logits, st = engine.prefill(cfg, run, pp, dims, toks,
                                    S + N + run.codec.cache_block, tp)
        tok = engine.greedy_token(cfg, logits, tp)
        outs = [tok]
        for _ in range(N):
            logits, st = engine.decode_step(cfg, run, pp, dims, st, tok, tp)
            tok = engine.greedy_token(cfg, logits, tp)
            outs.append(tok)
        return jnp.concatenate(outs, axis=1)

    f = jax.jit(cl.shmap(serve, mesh, (pspecs, P("data")), P("data")))
    out = np.asarray(f(params, prompts))          # compile + run
    t0 = time.time()
    out = np.asarray(f(params, prompts))
    dt = time.time() - t0
    print(f"[serve] {B} x ({S}+{N}) tokens, steady state "
          f"{B * N / dt:.1f} tok/s on CPU-interpret")
    print(f"[serve] continuations[0][:10] = {out[0, :10].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
