"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the full LEXI stack (compressed FSDP weight gathers, compressed
gradient sync, compressed checkpoints) and fault-tolerant checkpointing.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/train_lm.py --steps 300 --mesh 2x4

The model is a qwen-style dense transformer sized to ~100M params
(d=512, 12L, vocab 32k).  On one CPU this takes ~1s/step at seq 256.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import MeshConfig, ModelConfig, RunConfig, ShapeConfig
from repro.core.collectives import CodecConfig
from repro.launch.train import train_loop
from repro.train import fault

CFG_100M = ModelConfig(
    name="lexi-100m", family="dense", n_layers=12, d_model=512,
    n_heads=8, n_kv_heads=4, d_ff=1536, vocab_size=32_768, head_dim=64,
    qk_norm=True,
)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--mesh", default="2x4")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/lexi_100m_ckpt")
    ap.add_argument("--codec", default="full", choices=["full", "off"])
    args = ap.parse_args()

    d, m = (int(x) for x in args.mesh.split("x"))
    n = CFG_100M.param_count()
    print(f"[example] model: {n / 1e6:.0f}M params, mesh {d}x{m}, "
          f"codec={args.codec}")
    run = RunConfig(
        codec=CodecConfig() if args.codec == "full" else CodecConfig.off(),
        warmup_steps=max(args.steps // 10, 10), lr=6e-4)
    shape = ShapeConfig("example", args.seq, args.batch, "train")

    def once():
        return train_loop(CFG_100M, shape, MeshConfig(d, m, 1), run,
                          steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=max(args.steps // 5, 1), resume=True)

    out = fault.run_with_restarts(once, max_restarts=2)
    print(f"[example] loss {out['first_loss']:.3f} -> "
          f"{out['final_loss']:.3f} over {args.steps} steps "
          f"(restarts={out['restarts']}, "
          f"stragglers={len(out['stragglers'])})")
    return 0 if out["final_loss"] < out["first_loss"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
