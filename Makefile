.PHONY: verify bench-serving

verify:            ## tier-1 test suite (same command everywhere)
	./scripts/verify.sh

bench-serving:     ## serving + decode-kernel benchmarks (writes BENCH_*.json)
	PYTHONPATH=src python -m benchmarks.run --only serving,decode_kernel
