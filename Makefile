.PHONY: verify bench-serving

verify:            ## tier-1 test suite (same command everywhere)
	./scripts/verify.sh

bench-serving:     ## continuous-batching serving benchmark (codec on/off)
	PYTHONPATH=src python -m benchmarks.run --only serving
