#!/usr/bin/env python3
"""Summarize / validate a serving trace written by ``--trace-out``.

The input is the Chrome trace-event JSON produced by
``repro.serve.telemetry.Tracer.to_chrome_trace`` (``src/repro/serve/
telemetry.py``): ``{"traceEvents": [...]}`` where request-lifecycle spans
are complete ("ph": "X") events with microsecond ``ts``/``dur`` and the
request uid repeated in ``args`` — see docs/ARCHITECTURE.md §9 for the
span taxonomy.  Stdlib only (CI runs it on a bare python3).

    python3 scripts/trace_summary.py serving-trace.json          # table
    python3 scripts/trace_summary.py serving-trace.json --check  # lint

Validation (both modes; ``--check`` prints nothing else and exits 1 on
the first problem class found):

  * top level is an object with a ``traceEvents`` list; every "X" event
    carries numeric ts/dur >= 0, integer pid/tid and an args dict;
  * every traced uid closes exactly ONE root ``request`` span;
  * every request-lane span (same args.uid) nests inside its root;
  * same-name spans on one request lane never overlap (stages are
    sequential by construction);
  * every root has at least one child span (a request that produced no
    admission/stage events never really ran).

The summary table reports per-span-name count / total / p50 / p95, the
byte flow per tier (wire + streamed chunks, cache spill/fetch, weight
HBM), and TTFT per request (end of the replay-else-admit stage minus
root start — matching the engine's host-clock TTFT).
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, List

EPS_US = 1e-3      # ns -> µs float conversion slack


def percentile(values: List[float], q: float) -> float:
    """Linear-interpolation percentile (numpy default) on a sample."""
    if not values:
        return 0.0
    v = sorted(values)
    if len(v) == 1:
        return v[0]
    pos = (len(v) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(v) - 1)
    return v[lo] + (v[hi] - v[lo]) * (pos - lo)


def load_events(path: str, errors: List[str]) -> List[Dict[str, Any]]:
    try:
        with open(path) as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        errors.append(f"cannot load {path}: {e}")
        return []
    if not isinstance(trace, dict) or \
            not isinstance(trace.get("traceEvents"), list):
        errors.append("top level must be an object with a "
                      "'traceEvents' list")
        return []
    spans = []
    for i, ev in enumerate(trace["traceEvents"]):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            errors.append(f"event {i} is not a dict with ph/name")
            continue
        if ev["ph"] != "X":
            continue                      # "M" metadata rows
        ok = (isinstance(ev.get("ts"), (int, float))
              and isinstance(ev.get("dur"), (int, float))
              and ev["dur"] >= 0
              and isinstance(ev.get("pid"), int)
              and isinstance(ev.get("tid"), int)
              and isinstance(ev.get("args"), dict))
        if not ok:
            errors.append(f"X event {i} ({ev.get('name')}) lacks valid "
                          "ts/dur/pid/tid/args")
            continue
        spans.append(ev)
    return spans


def validate(spans: List[Dict[str, Any]], errors: List[str]) -> None:
    roots: Dict[Any, Dict[str, Any]] = {}
    children: Dict[Any, List[Dict[str, Any]]] = defaultdict(list)
    for ev in spans:
        uid = ev["args"].get("uid")
        if uid is None:
            continue                      # engine-lane span
        if ev["name"] == "request":
            if uid in roots:
                errors.append(f"uid {uid} closed more than one root "
                              "'request' span")
            roots[uid] = ev
        else:
            children[uid].append(ev)
    if not roots:
        errors.append("trace holds no root 'request' spans")
    for uid, kids in children.items():
        root = roots.get(uid)
        if root is None:
            errors.append(f"uid {uid} has spans but no root "
                          "'request' span")
            continue
        r0, r1 = root["ts"], root["ts"] + root["dur"]
        for ev in kids:
            t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
            if t0 < r0 - EPS_US or t1 > r1 + EPS_US:
                errors.append(
                    f"uid {uid}: span '{ev['name']}' "
                    f"[{t0:.1f}, {t1:.1f}]us escapes its root "
                    f"[{r0:.1f}, {r1:.1f}]us")
        by_name: Dict[str, List] = defaultdict(list)
        for ev in kids:
            by_name[ev["name"]].append((ev["ts"], ev["ts"] + ev["dur"]))
        for name, ivs in by_name.items():
            ivs.sort()
            for (a0, a1), (b0, b1) in zip(ivs, ivs[1:]):
                if b0 < a1 - EPS_US:
                    errors.append(
                        f"uid {uid}: '{name}' spans overlap "
                        f"([{a0:.1f}, {a1:.1f}] vs [{b0:.1f}, {b1:.1f}])")
    for uid in roots:
        if not children.get(uid):
            errors.append(f"uid {uid}: root span has no child spans")


def summarize(spans: List[Dict[str, Any]]) -> str:
    by_name: Dict[str, List[float]] = defaultdict(list)
    bytes_by = defaultdict(int)
    roots: Dict[Any, Dict[str, Any]] = {}
    stage_end: Dict[Any, Dict[str, float]] = defaultdict(dict)
    for ev in spans:
        by_name[ev["name"]].append(ev["dur"] / 1e3)      # us -> ms
        a = ev["args"]
        if ev["name"] in ("wire", "wire_chunk"):
            bytes_by["wire"] += int(a.get("wire_bytes", 0))
        if ev["name"] == "cache_spill":
            bytes_by["cache_spill"] += int(a.get("bytes", 0))
        if ev["name"] == "cache_fetch":
            bytes_by["cache_fetch"] += int(a.get("bytes", 0))
            bytes_by["cache_remote"] += int(a.get("remote_bytes", 0))
        if ev["name"] == "decode_window":
            bytes_by["weight_hbm"] += int(a.get("weight_bytes", 0))
        uid = a.get("uid")
        if uid is not None:
            if ev["name"] == "request":
                roots[uid] = ev
            elif ev["name"] in ("admit", "replay"):
                stage_end[uid][ev["name"]] = ev["ts"] + ev["dur"]
    lines = [f"{'span':<16}{'count':>7}{'total_ms':>11}"
             f"{'p50_ms':>9}{'p95_ms':>9}"]
    for name in sorted(by_name):
        d = by_name[name]
        lines.append(f"{name:<16}{len(d):>7}{sum(d):>11.2f}"
                     f"{percentile(d, 50):>9.2f}{percentile(d, 95):>9.2f}")
    lines.append("")
    lines.append("bytes per tier:")
    for k in ("wire", "cache_spill", "cache_fetch", "cache_remote",
              "weight_hbm"):
        lines.append(f"  {k:<13}{bytes_by[k]:>14,} B")
    ttfts = []
    for uid, root in roots.items():
        se = stage_end.get(uid, {})
        end = se.get("replay", se.get("admit"))
        if end is not None:
            ttfts.append((end - root["ts"]) / 1e3)
    lines.append("")
    lines.append(f"requests: {len(roots)}  "
                 f"ttft_ms mean={sum(ttfts) / len(ttfts):.1f} "
                 f"p50={percentile(ttfts, 50):.1f} "
                 f"p95={percentile(ttfts, 95):.1f}"
                 if ttfts else f"requests: {len(roots)}  (no ttft stages)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome trace-event JSON (--trace-out)")
    ap.add_argument("--check", action="store_true",
                    help="validate only; exit 1 on any violation")
    args = ap.parse_args(argv)
    errors: List[str] = []
    spans = load_events(args.trace, errors)
    if not errors:
        validate(spans, errors)
    if errors:
        for e in errors:
            print(f"ERROR: {e}", file=sys.stderr)
        return 1
    if args.check:
        print(f"OK: {args.trace} — {len(spans)} spans, "
              f"{sum(1 for e in spans if e['name'] == 'request')} requests")
        return 0
    print(summarize(spans))
    return 0


if __name__ == "__main__":
    sys.exit(main())
