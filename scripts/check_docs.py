#!/usr/bin/env python3
"""Resolve every code reference in docs/ARCHITECTURE.md against the tree.

The doc's convention: backticked references of the form `path` or
`path:symbol`, where ``path`` is repo-relative and ``symbol`` is a
function, class, module-level name, or ``Class.member`` defined in that
file.  This checker fails (exit 1, one line per problem) when a referenced
file is missing or a referenced symbol is not defined in it — so the
architecture doc cannot rot silently.  Symbols are resolved against the
AST with proper scoping (``Class.method`` must be a member of THAT class,
not merely any same-named def elsewhere in the file).  Pure stdlib; runs
in CI without the jax venv and as a tier-1 test (tests/test_docs.py).
"""

from __future__ import annotations

import ast
import pathlib
import re
import sys
from typing import List, Set

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = [ROOT / "docs" / "ARCHITECTURE.md"]

# `path/to/file.py:Symbol.or.dotted` or a bare backticked repo file path
_REF = re.compile(r"`([A-Za-z0-9_./-]+\.(?:py|md|json|yml|sh))"
                  r"(?::([A-Za-z_][A-Za-z0-9_.]*))?`")

_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def _names_of(node: ast.AST) -> List[str]:
    if isinstance(node, _DEFS):
        return [node.name]
    if isinstance(node, ast.Assign):
        return [t.id for t in node.targets if isinstance(t, ast.Name)]
    if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
        return [node.target.id]
    return []


def module_symbols(src: str) -> Set[str]:
    """Qualified definitions of a module: top-level names plus
    ``Class.member`` for every def/assignment inside a class body."""
    syms: Set[str] = set()
    for node in ast.parse(src).body:
        for name in _names_of(node):
            syms.add(name)
        if isinstance(node, ast.ClassDef):
            for sub in node.body:
                for name in _names_of(sub):
                    syms.add(f"{node.name}.{name}")
    return syms


def check_file(doc: pathlib.Path) -> List[str]:
    problems: List[str] = []
    text = doc.read_text()
    refs = sorted({(m.group(1), m.group(2)) for m in _REF.finditer(text)},
                  key=lambda r: (r[0], r[1] or ""))
    if not refs:
        problems.append(f"{doc}: no code references found — is the "
                        f"`path:symbol` convention still in use?")
    sym_cache = {}
    for path, symbol in refs:
        target = ROOT / path
        if not target.is_file():
            problems.append(f"{doc.name}: `{path}` does not exist")
            continue
        if symbol is None:
            continue
        if not path.endswith(".py"):
            problems.append(f"{doc.name}: `{path}:{symbol}` — symbol "
                            f"references only apply to .py files")
            continue
        if path not in sym_cache:
            sym_cache[path] = module_symbols(target.read_text())
        if symbol not in sym_cache[path]:
            problems.append(f"{doc.name}: `{path}:{symbol}` is not defined "
                            f"in {path}")
    return problems


def main() -> int:
    problems: List[str] = []
    n_refs = 0
    for doc in DOCS:
        if not doc.is_file():
            problems.append(f"missing doc: {doc}")
            continue
        text = doc.read_text()
        n_refs += len({m.groups() for m in _REF.finditer(text)})
        problems.extend(check_file(doc))
    if problems:
        print("\n".join(problems))
        return 1
    print(f"docs check ok: {n_refs} references resolved "
          f"across {len(DOCS)} doc(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
