#!/usr/bin/env sh
# Tier-1 verification: run the full test suite exactly as CI/the driver does.
#   ./scripts/verify.sh [extra pytest args...]
set -eu
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
